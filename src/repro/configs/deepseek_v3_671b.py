"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8
[arXiv:2412.19437; hf].  61L d_model=7168 128H d_ff=2048 vocab=129280.

Simplifications vs. the HF checkpoint (noted in DESIGN.md): all 61 layers are
MoE (v3 uses 3 dense lead-in layers); MTP head omitted; aux-free routing
bias replaced by a Switch-style balance loss.  FSDP — 671B params need
param+opt sharding over both mesh axes."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b", family="moe",
    num_layers=61, d_model=7168, num_heads=128, num_kv_heads=128,
    head_dim=128, d_ff=2048, vocab_size=129280,
    moe=True, num_experts=256, num_shared_experts=1, moe_top_k=8,
    use_mla=True, q_lora_rank=1536, kv_lora_rank=512, rope_head_dim=64,
    v_head_dim=128, fsdp=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-v3-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=256,
        moe=True, num_experts=8, num_shared_experts=1, moe_top_k=2,
        use_mla=True, q_lora_rank=32, kv_lora_rank=16, rope_head_dim=8,
        v_head_dim=16, dtype="float32",
    )
