"""deepseek-moe-16b [moe] — 2 shared + 64 routed top-6, fine-grained
[arXiv:2401.06066; hf].  28L d_model=2048 16H (kv=16) d_ff=1408
vocab=102400."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b", family="moe",
    num_layers=28, d_model=2048, num_heads=16, num_kv_heads=16,
    head_dim=128, d_ff=1408, vocab_size=102400,
    moe=True, num_experts=64, num_shared_experts=2, moe_top_k=6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="deepseek-moe-smoke", family="moe",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=32, vocab_size=256,
        moe=True, num_experts=8, num_shared_experts=2, moe_top_k=2,
        dtype="float32",
    )
