"""Assigned input shapes (the × axis of the 40-cell matrix) and
applicability rules."""
from __future__ import annotations

import dataclasses

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # 'train' | 'prefill' | 'decode'
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}


def applicable(cfg: ModelConfig, shape: ShapeSpec) -> tuple[bool, str]:
    """(runs?, reason-if-skipped).  long_500k requires sub-quadratic
    sequence mixing (SSM/hybrid); full-attention archs skip it (DESIGN.md
    §5).  All assigned archs are decoder-capable, so decode shapes run
    everywhere."""
    if shape.name == "long_500k" and not cfg.ssm:
        return False, "full-attention arch — long_500k needs sub-quadratic"
    return True, ""
