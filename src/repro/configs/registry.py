"""Architecture registry: ``--arch <id>`` resolution + input_specs().

input_specs() returns ShapeDtypeStruct stand-ins for every model input of a
given (arch × shape) cell — weak-type-correct, shardable, no device
allocation — exactly what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, ShapeSpec, applicable  # noqa: F401
from repro.models.config import ModelConfig

_MODULES = {
    "zamba2-7b": "repro.configs.zamba2_7b",
    "starcoder2-15b": "repro.configs.starcoder2_15b",
    "qwen1.5-110b": "repro.configs.qwen15_110b",
    "internlm2-1.8b": "repro.configs.internlm2_1_8b",
    "minitron-4b": "repro.configs.minitron_4b",
    "deepseek-v3-671b": "repro.configs.deepseek_v3_671b",
    "deepseek-moe-16b": "repro.configs.deepseek_moe_16b",
    "internvl2-76b": "repro.configs.internvl2_76b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "whisper-small": "repro.configs.whisper_small",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).smoke_config()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict:
    """ShapeDtypeStruct inputs for one (arch × shape) cell.

    train:   {tokens, labels [, patches | frames]}
    prefill: {tokens [, patches | frames]}
    decode:  {tokens (B,), cache: init_cache-shaped structs}
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32
    if shape.kind in ("train", "prefill"):
        batch: dict = {}
        s_text = S
        if cfg.vlm_patches:
            s_text = S - cfg.vlm_patches
            batch["patches"] = _sds((B, cfg.vlm_patches, cfg.d_model), dt)
        if cfg.enc_dec:
            batch["frames"] = _sds((B, cfg.enc_frames, cfg.d_model), dt)
        batch["tokens"] = _sds((B, s_text), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = _sds((B, s_text), jnp.int32)
        return batch
    # decode: cache shapes from init_cache without allocating.
    from repro.models import decode as D
    cache = jax.eval_shape(lambda: D.init_cache(cfg, B, S))
    cache = jax.tree.map(lambda x: _sds(x.shape, x.dtype), cache)
    return {"tokens": _sds((B,), jnp.int32), "cache": cache}
