"""mamba2-1.3b [ssm] — SSD (state-space duality) [arXiv:2405.21060;
unverified].  48L d_model=2048 (attn-free) d_ff=0 vocab=50280 ssm_state=128.
Vocab padded 50280 → 50288 for 16-way sharding divisibility."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b", family="ssm",
    num_layers=48, d_model=2048, num_heads=0, num_kv_heads=0,
    head_dim=0, d_ff=0, vocab_size=50288,
    ssm=True, ssm_state=128, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mamba2-smoke", family="ssm",
        num_layers=3, d_model=64, num_heads=0, num_kv_heads=0,
        head_dim=0, d_ff=0, vocab_size=256,
        ssm=True, ssm_state=16, ssm_head_dim=16, ssm_chunk=16,
        dtype="float32",
    )
