"""qwen1.5-110b [dense] — QKV bias [hf:Qwen/Qwen1.5-0.5B; hf].
80L d_model=8192 64H (kv=8) d_ff=49152 vocab=152064.  FSDP (params + opt
state sharded over "data" as well) — 110B does not fit TP-only on v5e."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-110b", family="dense",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=49152, vocab_size=152064,
    qkv_bias=True, fsdp=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256, qkv_bias=True,
        dtype="float32",
    )
