"""whisper-small [audio] — enc-dec, conv frontend (stub)
[arXiv:2212.04356; unverified].  12L d_model=768 12H (kv=12) d_ff=3072
vocab=51865 (padded → 51872).  input_specs() supplies precomputed
conv-frontend frames (B, 1500, d_model).  12 heads don't divide the 16-way
model axis → attn_head_tp=False.  Whisper's semantic decoder context is 448;
we still lower the assigned decode shapes at the stated cache lengths
(DESIGN.md §5)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    num_layers=12, d_model=768, num_heads=12, num_kv_heads=12,
    head_dim=64, d_ff=3072, vocab_size=51872,
    enc_dec=True, enc_layers=12, enc_frames=1500,
    attn_head_tp=False,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        enc_dec=True, enc_layers=2, enc_frames=32, attn_head_tp=False,
        dtype="float32",
    )
