"""minitron-4b [dense] — pruned nemotron [arXiv:2407.14679; hf].
32L d_model=3072 24H (kv=8) d_ff=9216 vocab=256000.

24 heads do not divide the 16-way "model" axis → attn_head_tp=False: the
attention block runs with model-axis-replicated weights (the baseline the
§Perf minitron hillclimb attacks)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b", family="dense",
    num_layers=32, d_model=3072, num_heads=24, num_kv_heads=8,
    head_dim=128, d_ff=9216, vocab_size=256000,
    attn_head_tp=False,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="minitron-smoke", family="dense",
        num_layers=3, d_model=48, num_heads=6, num_kv_heads=2,
        head_dim=8, d_ff=96, vocab_size=512, attn_head_tp=False,
        dtype="float32",
    )
