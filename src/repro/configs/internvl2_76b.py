"""internvl2-76b [vlm] — InternViT + InternLM2 backbone
[arXiv:2404.16821; unverified].  80L d_model=8192 64H (kv=8) d_ff=28672
vocab=128256.  The ViT frontend is a STUB per the brief: input_specs()
supplies precomputed patch embeddings (B, 1024, d_model) that are prepended
to the text tokens."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm",
    num_layers=80, d_model=8192, num_heads=64, num_kv_heads=8,
    head_dim=128, d_ff=28672, vocab_size=128256,
    vlm_patches=1024, fsdp=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="internvl2-smoke", family="vlm",
        num_layers=3, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256, vlm_patches=8,
        dtype="float32",
    )
