"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; unverified].  81L d_model=3584 32H (kv=32) d_ff=14336
vocab=32000 ssm_state=64.  The shared attention+MLP block's params are
reused every 6 layers (13 application points, each with its own KV cache)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    num_layers=81, d_model=3584, num_heads=32, num_kv_heads=32,
    head_dim=112, d_ff=14336, vocab_size=32000,
    ssm=True, ssm_state=64, ssm_head_dim=64, ssm_expand=2, ssm_chunk=256,
    hybrid_attn_every=6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-smoke", family="hybrid",
        num_layers=7, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=256,
        ssm=True, ssm_state=16, ssm_head_dim=16, ssm_expand=2, ssm_chunk=16,
        hybrid_attn_every=3, dtype="float32",
    )
