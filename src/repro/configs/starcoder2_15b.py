"""starcoder2-15b [dense] — GQA, RoPE [arXiv:2402.19173; hf].
40L d_model=6144 48H (kv=4) d_ff=24576 vocab=49152."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b", family="dense",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=4,
    head_dim=128, d_ff=24576, vocab_size=49152,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-smoke", family="dense",
        num_layers=3, d_model=64, num_heads=8, num_kv_heads=2,
        head_dim=8, d_ff=128, vocab_size=256, dtype="float32",
    )
