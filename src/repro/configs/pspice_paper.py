"""Canonical settings for the pSPICE paper experiments (§IV).

Single source of truth for the simulated-time cost calibration and the
query grids used by benchmarks/figures.py and the tests.  The cost constants
are calibrated so the operator's PM-matching share of per-event cost (~80%)
and the absolute throughput scale (~1–3k events/s) sit in the regime the
paper evaluates (Intel 1.6 GHz, single thread), and 120% overload reaches
the 1 s latency bound within a 60k-event stream.
"""
from __future__ import annotations

# Simulated-time cost model (seconds) — see repro/cep/engine.py.
# The shed constants are calibrated to the CURRENT O(N) histogram-
# threshold Algorithm-2 plan (DESIGN.md §8): a utility lookup plus a
# constant number of bucket passes per PM.  Runs that pin the legacy
# plan (shed_plan="sort", the oracle/bench baseline) simulate a cheaper-
# per-call model than the O(N·log N) sort would really cost — pass
# c_shed_pm=1.5e-6 (the pre-recalibration sort-plan constant) to
# reproduce the old figures exactly.
COST = dict(
    c_base=3e-4,       # per-event window/bookkeeping cost
    c_match=6e-5,      # per-PM-per-event match cost (× pattern proc_cost)
    c_shed_base=1.5e-4,  # shed-call fixed cost
    c_shed_pm=5e-7,    # shed-call per-PM cost (O(N) threshold plan)
    c_ebl=6e-5,        # residual cost of an E-BL-dropped event
)

LATENCY_BOUND = 1.0     # seconds (paper §IV-A)
RATE_MULTIPLIER = 1.2   # default overload (120% of max throughput)
MAX_PMS = 128           # PM-store capacity for the paper-scale streams
BIN_SIZE = 64           # utility-table bin size bs (§III-C-1)
WARM_FRAC = 0.3         # model-builder observation phase

# Fig. 5 grids (match probability controlled the paper's way).
Q1_WINDOW_SIZES = (2000, 3000, 4000, 6000, 8000)
Q2_WINDOW_SIZES = (3000, 4500, 6000, 9000, 12000)
Q3_PATTERN_SIZES = (2, 3, 4, 5, 6)
Q4_PATTERN_SIZES = (2, 3, 4, 5, 7)

# Fig. 6 rate grid (×100 = percent of max throughput).
RATE_GRID = (1.2, 1.4, 1.6, 1.8, 2.0)

# Fig. 8 processing-time factors τ_Q1/τ_Q2.
TAU_FACTORS = (1, 2, 4, 8, 12, 16)
