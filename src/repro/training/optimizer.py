"""AdamW with global-norm clipping — raw JAX (no optax in the image).

Optimizer moments are float32 regardless of param dtype; for fsdp archs the
moments inherit the params' (data+model)-sharded specs, giving ZeRO-2/3-style
optimizer-state sharding for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100


def init_opt_state(params: PyTree) -> PyTree:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)  # noqa: E731
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(grads: PyTree, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(1.0, (step + 1) / max(cfg.warmup_steps, 1))
    return cfg.lr * warm


def adamw_update(cfg: AdamWConfig, params: PyTree, grads: PyTree,
                 opt_state: PyTree):
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m = cfg.b1 * m + (1 - cfg.b1) * g32
        v = cfg.b2 * v + (1 - cfg.b2) * g32 * g32
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    flat, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(
        x, tuple) and len(x) == 3 and not isinstance(x, dict))
    new_p = treedef.unflatten([t[0] for t in flat])
    new_m = treedef.unflatten([t[1] for t in flat])
    new_v = treedef.unflatten([t[2] for t in flat])
    return new_p, {"m": new_m, "v": new_v, "step": step}
