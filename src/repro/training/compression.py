"""Error-feedback int8 gradient compression for the data-parallel all-reduce.

At 1000+ nodes the DP gradient sync is the cross-pod bottleneck (DCN links
are ~25× slower than intra-pod ICI).  This module quantizes gradients to
int8 with a per-tensor scale before the psum and keeps the quantization
residual locally (error feedback), which provably preserves SGD/Adam
convergence for smooth objectives.

Used via shard_map over the dp axes — see ``compressed_grad_sync``.  The
uncompressed path is the GSPMD-implicit all-reduce inside value_and_grad;
EXPERIMENTS.md §Perf quantifies the wire-byte reduction (4 bytes → 1 byte
per element, ~4× off the collective term of the multi-pod train cells).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

# Version-compatible shard_map (jax.shard_map moved out of experimental in
# newer jax): callers — including the tests — should use this symbol.
from repro.dist.compat import shard_map  # noqa: F401

PyTree = Any


def quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def init_error_state(grads_like: PyTree) -> PyTree:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32),
                        grads_like)


def compress_decompress(g: jax.Array, err: jax.Array):
    """One error-feedback round WITHOUT the collective (numerics path,
    unit-testable): returns (decompressed, new_err)."""
    corrected = g.astype(jnp.float32) + err
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale)
    return deq, corrected - deq


def compressed_psum(g: jax.Array, err: jax.Array, axis_name: str):
    """Quantize + psum(int32 accumulate) + dequantize, with error feedback.

    Wire bytes: 1B/element (int8) vs 4B (f32) — the scales are scalar.
    The shards agree on a shared (max) scale BEFORE quantizing — a scalar
    pmax — so the int32 sum dequantizes exactly; quantizing with per-shard
    scales and dequantizing with the shared one would inflate every
    shard's contribution to the max shard's magnitude.
    """
    corrected = g.astype(jnp.float32) + err
    local_scale = jnp.maximum(jnp.max(jnp.abs(corrected)), 1e-12) / 127.0
    scale = jax.lax.pmax(local_scale, axis_name)  # shared scale (scalar)
    q = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - dequantize_int8(q, scale)
    # Accumulate in int32 to avoid overflow across the ring.
    total = jax.lax.psum(q.astype(jnp.int32), axis_name)
    n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
    mean = total.astype(jnp.float32) * scale / n
    return mean, new_err


def sync_tree(grads: PyTree, err: PyTree, axis_name: str):
    """Tree-mapped compressed_psum for use INSIDE a shard_map whose mapped
    axis is the DP axis (each shard holds its own microbatch gradients).
    Returns (mean_grads, new_err)."""
    flat_g, td = jax.tree.flatten(grads)
    flat_e = td.flatten_up_to(err)
    out = [compressed_psum(g, e, axis_name) for g, e in zip(flat_g, flat_e)]
    return (td.unflatten([o[0] for o in out]),
            td.unflatten([o[1] for o in out]))


def wire_bytes_saved(grads: PyTree) -> tuple[int, int]:
    """(f32_bytes, int8_bytes) per all-reduce round — the §Perf accounting."""
    n = sum(int(np.prod(g.shape)) for g in jax.tree.leaves(grads))
    return 4 * n, 1 * n

