"""The jitted training step: loss → grads → clip → AdamW."""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training import optimizer as O

PyTree = Any


def make_train_step(cfg: ModelConfig, opt_cfg: O.AdamWConfig | None = None,
                    remat: bool = True, causal_skip: bool = True):
    opt_cfg = opt_cfg or O.AdamWConfig()

    def train_step(params: PyTree, opt_state: PyTree, batch: dict):
        def loss_fn(p):
            return T.forward_train(cfg, p, batch, remat=remat,
                                   causal_skip=causal_skip)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        grads, gnorm = O.clip_by_global_norm(grads, opt_cfg.clip_norm)
        params, opt_state = O.adamw_update(opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=opt_state["step"])
        return params, opt_state, metrics

    return train_step


def make_eval_step(cfg: ModelConfig, remat: bool = False):
    def eval_step(params: PyTree, batch: dict):
        loss, metrics = T.forward_train(cfg, params, batch, remat=remat)
        return metrics["ce"]

    return eval_step
