"""Fault-tolerant sharded checkpointing.

Design (maps 1:1 onto a multi-host deployment; exercised single-process
here):
  - step-tagged directories ``<root>/step_%08d``;
  - atomic commit: write into ``.tmp-...``, fsync, rename (a crashed writer
    never corrupts the latest checkpoint);
  - per-array .npy files keyed by flattened pytree path + a JSON manifest
    (tree structure, shapes, dtypes, step) — on a cluster each host writes
    only the shards it owns (addressable-device filtering hook included);
  - RESHARDING restore: arrays are loaded as global numpy and re-sharded by
    the jit boundary of whatever mesh the restoring job uses — checkpoints
    written on a 256-chip mesh restore fine onto 512 chips or 1 CPU
    (elastic scaling / shrink-to-recover);
  - keep-last-k garbage collection;
  - NaN-guard restore loop lives in launch/train.py.
"""
from __future__ import annotations

import json
import os
import re
import shutil
from typing import Any

import jax
import numpy as np

PyTree = Any

_STEP_RE = re.compile(r"^step_(\d{8})$")


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(root: str, step: int, tree: PyTree, *, keep_last: int = 3) -> str:
    """Atomically write a checkpoint; returns the committed directory."""
    os.makedirs(root, exist_ok=True)
    final = os.path.join(root, f"step_{step:08d}")
    tmp = os.path.join(root, f".tmp-step_{step:08d}")
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    flat = _flatten(tree)
    manifest = {"step": step, "arrays": {}}
    for key, arr in flat.items():
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["arrays"][key] = {"file": fname,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)}
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)           # atomic commit
    _gc(root, keep_last)
    return final


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [int(m.group(1)) for d in os.listdir(root)
             if (m := _STEP_RE.match(d))]
    return max(steps) if steps else None


def restore(root: str, tree_like: PyTree, step: int | None = None) -> PyTree:
    """Load into the structure of ``tree_like`` (shapes must match; mesh may
    differ — resharding happens at the next jit boundary)."""
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {root}")
    d = os.path.join(root, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(
        tree_like)
    out = []
    for path, leaf in leaves_with_path:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        meta = manifest["arrays"][key]
        arr = np.load(os.path.join(d, meta["file"]))
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {leaf.shape}")
        out.append(arr)
    return treedef.unflatten(out)


def _gc(root: str, keep_last: int) -> None:
    steps = sorted(int(m.group(1)) for d in os.listdir(root)
                   if (m := _STEP_RE.match(d)))
    for s in steps[:-keep_last] if keep_last > 0 else []:
        shutil.rmtree(os.path.join(root, f"step_{s:08d}"),
                      ignore_errors=True)
