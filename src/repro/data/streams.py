"""Synthetic event-stream generators shaped like the paper's three datasets
(§IV-A): NYSE intraday stock quotes, RTLS soccer positions (DEBS'13), and
Dublin public bus traffic (PLBT).

The container is offline, so we generate streams with the *statistical
structure* the queries care about (event-type mix, window-open rates,
matchable-event probabilities, distinct-id cardinalities) and control the
match probability the way the paper does — via window size (Q1/Q2) or pattern
size (Q3/Q4).

Each generator returns a RawStream; ``classify`` turns a RawStream + pattern
list into the engine's EventBatch (per-pattern class / bind / open arrays).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax.numpy as jnp
import numpy as np

from repro.cep import patterns as pat
from repro.cep.engine import EventBatch


@dataclasses.dataclass
class RawStream:
    """Dataset-agnostic event records (column-oriented)."""
    kind: str                 # 'stock' | 'soccer' | 'bus'
    n: int
    type_id: np.ndarray       # (n,) int32 — symbol / player / bus id
    attr: np.ndarray          # (n,) int32 — rise(1)/fall(0) | defend striker
                              #   id | delayed(1)/on-time(0)
    group: np.ndarray         # (n,) int32 — n/a | striker id | stop id
    num_types: int


# ---------------------------------------------------------------------------
# Generators
# ---------------------------------------------------------------------------

def gen_stock(n: int, num_symbols: int = 500, pattern_symbols: int = 10,
              hot_fraction: float = 0.9, p_class: float = 0.03,
              seed: int = 0) -> RawStream:
    """NYSE-like quote stream: `num_symbols` symbols; per-tick attr=1 when
    the quote rises strongly enough to count as a pattern event (RE_x).

    The 10 pattern symbols (ids 0..9) dominate tick volume (hot_fraction) —
    large caps dominate trading, and it creates the regime the paper's E-BL
    baseline faces: the droppable irrelevant pool is small, so event-level
    shedding must drop events of pattern symbols (whose matchable/
    non-matchable ticks it cannot tell apart at type granularity).
    p_class controls the per-tick probability that a pattern-symbol quote is
    a matchable rise — i.e. the completion-time scale, hence (via the window
    size) the match probability, the paper's Fig. 5 x-axis.

    The stationary special case of ``gen_stock_drift`` (same RNG draw
    order, so identical seeds give identical streams).
    """
    return gen_stock_drift(n, num_symbols=num_symbols,
                           pattern_symbols=pattern_symbols,
                           hot_fraction=hot_fraction,
                           p_class=p_class, p_class_end=p_class, seed=seed)


def gen_stock_drift(n: int, num_symbols: int = 500,
                    pattern_symbols: int = 10,
                    hot_fraction: float = 0.9,
                    hot_fraction_end: float | None = None,
                    p_class: float = 0.03, p_class_end: float = 0.10,
                    seed: int = 0) -> RawStream:
    """NYSE-like stream whose statistics DRIFT across the stream: the
    matchable-rise probability (and optionally the hot-symbol share) ramps
    linearly from its start to its end value.

    This is the regime the runtime's online model refresh exists for
    (repro.runtime.refresh, DESIGN.md §7): a model built on the head of
    the stream has stale transition probabilities — hence stale completion
    probabilities and utilities — by the tail.  A one-shot builder keeps
    shedding by the head's statistics; a refreshing runtime tracks the
    ramp.
    """
    rng = np.random.default_rng(seed)
    t = np.arange(n) / max(n - 1, 1)
    hot_frac = hot_fraction if hot_fraction_end is None else \
        hot_fraction + (hot_fraction_end - hot_fraction) * t
    p_cls = p_class + (p_class_end - p_class) * t
    hot = rng.integers(0, pattern_symbols, size=n)
    cold = rng.integers(pattern_symbols, num_symbols, size=n)
    is_hot = rng.random(n) < hot_frac
    type_id = np.where(is_hot, hot, cold).astype(np.int32)
    rise = ((rng.random(n) < p_cls) & is_hot).astype(np.int32)
    return RawStream(kind="stock", n=n, type_id=type_id, attr=rise,
                     group=np.zeros(n, np.int32), num_types=num_symbols)


def drifting_arrivals(n: int, rate: float, rate_end: float) -> np.ndarray:
    """Arrival times for a linearly drifting event rate (events/second):
    the instantaneous rate ramps rate → rate_end over the stream, so the
    operator's load — and the overload detector's headroom — shifts under
    it mid-run."""
    t = np.arange(n) / max(n - 1, 1)
    inst = rate + (rate_end - rate) * t
    gaps = 1.0 / np.maximum(inst, 1e-9)
    arr = np.cumsum(gaps) - gaps[0]
    return arr.astype(np.float32)


def gen_soccer(n: int, num_players: int = 32, num_strikers: int = 2,
               p_striker: float = 0.004, p_defend: float = 0.05,
               seed: int = 0) -> RawStream:
    """RTLS-like stream: ball-possession events by strikers open windows;
    defend events (defender within distance of the striker) are class-1.

    attr = striker id a defend event refers to (the last striker in
    possession); group mirrors attr for binding.
    """
    rng = np.random.default_rng(seed)
    r = rng.random(n)
    is_striker = r < p_striker
    is_defend = (~is_striker) & (r < p_striker + p_defend)
    striker_ids = rng.integers(0, num_strikers, size=n).astype(np.int32)
    # Last striker in possession (binding for defend events).
    cur = np.maximum.accumulate(
        np.where(is_striker, np.arange(n), -1))
    last_striker = np.where(cur >= 0, striker_ids[np.maximum(cur, 0)], -1)
    defender = rng.integers(num_strikers, num_players, size=n).astype(np.int32)
    type_id = np.where(is_striker, striker_ids,
                       np.where(is_defend, defender, -1)).astype(np.int32)
    attr = np.where(is_striker, 2, np.where(is_defend, 1, 0)).astype(np.int32)
    group = np.where(is_striker, striker_ids, last_striker).astype(np.int32)
    return RawStream(kind="soccer", n=n, type_id=type_id, attr=attr,
                     group=group, num_types=num_players)


def gen_bus(n: int, num_buses: int = 911, num_stops: int = 48,
            p_delay: float = 0.08, burst_stops: int = 6,
            burst_boost: float = 4.0, seed: int = 0) -> RawStream:
    """PLBT-like stream: bus events at stops; delays cluster on a few
    'incident' stops (the correlated-delay structure Q4 detects)."""
    rng = np.random.default_rng(seed)
    bus = rng.integers(0, num_buses, size=n).astype(np.int32)
    stop = rng.integers(0, num_stops, size=n).astype(np.int32)
    p = np.full(n, p_delay)
    hot = rng.choice(num_stops, size=burst_stops, replace=False)
    p[np.isin(stop, hot)] = np.minimum(p_delay * burst_boost, 0.9)
    delayed = (rng.random(n) < p).astype(np.int32)
    return RawStream(kind="bus", n=n, type_id=bus, attr=delayed, group=stop,
                     num_types=num_buses)


# ---------------------------------------------------------------------------
# Classification: RawStream × patterns → EventBatch
# ---------------------------------------------------------------------------

def _classify_one(spec: pat.PatternSpec, raw: RawStream):
    """Per-pattern (class, bind, open, potential_class) arrays for one stream.

    ``potential_class`` is the class the event's TYPE could produce (e.g.
    any tick of pattern symbol j, rising or not, has potential class j+1).
    E-BL only sees type granularity — it cannot tell matchable from
    non-matchable events of the same type (paper §IV-A: "an event type
    (e.g., player Id or stock symbol)").
    """
    n = raw.n
    if raw.kind == "stock":
        # Class j (1..C) == strongly-rising quote of pattern symbol j-1.
        is_pat = raw.type_id < spec.num_classes
        pot = np.where(is_pat, raw.type_id + 1, 0)
        cls = np.where(is_pat & (raw.attr == 1), raw.type_id + 1, 0)
        opener = spec.class_sequence[0] if spec.class_sequence else 1
        opens = cls == opener
        bind = np.full(n, -1, np.int32)
    elif raw.kind == "soccer":
        cls = np.where(raw.attr == 1, 1, 0)          # defend events
        opens = raw.attr == 2                        # striker possession
        bind = raw.group                             # striker id
        # Any player event could be a defend (or striker) event.
        pot = np.where(raw.attr == 2, 2, np.where(raw.type_id >= 0, 1, 0))
    elif raw.kind == "bus":
        cls = np.where(raw.attr == 1, 1, 0)          # delayed bus
        # Slide-opened windows: every `slide` events.
        opens = (np.arange(n) % max(spec.slide, 1)) == 0
        bind = raw.group                             # stop id
        pot = np.ones(n, np.int32)                   # every bus could delay
    else:
        raise ValueError(raw.kind)
    return (cls.astype(np.int32), bind.astype(np.int32), opens.astype(bool),
            pot.astype(np.int32))


def ebl_event_priorities(specs: Sequence[pat.PatternSpec], raw: RawStream,
                         pot_per_pattern: np.ndarray) -> np.ndarray:
    """E-BL raw drop priority per event (paper §IV-A baseline 2).

    Event-TYPE utility ∝ repetition of the type's potential class across
    pattern definitions ÷ the type's frequency in windows; priority =
    1 − normalized utility (0 = never drop, 1 = drop first).  Types
    irrelevant to every pattern get priority 1 and are shed first; when the
    irrelevant pool can't cover the drop budget, the feedback controller in
    the engine pushes the drop fraction up until pattern-type events are
    dropped too — at type granularity, uniform sampling within a type then
    hits matchable events (the source of E-BL's false negatives).
    """
    n = raw.n
    util = np.zeros(n)
    for p, spec in enumerate(specs):
        pot = pot_per_pattern[:, p]
        if spec.kind == pat.KIND_SEQ:
            seq = np.array(spec.class_sequence)
            rep = np.bincount(seq, minlength=spec.num_classes + 1).astype(
                float)
        else:
            rep = np.zeros(3)
            rep[1] = spec.any_n
            rep[2] = 1.0  # the opener (e.g. striker) appears once
        freq = np.bincount(pot, minlength=len(rep)).astype(float) / n
        u = np.where(pot > 0, rep[pot] / np.maximum(freq[pot], 1e-9), 0.0)
        util += spec.weight * u
    umax = max(util.max(), 1e-9)
    return (1.0 - util / umax).astype(np.float32)


def classify(specs: Sequence[pat.PatternSpec], raw: RawStream, rate: float,
             seed: int = 0, rate_end: float | None = None) -> EventBatch:
    """Build the engine's EventBatch: per-pattern class/bind/open + arrival
    times for the given input event rate (events/second).  With
    ``rate_end`` the arrival rate ramps linearly rate → rate_end
    (``drifting_arrivals``) — the drifting-load workload for the streaming
    runtime's online refresh."""
    P = len(specs)
    cls = np.zeros((raw.n, P), np.int32)
    bind = np.zeros((raw.n, P), np.int32)
    opens = np.zeros((raw.n, P), bool)
    pot = np.zeros((raw.n, P), np.int32)
    for p, spec in enumerate(specs):
        cls[:, p], bind[:, p], opens[:, p], pot[:, p] = _classify_one(
            spec, raw)
    ebl_raw = ebl_event_priorities(specs, raw, pot)
    rng = np.random.default_rng(seed + 1234)
    arrival = (np.arange(raw.n) / rate).astype(np.float32) \
        if rate_end is None else drifting_arrivals(raw.n, rate, rate_end)
    return EventBatch(
        ev_class=jnp.asarray(cls),
        ev_bind=jnp.asarray(bind),
        ev_open=jnp.asarray(opens),
        ev_id=jnp.asarray(raw.type_id),
        ev_rand=jnp.asarray(rng.random(raw.n), dtype=jnp.float32),
        ebl_raw=jnp.asarray(ebl_raw),
        arrival=jnp.asarray(arrival),
    )


# ---------------------------------------------------------------------------
# Scenario registry: the SEEDED evaluation scenarios (one per paper dataset)
# shared by the quality sweep (repro.eval.sweep), the backend-parity tests
# and the metamorphic shedding tests — so "the stock workload" means the
# same specs, generator parameters and seed everywhere (DESIGN.md §9).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Scenario:
    """A named, fully-seeded evaluation workload: which queries run
    against which generated stream, plus the engine sizing the paper's
    configuration uses for it.  ``n`` scales the stream length (tests use
    short streams, sweeps long ones); everything else is pinned.

    The parameters put each dataset in the regime the paper evaluates:
    the operator's input is dominated by relevant event types (so
    event-level shedding cannot hide in an irrelevant-event pool), the
    PM store has real churn (so PM shedding acts as a continuous
    utility-driven filter, not a one-off wipe), and the latency bound
    sits within a small multiple of the store's processing time (so
    Algorithm 1 computes *partial* shed amounts).
    """
    name: str
    dataset: str                                   # generator family
    make_specs: Callable[[], list]                 # () -> [PatternSpec]
    gen: Callable[[int, int], RawStream]           # (n, seed) -> RawStream
    n_default: int                                 # full-sweep stream length
    n_quick: int                                   # CI --quick stream length
    seed: int = 7
    max_pms: int = 256
    bin_size: int = 64
    latency_bound: float = 0.05

    def specs(self) -> list:
        return self.make_specs()

    def raw(self, n: int | None = None, seed: int | None = None) -> RawStream:
        return self.gen(n if n is not None else self.n_default,
                        self.seed if seed is None else seed)


SCENARIOS: dict[str, Scenario] = {}


def register_scenario(sc: Scenario) -> Scenario:
    if sc.name in SCENARIOS:
        raise ValueError(f"duplicate scenario {sc.name!r}")
    SCENARIOS[sc.name] = sc
    return sc


def get_scenario(name: str) -> Scenario:
    try:
        return SCENARIOS[name]
    except KeyError:
        raise KeyError(f"unknown scenario {name!r}; "
                       f"have {sorted(SCENARIOS)}") from None


def scenario_names() -> list[str]:
    return sorted(SCENARIOS)


register_scenario(Scenario(
    name="stock", dataset="stock",
    # Q1 over the NYSE-like quote stream (§IV-A) as a multi-query grid —
    # the same 10-symbol rising-quote sequence at three window sizes
    # (the paper's Fig. 5 x-axis), sharing one PM store.
    make_specs=lambda: [pat.make_q1(window_size=w, num_symbols=10)
                        for w in (600, 1200, 2400)],
    gen=lambda n, seed: gen_stock(n, num_symbols=500, pattern_symbols=10,
                                  hot_fraction=0.95, p_class=0.1, seed=seed),
    n_default=30000, n_quick=12000))

register_scenario(Scenario(
    name="soccer", dataset="soccer",
    # Q3 over the RTLS-like position stream: striker possession opens a
    # window; any_n distinct defenders bound to the striker complete it.
    # The any_n grid is the paper's Fig. 5 pattern-size axis; defend
    # events dominate the stream, so E-BL's type-utility model must
    # choose between them and the (rarer, window-opening) striker events.
    make_specs=lambda: [pat.make_q3(any_n=a, window_size=150)
                        for a in range(2, 10)],
    gen=lambda n, seed: gen_soccer(n, num_players=14, num_strikers=2,
                                   p_striker=0.08, p_defend=0.88,
                                   seed=seed),
    n_default=30000, n_quick=12000))

register_scenario(Scenario(
    name="bus", dataset="bus",
    # Q4 over the Dublin-bus-like stream: any_n distinct delayed buses at
    # the same stop inside count-slid windows.  Every bus event is a
    # potential delay, so the stream has no irrelevant-event pool at all.
    make_specs=lambda: [pat.make_q4(any_n=3, window_size=600, slide=200)],
    gen=lambda n, seed: gen_bus(n, num_buses=911, num_stops=48,
                                p_delay=0.08, seed=seed),
    n_default=30000, n_quick=12000, max_pms=128))
