"""Load shedders (paper §III-F Algorithm 2 + §IV-A baselines).

All shedders operate on the dense PM store of the vectorized CEP operator:
    active     (N,) bool   — live PM mask
    pattern_id (N,) int32  — which query each PM belongs to
    state      (N,) int32  — current state machine state
    r_w        (N,) int32  — events remaining in the PM's window
Dropping a PM == clearing its mask bit; no payload movement (TPU adaptation
of Alg. 2's sort-and-remove, see DESIGN.md §3).

Shedders:
  - pspice_drop:  utility-table lookup (O(1)/PM) + keep-top-(n-ρ) by utility.
  - random_drop:  PM-BL — Bernoulli-uniform ρ-subset drop.
  - (E-BL, the event-level baseline, lives in the engine's input path —
     see repro/cep/engine.py — because it sheds events, not PMs.)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import utility as util

Array = jax.Array


def pspice_utilities(stacked_tables: Array, bin_sizes: Array,
                     active: Array, pattern_id: Array, state: Array,
                     r_w: Array) -> Array:
    """Utility per PM slot; inactive slots get +inf so they are never chosen
    as 'lowest utility' (they aren't droppable — already empty)."""
    u = util.multi_pattern_lookup(stacked_tables, bin_sizes, pattern_id,
                                  state, r_w)
    return jnp.where(active, u, jnp.inf)


def drop_lowest_utility(active: Array, utilities: Array, rho: Array) -> Array:
    """Algorithm 2: drop the rho active PMs with the lowest utilities.

    Vectorized equivalent of sort + drop-first-ρ: rank PMs by utility
    ascending; clear slots whose rank < ρ.  rho is a traced scalar so this is
    jit/scan-safe (no dynamic shapes).
    """
    order = jnp.argsort(utilities)                # ascending; inf (inactive) last
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    drop = ranks < rho
    return active & ~drop


def random_drop(key: Array, active: Array, rho: Array) -> Array:
    """PM-BL: drop a uniformly random ρ-subset of active PMs (Bernoulli
    sampler realized as random ranking — exactly ρ dropped, matching the
    budget the overload detector computed)."""
    scores = jax.random.uniform(key, active.shape)
    scores = jnp.where(active, scores, jnp.inf)
    order = jnp.argsort(scores)
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    return active & ~(ranks < rho)


def shed(kind: str, *, key: Array, active: Array, rho: Array,
         stacked_tables: Array | None = None, bin_sizes: Array | None = None,
         pattern_id: Array | None = None, state: Array | None = None,
         r_w: Array | None = None) -> Array:
    """Dispatch helper used by the engine. kind in {'pspice', 'pmbl'}."""
    if kind == "pspice":
        u = pspice_utilities(stacked_tables, bin_sizes, active, pattern_id,
                             state, r_w)
        return drop_lowest_utility(active, u, rho)
    if kind == "pmbl":
        return random_drop(key, active, rho)
    raise ValueError(f"unknown shedder kind: {kind}")


# ---------------------------------------------------------------------------
# E-BL event-utility model (paper §IV-A baseline 2, after He et al. [15] +
# weighted sampling [13]).  Event *types* get utility proportional to their
# repetition in patterns and in windows; low-utility types are dropped from
# incoming windows by uniform sampling within type.
# ---------------------------------------------------------------------------

def ebl_type_utilities(pattern_class_of_type: Array,
                       class_repetition_in_patterns: Array,
                       type_frequency_in_windows: Array) -> Array:
    """Utility per event type.

    pattern_class_of_type: (n_types,) int32 — pattern class each raw event
        type maps to (0 == irrelevant to every pattern).
    class_repetition_in_patterns: (n_classes,) float — how often the class
        appears across pattern definitions (importance ∝ repetition).
    type_frequency_in_windows: (n_types,) float — empirical frequency (types
        that are rare in windows are harder to replace → more valuable).
    """
    rep = class_repetition_in_patterns[pattern_class_of_type]
    freq = jnp.maximum(type_frequency_in_windows, 1e-9)
    u = rep / freq
    return jnp.where(pattern_class_of_type > 0, u, 0.0)


def ebl_drop_mask(key: Array, type_of_event: Array, type_utils: Array,
                  drop_fraction: Array) -> Array:
    """Per-event drop decision: drop probability inversely related to the
    event type's utility, scaled so the expected drop rate == drop_fraction.

    Returns bool (n_events,) — True means the event is dropped before window
    processing (black-box shedding)."""
    u = type_utils[type_of_event]
    u_max = jnp.maximum(u.max(), 1e-9)
    # Normalized "keep priority" in [0, 1]; uniform sampling within a type.
    keep_priority = u / u_max
    # Drop probability per event, renormalized to hit the global budget.
    raw = 1.0 - keep_priority
    mean_raw = jnp.maximum(raw.mean(), 1e-9)
    p_drop = jnp.clip(raw * (drop_fraction / mean_raw), 0.0, 1.0)
    return jax.random.uniform(key, type_of_event.shape) < p_drop
