"""Load shedders (paper §III-F Algorithm 2 + §IV-A baselines).

All shedders operate on the dense PM store of the vectorized CEP operator:
    active     (N,) bool   — live PM mask
    pattern_id (N,) int32  — which query each PM belongs to
    state      (N,) int32  — current state machine state
    r_w        (N,) int32  — events remaining in the PM's window
Dropping a PM == clearing its mask bit; no payload movement (TPU adaptation
of Alg. 2's sort-and-remove, see DESIGN.md §3).

Shedders:
  - pspice_drop:  utility-table lookup (O(1)/PM) + keep-top-(n-ρ) by utility.
  - random_drop:  PM-BL — Bernoulli-uniform ρ-subset drop.
  - (E-BL, the event-level baseline, lives in the engine's input path —
     see repro/cep/engine.py — because it sheds events, not PMs.)

Selection plans (DESIGN.md §3, §8):
  - "threshold" (default): ``threshold_drop_mask`` — an O(N)
    histogram-refinement select.  No sort anywhere on the hot path.
  - "sort": the original argsort rank (kept as the oracle the threshold
    plan is property-tested against, and as the legacy baseline
    ``benchmarks/bench_engine.py`` measures the win over).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import utility as util

Array = jax.Array

# Finite inactive-slot sentinel (f32-safe inf).  A PYTHON float on
# purpose: a module-level jnp array would be a captured constant inside
# the block megakernel's Pallas trace (kernels/block_step.py runs
# ``threshold_drop_mask`` in-kernel); a weak scalar inlines, and
# promotes to the identical f32 value.
_BIG = 3.4e38


def pspice_utilities(stacked_tables: Array, bin_sizes: Array,
                     active: Array, pattern_id: Array, state: Array,
                     r_w: Array) -> Array:
    """Utility per PM slot; inactive slots get +inf so they are never chosen
    as 'lowest utility' (they aren't droppable — already empty)."""
    u = util.multi_pattern_lookup(stacked_tables, bin_sizes, pattern_id,
                                  state, r_w)
    return jnp.where(active, u, jnp.inf)


def drop_lowest_utility(active: Array, utilities: Array, rho: Array) -> Array:
    """Algorithm 2 ORACLE: drop the rho active PMs with the lowest utilities.

    Vectorized equivalent of sort + drop-first-ρ: rank PMs by utility
    ascending; clear slots whose rank < ρ.  rho is a traced scalar so this is
    jit/scan-safe (no dynamic shapes).  O(N log N) — the per-event hot path
    uses ``threshold_drop_mask`` instead; this stays as the property-test
    oracle and the legacy plan (``plan="sort"``).
    """
    order = jnp.argsort(utilities)                # ascending; inf (inactive) last
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    drop = ranks < rho
    return active & ~drop


def bucket_edges(lo: Array, hi: Array, nbins: int) -> Array:
    """The (nbins+1,) bucket edges every histogram implementation shares.

    The SAME expression is used by the jnp histogram below and by the
    Pallas kernel (``kernels.shed_select.utility_histogram_pallas``), so
    boundary values land in the same bucket bit-for-bit on every backend.
    The top edge is +inf: the last bucket is right-closed (it owns the max).
    """
    edges = lo + (hi - lo) * jnp.arange(nbins + 1, dtype=jnp.float32) / nbins
    return edges.at[-1].set(jnp.inf)


def _histogram_jnp(u: Array, mask: Array, lo: Array, hi: Array,
                   nbins: int) -> Array:
    """O(N) masked bucket counts via one scatter-add.  Bucket membership is
    edge-comparison based (searchsorted against ``bucket_edges``) so it
    agrees exactly with the Pallas histogram kernel."""
    edges = bucket_edges(lo, hi, nbins)
    b = jnp.clip(jnp.searchsorted(edges, jnp.where(mask, u, lo),
                                  side="right") - 1, 0, nbins - 1)
    return jnp.zeros((nbins,), jnp.int32).at[b].add(mask.astype(jnp.int32))


def threshold_drop_mask(active: Array, utilities: Array, rho: Array, *,
                        nbins: int = 128, levels: int = 3,
                        hist_fn=None) -> Array:
    """Algorithm 2 without the sort: O(N·levels) histogram-refinement select.

    Each level buckets the surviving candidate set over [lo, hi), finds the
    boundary bucket that contains the ρ-th lowest utility (cumsum over the
    tiny histogram + searchsorted), drops everything strictly below it, and
    recurses INTO the bucket.  After ``levels`` rounds the candidate span is
    (hi-lo)/nbins**levels wide; the remaining budget breaks ties by slot
    index — exactly the stable-argsort oracle's tie order once the bucket
    has collapsed to a single f32 value (all-ties inputs are bitwise equal
    to the oracle).  Guarantees, for any input (tests/test_shedder.py):
      - exactly min(ρ, n_active) PMs dropped,
      - inactive slots never revived,
      - max(dropped utility) ≤ min(kept utility) + (hi-lo)/nbins**levels.

    ``hist_fn(u, lo, hi) -> (nbins,) int32`` may be supplied to compute the
    bucket counts (the Pallas-kernel path passes
    ``utility_histogram_pallas``); excluded entries are passed as NaN, which
    no bucket counts.  The default is one jnp scatter-add; both agree
    bitwise because they share ``bucket_edges``.
    """
    u = utilities.astype(jnp.float32)
    n_active = active.sum().astype(jnp.int32)
    need = jnp.minimum(rho.astype(jnp.int32), n_active)
    lo = jnp.min(jnp.where(active, u, _BIG))
    hi0 = jnp.max(jnp.where(active, u, -_BIG))
    hi = jnp.where(hi0 > lo, hi0, lo + 1.0)
    mask = active
    drop = jnp.zeros_like(active)
    for _ in range(levels):
        if hist_fn is None:
            hist = _histogram_jnp(u, mask, lo, hi, nbins)
        else:
            hist = hist_fn(jnp.where(mask, u, jnp.nan), lo, hi)
        cum = jnp.cumsum(hist)
        # First bucket whose cumulative count reaches the remaining budget.
        kb = jnp.clip(jnp.searchsorted(cum, need, side="left"), 0, nbins - 1)
        # Boundary values MUST compare against the very same f32 edge the
        # histogram bucketed them with — take it from the shared edges.
        edges = bucket_edges(lo, hi, nbins)
        edge = edges[kb]
        upper = edges[kb + 1]                 # +inf for the last bucket
        below = mask & (u < edge)
        drop = drop | below
        need = jnp.maximum(need - below.sum().astype(jnp.int32), 0)
        mask = mask & ~below & (u < upper)
        lo = edge
        hi_next = jnp.where(kb == nbins - 1, hi, upper)
        hi = jnp.where(hi_next > lo, hi_next, lo + 1.0)
    # Exact-ρ remainder inside the final bucket: first `need` by slot index.
    idx_rank = jnp.cumsum(mask) - 1
    drop = drop | (mask & (idx_rank < need))
    return active & ~drop


def random_drop(key: Array, active: Array, rho: Array) -> Array:
    """PM-BL: drop a uniformly random ρ-subset of active PMs — exactly ρ
    dropped, matching the budget the overload detector computed.  Realized
    as the O(N) threshold select over iid uniform scores (the ρ lowest of
    iid uniforms are a uniform ρ-subset); no sort."""
    scores = jax.random.uniform(key, active.shape)
    return threshold_drop_mask(active, scores, rho)


def shed(kind: str, *, key: Array, active: Array, rho: Array,
         stacked_tables: Array | None = None, bin_sizes: Array | None = None,
         pattern_id: Array | None = None, state: Array | None = None,
         r_w: Array | None = None, plan: str = "threshold") -> Array:
    """Dispatch helper used by the engine. kind in {'pspice', 'pmbl'};
    plan in {'threshold', 'sort'} (see module docstring)."""
    if kind == "pspice":
        u = pspice_utilities(stacked_tables, bin_sizes, active, pattern_id,
                             state, r_w)
        if plan == "sort":
            return drop_lowest_utility(active, u, rho)
        return threshold_drop_mask(active, u, rho)
    if kind == "pmbl":
        if plan == "sort":
            scores = jax.random.uniform(key, active.shape)
            scores = jnp.where(active, scores, jnp.inf)
            return drop_lowest_utility(active, scores, rho)
        return random_drop(key, active, rho)
    raise ValueError(f"unknown shedder kind: {kind}")


# ---------------------------------------------------------------------------
# E-BL event-utility model (paper §IV-A baseline 2, after He et al. [15] +
# weighted sampling [13]).  Event *types* get utility proportional to their
# repetition in patterns and in windows; low-utility types are dropped from
# incoming windows by uniform sampling within type.
# ---------------------------------------------------------------------------

def ebl_type_utilities(pattern_class_of_type: Array,
                       class_repetition_in_patterns: Array,
                       type_frequency_in_windows: Array) -> Array:
    """Utility per event type.

    pattern_class_of_type: (n_types,) int32 — pattern class each raw event
        type maps to (0 == irrelevant to every pattern).
    class_repetition_in_patterns: (n_classes,) float — how often the class
        appears across pattern definitions (importance ∝ repetition).
    type_frequency_in_windows: (n_types,) float — empirical frequency (types
        that are rare in windows are harder to replace → more valuable).
    """
    rep = class_repetition_in_patterns[pattern_class_of_type]
    freq = jnp.maximum(type_frequency_in_windows, 1e-9)
    u = rep / freq
    return jnp.where(pattern_class_of_type > 0, u, 0.0)


def ebl_drop_mask(key: Array, type_of_event: Array, type_utils: Array,
                  drop_fraction: Array) -> Array:
    """Per-event drop decision: drop probability inversely related to the
    event type's utility, scaled so the expected drop rate == drop_fraction.

    Returns bool (n_events,) — True means the event is dropped before window
    processing (black-box shedding)."""
    u = type_utils[type_of_event]
    u_max = jnp.maximum(u.max(), 1e-9)
    # Normalized "keep priority" in [0, 1]; uniform sampling within a type.
    keep_priority = u / u_max
    # Drop probability per event, renormalized to hit the global budget.
    raw = 1.0 - keep_priority
    mean_raw = jnp.maximum(raw.mean(), 1e-9)
    p_drop = jnp.clip(raw * (drop_fraction / mean_raw), 0.0, 1.0)
    return jax.random.uniform(key, type_of_event.shape) < p_drop
