"""Overload detection & shed-amount computation (paper §III-E, Algorithm 1).

The overload detector estimates, per input event,
    l_e = l_q + l_p        (queueing + processing latency)
and triggers shedding when  l_e + l_s (+ b_s) > LB.

l_p = f(n_pm) and l_s = g(n_pm) are regressions learned online from
(n_pm, latency) samples; the paper "applies several regression models ... and
uses the one that results in lower error".  We fit a linear model and an
n·log2(n) model and keep the better one.  f must be invertible to compute
n'_pm = f^{-1}(l'_p) (Alg. 1 line 7); both candidates have closed-form
inverses.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

Array = jax.Array

LINEAR, NLOGN = 0, 1


@dataclasses.dataclass
class LatencyModel:
    """l = a·basis(n) + b with basis either n or n·log2(n+1)."""
    a: Array
    b: Array
    kind: Array  # int32 scalar: LINEAR or NLOGN

    def __call__(self, n_pm: Array) -> Array:
        return predict_latency(self, n_pm)


jax.tree_util.register_pytree_node(
    LatencyModel,
    lambda m: ((m.a, m.b, m.kind), None),
    lambda _, ch: LatencyModel(*ch),
)


def _basis(n: Array, kind: Array) -> Array:
    n = n.astype(jnp.float32)
    return jnp.where(kind == LINEAR, n, n * jnp.log2(n + 1.0))


def _lstsq_1d(x: Array, y: Array, w: Array) -> tuple[Array, Array]:
    """Weighted least squares for y = a·x + b (closed form, jit-safe)."""
    sw = jnp.maximum(w.sum(), 1e-30)
    mx = (w * x).sum() / sw
    my = (w * y).sum() / sw
    cov = (w * (x - mx) * (y - my)).sum()
    var = jnp.maximum((w * (x - mx) ** 2).sum(), 1e-30)
    a = cov / var
    b = my - a * mx
    return a, b


@jax.jit
def fit_latency_model(n_pm: Array, latency: Array,
                      valid: Array | None = None) -> LatencyModel:
    """Fit both candidate regressions, keep the lower-SSE one (paper §III-E)."""
    w = jnp.ones_like(latency) if valid is None else valid.astype(jnp.float32)

    def fit(kind):
        x = _basis(n_pm, jnp.int32(kind))
        a, b = _lstsq_1d(x, latency, w)
        a = jnp.maximum(a, 1e-12)  # latency must increase with n_pm
        sse = (w * (a * x + b - latency) ** 2).sum()
        return a, b, sse

    a0, b0, e0 = fit(LINEAR)
    a1, b1, e1 = fit(NLOGN)
    pick_lin = e0 <= e1
    return LatencyModel(
        a=jnp.where(pick_lin, a0, a1),
        b=jnp.where(pick_lin, b0, b1),
        kind=jnp.where(pick_lin, LINEAR, NLOGN).astype(jnp.int32),
    )


def predict_latency(model: LatencyModel, n_pm: Array) -> Array:
    return model.a * _basis(jnp.asarray(n_pm), model.kind) + model.b


def invert_latency(model: LatencyModel, l_target: Array) -> Array:
    """n'_pm = f^{-1}(l'_p)  (Alg. 1 line 7).

    Linear: n = (l-b)/a.  For n·log2(n+1): Newton iterations (monotone,
    convex — converges in a handful of steps; fixed 16 for jit).
    """
    t = jnp.maximum((l_target - model.b) / model.a, 0.0)

    def newton(n, _):
        fn = n * jnp.log2(n + 1.0) - t
        dfn = jnp.log2(n + 1.0) + n / ((n + 1.0) * jnp.log(2.0))
        n = jnp.clip(n - fn / jnp.maximum(dfn, 1e-9), 0.0, 1e12)
        return n, None

    n_nlogn, _ = jax.lax.scan(newton, jnp.maximum(t, 1.0), None, length=16)
    return jnp.where(model.kind == LINEAR, t, n_nlogn)


def invert_latency_lazy(model: LatencyModel, l_target: Array) -> Array:
    """``invert_latency`` with the Newton iteration under a ``lax.cond``:
    bit-identical results (each branch is the exact expression the
    ``jnp.where`` in ``invert_latency`` selects), but a LINEAR model pays
    only the closed-form inverse at runtime.  The per-event Algorithm-1
    check inside the block-step kernel (kernels/block_step.py) uses this
    — under vmap (tenant lanes) the cond lowers back to a select, which
    is exactly ``invert_latency``'s cost."""
    t = jnp.maximum((l_target - model.b) / model.a, 0.0)

    def newton_path(t):
        def newton(n, _):
            fn = n * jnp.log2(n + 1.0) - t
            dfn = jnp.log2(n + 1.0) + n / ((n + 1.0) * jnp.log(2.0))
            n = jnp.clip(n - fn / jnp.maximum(dfn, 1e-9), 0.0, 1e12)
            return n, None

        n, _ = jax.lax.scan(newton, jnp.maximum(t, 1.0), None, length=16)
        return n

    return jax.lax.cond(model.kind == LINEAR, lambda t: t, newton_path, t)


@dataclasses.dataclass
class OverloadDecision:
    shed: Array   # bool — does l_e + l_s (+ b_s) exceed LB?
    rho: Array    # int32 — number of PMs to drop (0 if not shedding)
    l_e: Array    # estimated event latency (for telemetry / Fig. 7)


jax.tree_util.register_pytree_node(
    OverloadDecision,
    lambda d: ((d.shed, d.rho, d.l_e), None),
    lambda _, ch: OverloadDecision(*ch),
)


def detect_overload(f_model: LatencyModel, g_model: LatencyModel,
                    l_q: Array, n_pm: Array, latency_bound: float,
                    safety_buffer: float = 0.0,
                    lazy: bool = False) -> OverloadDecision:
    """Algorithm 1: decide whether to shed and how many PMs to drop.

    l'_p = LB - l_q - l_s;  n'_pm = f^{-1}(l'_p);  rho = n_pm - n'_pm.
    ``lazy`` routes the inversion through ``invert_latency_lazy`` — the
    same bits, but the Newton path only executes for NLOGN models (the
    block-step kernel runs this check once per event, in-loop).
    """
    n_pm_f = n_pm.astype(jnp.float32)
    l_p = predict_latency(f_model, n_pm_f)
    l_s = predict_latency(g_model, n_pm_f)
    l_e = l_q + l_p
    shed = l_e + l_s + safety_buffer > latency_bound
    l_p_new = jnp.maximum(latency_bound - l_q - l_s - safety_buffer, 0.0)
    invert = invert_latency_lazy if lazy else invert_latency
    # +eps guards float32 round-down at exact solutions (n' must not be
    # under-counted by one — that would over-shed every call).
    n_keep = jnp.floor(invert(f_model, l_p_new) + 1e-4).astype(jnp.int32)
    rho = jnp.where(shed, jnp.maximum(n_pm - n_keep, 0), 0).astype(jnp.int32)
    return OverloadDecision(shed=shed, rho=rho, l_e=l_e)
