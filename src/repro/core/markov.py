"""Markov-chain / Markov-reward-process machinery for pSPICE (paper §III-C).

The pattern-matching state machine of a query q is modeled as a Markov chain
over states S_q = {s_1 .. s_m} (s_1 = initial, s_m = final/absorbing).  The
transition matrix T_q is estimated online from ``Observation<q, s, s', t>``
tuples emitted by the CEP operator; t is the measured processing time of that
transition and becomes the reward of a Markov reward process (MRP).

Everything here is pure JAX so model (re)building can run jitted on-device —
the paper's "model builder" component (§III-A).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

Array = jax.Array


# ---------------------------------------------------------------------------
# Observation accumulation (statistic gathering, §III-C-1)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TransitionStats:
    """Scatter-add accumulator for transition counts and reward sums.

    counts[s, s']      — number of observed s -> s' transitions
    reward_sum[s, s']  — summed processing time of those transitions
    """
    counts: Array      # (m, m) float32
    reward_sum: Array  # (m, m) float32

    @staticmethod
    def zeros(m: int) -> "TransitionStats":
        return TransitionStats(
            counts=jnp.zeros((m, m), jnp.float32),
            reward_sum=jnp.zeros((m, m), jnp.float32),
        )

    @property
    def num_states(self) -> int:
        return self.counts.shape[0]

    @property
    def num_observations(self) -> Array:
        return self.counts.sum()


jax.tree_util.register_pytree_node(
    TransitionStats,
    lambda ts: ((ts.counts, ts.reward_sum), None),
    lambda _, ch: TransitionStats(*ch),
)


@jax.jit
def add_observations(stats: TransitionStats, s: Array, s_next: Array,
                     t: Array, valid: Array) -> TransitionStats:
    """Batched scatter-add of observations <s, s', t> (masked by ``valid``).

    s, s_next: int32 (n,) state indices; t: float32 (n,) processing times.
    """
    w = valid.astype(jnp.float32)
    counts = stats.counts.at[s, s_next].add(w)
    rsum = stats.reward_sum.at[s, s_next].add(w * t)
    return TransitionStats(counts, rsum)


# ---------------------------------------------------------------------------
# Transition matrix & reward function (§III-C-1/2)
# ---------------------------------------------------------------------------

def estimate_transition_matrix(stats: TransitionStats,
                               absorbing_final: bool = True,
                               laplace: float = 0.0) -> Array:
    """Row-normalized transition matrix T[s, s'] from counts.

    Rows with zero observations become self-loops (the chain stays put — the
    conservative prior for an unseen state).  The final state is absorbing:
    once a PM completes, it stays completed (paper Fig. 4's last row).
    """
    m = stats.num_states
    c = stats.counts + laplace
    row = c.sum(axis=1, keepdims=True)
    T = jnp.where(row > 0, c / jnp.maximum(row, 1e-30), jnp.eye(m))
    if absorbing_final:
        T = T.at[m - 1].set(jax.nn.one_hot(m - 1, m))
    return T


def estimate_reward_matrix(stats: TransitionStats,
                           default_reward: float = 0.0) -> Array:
    """R[s, s'] = mean observed processing time of an s -> s' transition."""
    c = stats.counts
    return jnp.where(c > 0, stats.reward_sum / jnp.maximum(c, 1e-30),
                     default_reward)


# ---------------------------------------------------------------------------
# Completion probability  P_pm = T^{R_w}(i, m)   (paper Eq. 3)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_bins", "bin_size"))
def binned_matrix_powers(T: Array, num_bins: int, bin_size: int) -> Array:
    """Return stacked powers  [T^{bs}, T^{2·bs}, ..., T^{num_bins·bs}].

    The paper computes T^{R_w} only at every ``bs`` events to bound memory
    (§III-C-1) and interpolates between bins.  Computed as a scan of m×m
    matmuls (MXU-friendly).
    """
    T_bs = _matrix_power(T, bin_size)

    def step(acc, _):
        acc = acc @ T_bs
        return acc, acc

    eye = jnp.eye(T.shape[0], dtype=T.dtype)
    _, powers = jax.lax.scan(step, eye, None, length=num_bins)
    return powers  # (num_bins, m, m)


def _matrix_power(T: Array, k: int) -> Array:
    """T^k by binary exponentiation (k is a static Python int)."""
    result = jnp.eye(T.shape[0], dtype=T.dtype)
    base = T
    while k > 0:
        if k & 1:
            result = result @ base
        base = base @ base
        k >>= 1
    return result


def completion_probability_table(T: Array, num_bins: int,
                                 bin_size: int) -> Array:
    """P[j, i] = prob. a PM in state s_i completes given (j+1)·bs events left.

    The last column of T^{R_w} (paper Fig. 4's red box).
    """
    powers = binned_matrix_powers(T, num_bins, bin_size)
    return powers[:, :, -1]  # (num_bins, m)


# ---------------------------------------------------------------------------
# Remaining processing time via MRP value iteration  (§III-C-2)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("num_bins", "bin_size"))
def remaining_time_table(T: Array, R: Array, num_bins: int,
                         bin_size: int) -> Array:
    """tau[j, i] = expected remaining processing time of a PM in state s_i
    given (j+1)·bs events remain in its window.

    Bellman backup (value iteration, Howard'71):
        tau_{k}(s) = sum_{s'} T[s,s'] · (R[s,s'] + tau_{k-1}(s'))
    with the final state absorbing at zero cost (a completed PM consumes no
    further processing).  Iteration index k == events remaining R_w; we keep
    every bin_size-th iterate (paper keeps results per bin, interpolates).
    """
    m = T.shape[0]
    # Expected one-step reward per state: r(s) = sum_s' T[s,s']·R[s,s'].
    r = (T * R).sum(axis=1).at[m - 1].set(0.0)
    T_nofinal = T.at[m - 1].set(0.0)  # absorbing final contributes 0 onward

    def one_event(tau, _):
        tau = r + T_nofinal @ tau
        return tau, None

    def one_bin(tau, _):
        tau, _ = jax.lax.scan(one_event, tau, None, length=bin_size)
        return tau, tau

    tau0 = jnp.zeros((m,), T.dtype)
    _, taus = jax.lax.scan(one_bin, tau0, None, length=num_bins)
    return taus  # (num_bins, m)


# ---------------------------------------------------------------------------
# Drift detection for retraining (§III-D)
# ---------------------------------------------------------------------------

def transition_matrix_mse(T_model: Array, T_fresh: Array) -> Array:
    """Mean squared error between the deployed and freshly-estimated matrix."""
    return jnp.mean((T_model - T_fresh) ** 2)


def needs_retraining(T_model: Array, T_fresh: Array,
                     threshold: float = 1e-3) -> Array:
    return transition_matrix_mse(T_model, T_fresh) > threshold


# ---------------------------------------------------------------------------
# NumPy reference oracles (used by tests)
# ---------------------------------------------------------------------------

def np_completion_probability(T: np.ndarray, R_w: int) -> np.ndarray:
    """Oracle: last column of T^R_w."""
    return np.linalg.matrix_power(np.asarray(T, np.float64), R_w)[:, -1]


def np_remaining_time(T: np.ndarray, R: np.ndarray, R_w: int) -> np.ndarray:
    """Oracle: naive value iteration in float64."""
    T = np.asarray(T, np.float64).copy()
    R = np.asarray(R, np.float64)
    m = T.shape[0]
    r = (T * R).sum(axis=1)
    r[m - 1] = 0.0
    Tn = T.copy()
    Tn[m - 1] = 0.0
    tau = np.zeros(m)
    for _ in range(R_w):
        tau = r + Tn @ tau
    return tau
