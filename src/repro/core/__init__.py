"""pSPICE core: Markov model builder, utility tables, overload detection,
load shedders (paper §III)."""
from repro.core import markov, overload, shedder, utility  # noqa: F401
