"""Utility tables for partial matches (paper §III-B, §III-C-3).

U_pm = w_q · P_pm / tau_pm  (Eq. 1), with P and tau min-max scaled to a common
range first (§III-C-3: "we bring the completion probabilities and processing
times to the same scale").  Materialized as UT_q[(ws/bs) × m] so the load
shedder does O(1) lookups (paper: "Getting the utility of a PM from UT has
only O(1) time complexity").
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core import markov

Array = jax.Array

_EPS = 1e-6


def _minmax_scale(x: Array, lo: float = _EPS, hi: float = 1.0) -> Array:
    """Scale x into [lo, hi].  Degenerate (constant) tables map to hi."""
    xmin, xmax = x.min(), x.max()
    span = xmax - xmin
    scaled = jnp.where(span > 0, (x - xmin) / jnp.maximum(span, 1e-30), 1.0)
    return lo + scaled * (hi - lo)


@dataclasses.dataclass
class UtilityTable:
    """Per-pattern utility table UT_q plus the tables it was derived from.

    table[j, i] = utility of a PM of this pattern in state s_i with
    (j+1)·bin_size events remaining in its window.  Index j = ceil(R_w/bs)-1;
    intermediate R_w values use linear interpolation (§III-C-1).
    """
    table: Array        # (num_bins, m)
    completion: Array   # (num_bins, m)   raw P
    remaining: Array    # (num_bins, m)   raw tau
    bin_size: int
    weight: float

    @property
    def num_bins(self) -> int:
        return self.table.shape[0]

    @property
    def num_states(self) -> int:
        return self.table.shape[1]


jax.tree_util.register_pytree_node(
    UtilityTable,
    lambda ut: ((ut.table, ut.completion, ut.remaining),
                (ut.bin_size, ut.weight)),
    lambda aux, ch: UtilityTable(*ch, bin_size=aux[0], weight=aux[1]),
)


def build_utility_table(T: Array, R: Array, window_size: int, bin_size: int,
                        weight: float = 1.0,
                        use_remaining_time: bool = True) -> UtilityTable:
    """Build UT_q from a learned transition matrix + reward matrix.

    use_remaining_time=False gives the paper's pSPICE-- ablation (denominator
    of Eq. 1 fixed to 1).
    """
    num_bins = max(1, -(-window_size // bin_size))  # ceil
    P = markov.completion_probability_table(T, num_bins, bin_size)
    tau = markov.remaining_time_table(T, R, num_bins, bin_size)
    P_s = _minmax_scale(P)
    tau_s = _minmax_scale(tau) if use_remaining_time else jnp.ones_like(tau)
    table = weight * P_s / jnp.maximum(tau_s, _EPS)
    return UtilityTable(table=table, completion=P, remaining=tau,
                        bin_size=bin_size, weight=weight)


def lookup_utility(ut_table: Array, bin_size: int, state: Array,
                   r_w: Array) -> Array:
    """Vectorized O(1) utility lookup with linear interpolation between bins.

    state: (n,) int32 current states; r_w: (n,) int32/float events remaining.
    Returns (n,) float32 utilities.  R_w in [(j-1)·bs, j·bs] interpolates
    between bins j-1 and j (paper §III-C-1).
    """
    num_bins = ut_table.shape[0]
    pos = jnp.clip(r_w.astype(jnp.float32) / bin_size - 1.0, 0.0,
                   num_bins - 1.0)
    j0 = jnp.floor(pos).astype(jnp.int32)
    j1 = jnp.minimum(j0 + 1, num_bins - 1)
    frac = pos - j0.astype(jnp.float32)
    u0 = ut_table[j0, state]
    u1 = ut_table[j1, state]
    return u0 * (1.0 - frac) + u1 * frac


def stack_tables(tables: Sequence[UtilityTable],
                 max_states: int | None = None) -> tuple[Array, Array]:
    """Stack per-pattern tables into one (n_patterns, num_bins, max_m) array
    (padded with -inf so padded states are never preferred for KEEPING — they
    can't occur) + bin sizes.  Lets a multi-query operator look up utilities
    for PMs of any pattern with one gather.
    """
    if max_states is None:
        max_states = max(t.num_states for t in tables)
    num_bins = max(t.num_bins for t in tables)
    out = []
    for t in tables:
        tab = t.table
        tab = jnp.pad(tab, ((0, num_bins - t.num_bins),
                            (0, max_states - t.num_states)),
                      constant_values=0.0)
        out.append(tab)
    bins = jnp.array([t.bin_size for t in tables], jnp.int32)
    return jnp.stack(out), bins


def multi_pattern_lookup(stacked: Array, bin_sizes: Array, pattern_id: Array,
                         state: Array, r_w: Array) -> Array:
    """Utility lookup across patterns: stacked (P, B, M), all args (n,)."""
    num_bins = stacked.shape[1]
    bs = bin_sizes[pattern_id].astype(jnp.float32)
    pos = jnp.clip(r_w.astype(jnp.float32) / bs - 1.0, 0.0, num_bins - 1.0)
    j0 = jnp.floor(pos).astype(jnp.int32)
    j1 = jnp.minimum(j0 + 1, num_bins - 1)
    frac = pos - j0.astype(jnp.float32)
    u0 = stacked[pattern_id, j0, state]
    u1 = stacked[pattern_id, j1, state]
    return u0 * (1.0 - frac) + u1 * frac
