"""The streaming runtime: chunk lifecycle orchestration (DESIGN.md §7).

``StreamRuntime`` (one tenant) and ``MultiTenantRuntime`` (L vmapped tenant
lanes) drive the engine chunk-by-chunk over unbounded streams:

    push(events) ─→ ChunkBuffer ─→ [run_engine_chunk / run_chunk_lanes]
         ▲                              │ donated carry, traced start
         │ host-side control            ▼
         └── telemetry ◄── refresh? ◄── counters

Between chunks the host reads telemetry, and — on the refresh cadence —
re-estimates the Markov/utility model and the latency regression from the
carry's accumulated observations (``repro.runtime.refresh``), so the
shedder tracks drifting stream statistics.  The carry is donated into
every chunk, so steady-state memory is constant regardless of how long
the stream runs.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Sequence

import jax

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.runtime import chunker, lanes as LN, refresh as RF, telemetry as TM


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    chunk_size: int = 1024
    refresh: RF.RefreshConfig | None = None


class StreamRuntime:
    """Single-tenant chunked runtime over one event stream.

    ``push`` ingests any number of events (the tail shorter than a chunk
    stays buffered); ``flush`` drains the remainder.  Chunked execution is
    bitwise-identical to one monolithic ``run_engine`` scan of the same
    events — chunking changes memory behavior and control cadence, never
    results.
    """

    def __init__(self, cfg: eng.EngineConfig, model: eng.EngineModel,
                 rt: RuntimeConfig | None = None,
                 specs: Sequence[pat.PatternSpec] | None = None,
                 carry: eng.Carry | None = None, seed: int = 0):
        self.cfg = cfg
        self.model = model
        self.rt = rt or RuntimeConfig()
        self.specs = list(specs) if specs is not None else None
        if self._refresh_on() and not cfg.gather_stats:
            raise ValueError("model refresh needs cfg.gather_stats=True "
                             "(the carry must accumulate observations)")
        if self._refresh_on() and self.specs is None:
            raise ValueError("model refresh needs the PatternSpec list")
        if self._refresh_on():
            # Refresh must never change array shapes mid-stream (that
            # would retrace the chunk executable): widen the utility
            # tables to refresh width up front.
            self.model = RF.prepare_model(self.specs, self.model,
                                          self.rt.refresh)
        self.carry = carry if carry is not None else eng.init_carry(
            cfg, seed=seed)
        self.telemetry = TM.TelemetryLog()
        self.refresh_state = RF.RefreshState()
        self._buf = chunker.ChunkBuffer(self.rt.chunk_size)
        self._chunk_i = 0
        self.events_processed = 0
        self._snapshot: dict[str, float] | None = None

    # -- chunk execution (overridden by the lane runtime) -------------------
    def _run(self, chunk: eng.EventBatch, start: int):
        return eng.run_engine_chunk(self.cfg, self.model, chunk, self.carry,
                                    eng.wrap_event_index(start))

    def _refresh_on(self) -> bool:
        r = self.rt.refresh
        return r is not None and r.every_chunks > 0

    def _maybe_refresh(self) -> bool:
        if not self._refresh_on() \
           or self._chunk_i % self.rt.refresh.every_chunks != 0:
            return False
        self.model, self.carry, did = RF.refresh_model(
            self.specs, self.cfg, self.model, self.carry, self.rt.refresh,
            self.refresh_state)
        return did

    # -- ingestion ----------------------------------------------------------
    def push(self, events: eng.EventBatch,
             flush: bool = False) -> list[TM.ChunkStats]:
        """Ingest events; run every full chunk now available.  With
        ``flush`` the sub-chunk remainder runs too (end of stream)."""
        pieces = self._buf.push(events)
        if flush:
            pieces += self._buf.drain()
        return [self._run_piece(start, chunk) for start, chunk in pieces]

    def flush(self) -> list[TM.ChunkStats]:
        """Drain the buffered remainder as one final short chunk."""
        return [self._run_piece(start, chunk)
                for start, chunk in self._buf.drain()]

    def _run_piece(self, start: int, chunk: eng.EventBatch) -> TM.ChunkStats:
        # The previous chunk's snapshot doubles as this chunk's baseline
        # (refresh never touches the counters), halving per-chunk
        # device→host transfers.
        before = self._snapshot or TM.counter_snapshot(self.carry)
        t0 = time.perf_counter()
        self.carry, outs = self._run(chunk, start)
        jax.block_until_ready(self.carry.sim_time)
        wall = time.perf_counter() - t0
        self._chunk_i += 1
        t1 = time.perf_counter()
        refreshed = self._maybe_refresh()
        refresh_wall = time.perf_counter() - t1
        self._snapshot = TM.counter_snapshot(self.carry)
        stats = TM.summarize_chunk(
            self._chunk_i - 1, start, outs, before, self._snapshot, wall,
            refreshed=refreshed, refresh_wall_s=refresh_wall)
        self.telemetry.append(stats)
        self.events_processed += stats.n_events
        return stats


class MultiTenantRuntime(StreamRuntime):
    """L independent tenant lanes, vmapped per chunk (repro.runtime.lanes).

    Events are pushed lane-stacked — every ``EventBatch`` leaf carries a
    leading ``(L,)`` axis (``lanes.stack``) — and lanes advance in lockstep
    over aligned chunk windows.  Models may be shared
    (``lanes.broadcast_model``) or per-lane; refresh runs PER LANE from
    each lane's own carry, so tenants adapt to their own stream's drift.
    On a multi-device mesh, pass ``mesh`` to spread lanes × patterns via
    ``repro.dist.sharding.run_chunk_lanes_sharded``.
    """

    def __init__(self, cfg: eng.EngineConfig, model: eng.EngineModel,
                 num_lanes: int, rt: RuntimeConfig | None = None,
                 specs: Sequence[pat.PatternSpec] | None = None,
                 carry: eng.Carry | None = None, seed: int = 0, mesh=None):
        self.num_lanes = num_lanes
        self.mesh = mesh
        if carry is None:
            carry = LN.init_lane_carries(cfg, num_lanes, seed=seed)
        super().__init__(cfg, model, rt=rt, specs=specs, carry=carry,
                         seed=seed)
        # chunk over the EVENT axis (axis 1 of lane-stacked leaves)
        self._buf = chunker.ChunkBuffer(self.rt.chunk_size, axis=1)
        self.refresh_state = [RF.RefreshState() for _ in range(num_lanes)]

    def _run(self, chunk: eng.EventBatch, start: int):
        start_i = eng.wrap_event_index(start)
        if self.mesh is not None:
            from repro.dist import sharding as SH
            return SH.run_chunk_lanes_sharded(
                self.cfg, self.model, chunk, self.carry, start_i,
                mesh=self.mesh)
        return LN.run_chunk_lanes(self.cfg, self.model, chunk, self.carry,
                                  start_i)

    def _maybe_refresh(self) -> bool:
        if not self._refresh_on() \
           or self._chunk_i % self.rt.refresh.every_chunks != 0:
            return False
        models, carries, did = [], [], False
        for lane in range(self.num_lanes):
            m, c, d = RF.refresh_model(
                self.specs, self.cfg, LN.unstack_lane(self.model, lane),
                LN.unstack_lane(self.carry, lane), self.rt.refresh,
                self.refresh_state[lane])
            models.append(m)
            carries.append(c)
            did |= d
        if did:
            self.model = LN.stack(models)
            self.carry = LN.stack(carries)
        return did

    def merged_carry(self) -> eng.Carry:
        """All lanes folded into one L·P-pattern carry (engine.merge_carries)
        — the global view telemetry and reporting aggregate over."""
        return eng.merge_carries(self.carry)
