"""The streaming runtime: chunk lifecycle orchestration (DESIGN.md §7).

``StreamRuntime`` (one tenant) and ``MultiTenantRuntime`` (L vmapped tenant
lanes) drive the engine chunk-by-chunk over unbounded streams:

    push(events) ─→ ChunkBuffer ─→ [run_engine_chunk / run_chunk_lanes]
         ▲                              │ donated carry, traced start
         │ host-side control            ▼
         └── telemetry ◄── refresh? ◄── counters

Between chunks the host reads telemetry, and — on the refresh cadence —
re-estimates the Markov/utility model and the latency regression from the
carry's accumulated observations (``repro.runtime.refresh``), so the
shedder tracks drifting stream statistics.  The carry is donated into
every chunk, so steady-state memory is constant regardless of how long
the stream runs.
"""
from __future__ import annotations

import dataclasses
import functools
import os
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as ctr
from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.runtime import chunker, faults as FT, guard as GD, \
    ingest as IG, lanes as LN, persist as PS, refresh as RF, \
    telemetry as TM

# Degradation-ladder rungs (DESIGN.md §12), least to most drastic.  Rung 1
# is the paper's own mechanism (pSPICE PM shedding, always armed) made
# MORE aggressive: a standing between-chunk PM trim on top of the in-scan
# Algorithm-1/2 path.  Rung 2 adds eSPICE-style input-level shedding at
# admission; rung 3 stops ingesting entirely.
RUNG_NORMAL, RUNG_PM_TRIM, RUNG_INPUT_SHED, RUNG_QUARANTINE = 0, 1, 2, 3
RUNG_NAMES = ("normal", "pm_trim", "input_shed", "quarantine")


@dataclasses.dataclass(frozen=True)
class LadderConfig:
    """Degradation-ladder state machine knobs (DESIGN.md §12)."""
    escalate_streak: int = 3     # consecutive violating chunks to go up
    deescalate_streak: int = 8   # consecutive clean chunks to come down
    trim_frac: float = 0.25      # active-PM fraction trimmed per chunk @ r1+
    input_shed_frac: float = 0.5  # forced admission drop probability @ r2+
    max_rung: int = RUNG_QUARANTINE
    latency_bound: float | None = None   # default: cfg.latency_bound

    def __post_init__(self):
        if self.escalate_streak < 1 or self.deescalate_streak < 1:
            raise ValueError(
                "ladder streaks must be >= 1 chunk: escalate_streak="
                f"{self.escalate_streak}, deescalate_streak="
                f"{self.deescalate_streak}")
        for name in ("trim_frac", "input_shed_frac"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"ladder.{name} is a drop ratio and must "
                                 f"be in [0, 1]: {v}")
        if not RUNG_NORMAL <= self.max_rung <= RUNG_QUARANTINE:
            raise ValueError("ladder.max_rung must be one of "
                             f"{list(range(len(RUNG_NAMES)))} "
                             f"({'/'.join(RUNG_NAMES)}): {self.max_rung}")
        if self.latency_bound is not None and not self.latency_bound > 0:
            raise ValueError("ladder.latency_bound must be > 0 seconds "
                             f"(or None to use the engine's): "
                             f"{self.latency_bound}")

    def rung_needs_ingest(self) -> bool:
        """Rungs 2+ act at ADMISSION (forced input shedding) — they are
        unreachable without an ingest front-end to carry them out."""
        return self.max_rung >= RUNG_INPUT_SHED


class DegradationLadder:
    """Hysteresis state machine over latency-bound violation streaks.

    ``observe`` is called once per completed chunk with its violation
    verdict; ``escalate_streak`` consecutive violations move one rung up,
    ``deescalate_streak`` consecutive clean chunks one rung down — streak
    counters reset on every transition, so each move needs a FULL fresh
    streak.  While quarantined no chunks run, so ``quarantine_tick``
    (called per rejected push) provides the de-escalation clock instead —
    quarantine can never be a terminal state.
    """

    def __init__(self, cfg: LadderConfig):
        self.cfg = cfg
        self.rung = RUNG_NORMAL
        self._bad = 0
        self._good = 0
        self._q_ticks = 0
        self.transitions: list[dict] = []

    def _move(self, new_rung: int, chunk_index: int, why: str) -> dict:
        tr = {"from": self.rung, "to": new_rung,
              "from_name": RUNG_NAMES[self.rung],
              "to_name": RUNG_NAMES[new_rung],
              "why": why, "chunk": chunk_index}
        self.rung = new_rung
        self._bad = self._good = self._q_ticks = 0
        self.transitions.append(tr)
        return tr

    def observe(self, violated: bool, chunk_index: int) -> dict | None:
        if violated:
            self._bad += 1
            self._good = 0
            if self._bad >= self.cfg.escalate_streak \
                    and self.rung < self.cfg.max_rung:
                return self._move(self.rung + 1, chunk_index, "escalate")
        else:
            self._good += 1
            self._bad = 0
            if self._good >= self.cfg.deescalate_streak \
                    and self.rung > RUNG_NORMAL:
                return self._move(self.rung - 1, chunk_index, "deescalate")
        return None

    def quarantine_tick(self, chunk_index: int) -> dict | None:
        """De-escalation clock while no chunks flow (rung 3)."""
        self._q_ticks += 1
        if self._q_ticks >= self.cfg.deescalate_streak \
                and self.rung > RUNG_NORMAL:
            return self._move(self.rung - 1, chunk_index,
                              "quarantine_timeout")
        return None

    # -- durable state (repro.runtime.persist) -----------------------------
    def control_state(self) -> dict:
        """Rung + hysteresis streaks — what a checkpoint rewind restores.
        The ``transitions`` log is append-only forensics (mirrored into
        telemetry) and travels only with FULL snapshots, never with
        in-memory guard rewinds — rewinding one side of the mirror would
        break the ladder/telemetry count invariant CI gates on."""
        return {"rung": self.rung, "bad": self._bad, "good": self._good,
                "q_ticks": self._q_ticks}

    def restore_control_state(self, d: dict) -> None:
        self.rung = int(d["rung"])
        self._bad = int(d["bad"])
        self._good = int(d["good"])
        self._q_ticks = int(d["q_ticks"])


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    chunk_size: int = 1024
    refresh: RF.RefreshConfig | None = None
    # Macro-batching (DESIGN.md §8): up to this many consecutive full
    # chunks run in ONE device dispatch (a lax.scan over chunks with the
    # per-chunk telemetry vectors computed in-scan), amortizing per-chunk
    # slicing/dispatch/transfer costs.  Groups never cross a refresh
    # boundary, so the host keeps its control cadence.  None (the
    # default) sizes the group from the chunk size
    # (``chunker.suggested_group_chunks``: small chunks group until one
    # dispatch covers ~8k events); 1 disables grouping.
    group_chunks: int | None = None
    # Unroll factor for the outer chunk scan inside a grouped dispatch
    # (lax.scan ``unroll=``): >1 trades compile time for fewer loop-back
    # edges on very small chunks.  1 keeps the plain scan.
    scan_unroll: int = 1
    # Resilience layer (DESIGN.md §12) — all three default OFF, and off
    # means provably off: the runtime takes the exact pre-resilience code
    # path and results stay bitwise-identical (tests/test_resilience.py).
    ingest: IG.IngestConfig | None = None    # bounded admission front-end
    ladder: LadderConfig | None = None       # degradation state machine
    guard: GD.GuardConfig | None = None      # invariant checks + restore
    # Durable persistence (DESIGN.md §13): snapshot + write-ahead log
    # under one directory.  Like the resilience knobs, None means the
    # pre-persistence code path bit for bit.
    persist: PS.PersistConfig | None = None

    def __post_init__(self):
        if self.chunk_size < 1:
            raise ValueError("runtime chunk_size must be >= 1 event: "
                             f"{self.chunk_size}")
        if self.scan_unroll < 1:
            raise ValueError("runtime scan_unroll must be >= 1 (1 = plain "
                             f"lax.scan): {self.scan_unroll}")
        if self.group_chunks is not None and self.group_chunks < 1:
            raise ValueError(
                "runtime group_chunks must be >= 1 chunk per dispatch, or "
                f"None for the auto policy: {self.group_chunks}")
        if self.ladder is not None and self.ladder.rung_needs_ingest() \
                and self.ingest is None:
            raise ValueError(
                "ladder.max_rung >= RUNG_INPUT_SHED needs an ingest front-"
                "end to apply input shedding/quarantine — set rt.ingest "
                "(IngestConfig) or cap ladder.max_rung at RUNG_PM_TRIM")

    def effective_group_chunks(self) -> int:
        if self.group_chunks is None:
            return chunker.suggested_group_chunks(self.chunk_size)
        return max(1, self.group_chunks)


def _make_group_runner(scan_fn, chunk_axis: int):
    """A donating jit that runs B consecutive chunks in ONE dispatch:
    a lax.scan over the leading chunk axis whose body IS the engine's
    event scan, so results are bitwise-identical to B sequential chunk
    calls; per-chunk telemetry vectors are computed in-scan.  The two
    instances differ only in the engine scan and where the chunk size
    sits in the event leaves ((B, chunk, ...) vs (B, L, chunk, ...))."""

    @functools.partial(jax.jit, static_argnames=("cfg", "unroll"),
                       donate_argnames=("carry", "events"))
    def run(cfg: eng.EngineConfig, model: eng.EngineModel,
            events: eng.EventBatch, carry: eng.Carry, start: jax.Array,
            unroll: int = 1):
        lead = jax.tree.leaves(events)[0]
        b, cs = lead.shape[0], lead.shape[chunk_axis]
        starts = start + cs * jnp.arange(b, dtype=jnp.int32)

        def body(c, x):
            ev_b, s = x
            c, outs = scan_fn(cfg, model, ev_b, c, s)
            return c, TM.device_chunk_stats(outs, c)

        return jax.lax.scan(body, carry, (events, starts),
                            unroll=max(1, min(unroll, b)))

    return run


_run_group_single = ctr.contract(
    "runtime._run_group_single", donate=("carry", "events"),
    max_while=14, max_cond=24, max_compiles=2,
    max_temp_bytes=ctr.hot_path_temp_budget,
    max_gather_bytes=ctr.hot_path_gather_budget)(
        _make_group_runner(eng._scan_events_backend, chunk_axis=1))
_run_group_lanes = ctr.contract(
    "runtime._run_group_lanes", donate=("carry", "events"),
    max_while=14, max_cond=24, max_compiles=2)(
        _make_group_runner(eng._scan_events_lanes_backend, chunk_axis=2))


class StreamRuntime:
    """Single-tenant chunked runtime over one event stream.

    ``push`` ingests any number of events (the tail shorter than a chunk
    stays buffered); ``flush`` drains the remainder.  Chunked execution is
    bitwise-identical to one monolithic ``run_engine`` scan of the same
    events — chunking changes memory behavior and control cadence, never
    results.
    """

    def __init__(self, cfg: eng.EngineConfig, model: eng.EngineModel,
                 rt: RuntimeConfig | None = None,
                 specs: Sequence[pat.PatternSpec] | None = None,
                 carry: eng.Carry | None = None, seed: int = 0):
        self.cfg = cfg
        self.model = model
        self.rt = rt or RuntimeConfig()
        self.specs = list(specs) if specs is not None else None
        if self._refresh_on() and not cfg.gather_stats:
            raise ValueError("model refresh needs cfg.gather_stats=True "
                             "(the carry must accumulate observations)")
        if self._refresh_on() and self.specs is None:
            raise ValueError("model refresh needs the PatternSpec list")
        if self._refresh_on():
            # Refresh must never change array shapes mid-stream (that
            # would retrace the chunk executable): widen the utility
            # tables to refresh width up front.
            self.model = RF.prepare_model(self.specs, self.model,
                                          self.rt.refresh)
        self.carry = carry if carry is not None else eng.init_carry(
            cfg, seed=seed)
        self.telemetry = TM.TelemetryLog()
        self.refresh_state = RF.RefreshState()
        self._buf = chunker.ChunkBuffer(self.rt.chunk_size)
        self._chunk_i = 0
        self.events_processed = 0
        self._snapshot: dict[str, float] | None = None
        self._init_resilience()

    # -- resilience layer (DESIGN.md §12) -----------------------------------
    def _init_resilience(self) -> None:
        """Ingest queue / degradation ladder / carry guard, each present
        only when its config is — absent configs leave the pre-resilience
        code path (and its results) untouched bit for bit."""
        rt = self.rt
        self.ingest = self._make_ingest() if rt.ingest is not None else None
        self.ladder = DegradationLadder(rt.ladder) \
            if rt.ladder is not None else None
        self.guard = GD.CarryGuard(rt.guard, lanes=self._guard_lanes()) \
            if rt.guard is not None else None
        self._quarantined = False
        self._event_cursor = 0       # global index after the last chunk
        self.quarantine_dropped = 0  # events refused while quarantined
        self.persist = PS.Persistence(rt.persist) \
            if rt.persist is not None else None
        self._last_snap_chunk = 0
        self._replaying = False         # True while re-pushing WAL records
        self._replay_cursor: int | None = None  # next unabsorbed record id
        if self.guard is not None:
            self.guard.save(self.carry, self.model, chunk_i=0,
                            control=self._control_state(scope="guard"))

    def _make_ingest(self):
        return IG.IngestQueue(self.rt.ingest)

    def _guard_lanes(self) -> int | None:
        return None

    def _record_admission(self, rep) -> None:
        for r in (rep if isinstance(rep, list) else [rep]):
            if r.shed or r.rejected or r.quarantined:
                self.telemetry.record_event(
                    "admission", self._chunk_i, dataclasses.asdict(r))

    @property
    def backpressure(self) -> bool:
        """True when the last offer hit the hard queue bound or left the
        queue above the high watermark — slow the producer."""
        if self.ingest is None:
            return False
        reps = self.ingest.reports
        return bool(reps and reps[-1].backpressure)

    def _apply_ladder(self, tr: dict | None) -> None:
        """Record a ladder transition and apply its standing effects."""
        if tr is None:
            return
        self.telemetry.record_event("ladder", tr["chunk"], tr)
        rung = self.ladder.rung
        if self.ingest is not None:
            self.ingest.forced_drop = self.rt.ladder.input_shed_frac \
                if rung >= RUNG_INPUT_SHED else 0.0
        self._quarantined = rung >= RUNG_QUARANTINE

    def _trim(self, frac: float) -> None:
        i = eng.wrap_event_index(self._event_cursor)
        self.carry = self._trim_call(i, jnp.float32(frac))
        # Trim bumps pms_shed/shed_calls through the engine's own shed
        # path; the stale counter snapshot folds them into the NEXT
        # chunk's deltas, so aggregate telemetry stays complete.

    def _trim_call(self, i, frac):
        return GD.trim_store(self.cfg, self.model, self.carry, i, frac)

    def _after_chunk(self, out: list[TM.ChunkStats]) -> None:
        """Ladder observation + guard check at the chunk-group boundary
        (the host's control cadence — same place refresh runs)."""
        if self.ladder is not None:
            bound = self.rt.ladder.latency_bound \
                if self.rt.ladder.latency_bound is not None \
                else self.cfg.latency_bound
            for s in out:
                self._apply_ladder(
                    self.ladder.observe(s.l_e_p99 > bound, s.chunk_index))
                s.rung = self.ladder.rung
            if self.ladder.rung >= RUNG_PM_TRIM and not self._quarantined:
                self._trim(self.rt.ladder.trim_frac)
        if self.guard is not None:
            self._guard_tick()

    def _guard_tick(self) -> None:
        gcfg = self.rt.guard
        if self._chunk_i % gcfg.check_every_chunks != 0:
            return
        viols = self.guard.check(self.carry, self.model)
        if viols:
            for v in viols:
                self.telemetry.record_event("guard_violation",
                                            self._chunk_i, v.to_row())
            if gcfg.restore_on_violation and self.guard.has_checkpoint:
                self._guard_restore(viols)
        elif self._chunk_i % gcfg.checkpoint_every_chunks == 0:
            # Check-then-save: a poisoned state is never checkpointed.
            self.guard.save(self.carry, self.model, self._chunk_i,
                            control=self._control_state(scope="guard"))

    def _guard_restore(self, viols: list[GD.GuardViolation]) -> None:
        self.carry, self.model = self.guard.restore(self.carry, self.model)
        # Restore REWINDS the carry counters — the cached snapshot is
        # stale; drop it so the next chunk re-baselines from the carry.
        self._snapshot = None
        # Rewind the control state captured WITH the checkpoint: ladder
        # rung/streaks, admission tokens/clock/latch/PRNG, quarantine
        # counters — otherwise a restore resumes the arrays at the
        # checkpoint but the controllers at their post-fault values.
        ctl = self.guard.checkpoint_control
        if ctl is not None:
            self._restore_control_state(ctl, scope="guard")
        self.telemetry.record_event("guard_restore", self._chunk_i, {
            "from_chunk": self.guard.checkpoint_chunk,
            "rung": None if self.ladder is None else self.ladder.rung,
            "lanes": sorted({v.lane for v in viols
                             if v.lane is not None}) or None})

    def guard_now(self) -> list[GD.GuardViolation]:
        """Run the invariant checks immediately (end-of-run sweep, tests,
        chaos harness); restores on violation per the guard config."""
        if self.guard is None:
            raise ValueError("guard_now needs rt.guard (GuardConfig)")
        viols = self.guard.check(self.carry, self.model)
        if viols:
            for v in viols:
                self.telemetry.record_event("guard_violation",
                                            self._chunk_i, v.to_row())
            if self.rt.guard.restore_on_violation \
                    and self.guard.has_checkpoint:
                self._guard_restore(viols)
        return viols

    # -- durable persistence (DESIGN.md §13) --------------------------------
    def _persist_extra(self) -> dict:
        """Subclass hook: JSON-able extras carried inside every durable
        snapshot (the supervisor's match accumulator rides here)."""
        return {}

    def _persist_restore_extra(self, extra: dict) -> None:
        """Subclass hook: inverse of ``_persist_extra``."""

    def _persist_queues(self) -> list:
        """(lane, IngestQueue) pairs whose queued events + control state
        the snapshot must carry; [] without an ingest front-end."""
        if self.ingest is None:
            return []
        queues = getattr(self.ingest, "queues", None)
        return list(enumerate(queues)) if queues is not None \
            else [(0, self.ingest)]

    def _control_state(self, scope: str = "full") -> dict:
        """Host-side control state in the snapshot codec's JSON form.

        ``scope="guard"`` keeps the subset an in-memory guard restore
        rewinds (ladder rung/streaks, admission control state, quarantine
        counters); ``scope="full"`` adds stream cursors, refresh state,
        telemetry and the forensic logs for the durable snapshot.
        """
        d: dict = {"quarantine_dropped": int(self.quarantine_dropped)}
        if self.ladder is not None:
            d["ladder"] = self.ladder.control_state()
        if self.ingest is not None:
            d["ingest"] = self.ingest.control_state()
        if scope != "full":
            return d
        d["chunk_i"] = int(self._chunk_i)
        d["event_cursor"] = int(self._event_cursor)
        d["events_processed"] = int(self.events_processed)
        d["counter_snapshot"] = self._snapshot
        d["buf_next_start"] = int(self._buf.next_start)
        d["telemetry"] = self.telemetry.to_json()
        d["extra"] = self._persist_extra()
        if self.ladder is not None:
            d["ladder"]["transitions"] = [dict(t) for t in
                                          self.ladder.transitions]
        states = self.refresh_state if isinstance(self.refresh_state, list) \
            else [self.refresh_state]
        d["refresh"] = [s.to_control() for s in states]
        if self.guard is not None:
            d["guard_counters"] = self.guard.counters()
        return d

    def _restore_control_state(self, d: dict, scope: str = "full") -> None:
        self.quarantine_dropped = int(d.get("quarantine_dropped", 0))
        if self.ladder is not None and "ladder" in d:
            self.ladder.restore_control_state(d["ladder"])
            if scope == "full" and "transitions" in d["ladder"]:
                self.ladder.transitions = [dict(t) for t in
                                           d["ladder"]["transitions"]]
            # Re-derive the restored rung's standing effects (what
            # _apply_ladder does on a transition).
            rung = self.ladder.rung
            if self.ingest is not None:
                self.ingest.forced_drop = self.rt.ladder.input_shed_frac \
                    if rung >= RUNG_INPUT_SHED else 0.0
            self._quarantined = rung >= RUNG_QUARANTINE
        if self.ingest is not None and "ingest" in d:
            self.ingest.restore_control_state(d["ingest"])
        if scope != "full":
            return
        self._chunk_i = int(d["chunk_i"])
        self._event_cursor = int(d["event_cursor"])
        self.events_processed = int(d["events_processed"])
        self._snapshot = d["counter_snapshot"]
        self.telemetry = TM.TelemetryLog.from_json(d["telemetry"])
        states = [RF.RefreshState.from_control(s) for s in d["refresh"]]
        if isinstance(self.refresh_state, list):
            self.refresh_state = states
        else:
            self.refresh_state = states[0]
        if self.guard is not None and "guard_counters" in d:
            self.guard.restore_counters(d["guard_counters"])
        self._persist_restore_extra(d.get("extra", {}))

    def _maybe_snapshot(self) -> bool:
        if self._chunk_i - self._last_snap_chunk \
                < self.rt.persist.snapshot_every_chunks:
            return False
        self.snapshot_now()
        return True

    def snapshot_now(self) -> str:
        """Write one durable snapshot generation (atomic + CRC, rotated;
        repro.runtime.persist).  Returns the file path."""
        if self.persist is None:
            raise ValueError("snapshot_now needs rt.persist "
                             "(PersistConfig)")
        control = self._control_state("full")
        # First WAL record NOT absorbed into this snapshot: during normal
        # operation every appended record has been pushed; during replay
        # the cursor tracks the record being re-pushed, so a snapshot cut
        # mid-recovery is itself a correct recovery point.
        control["wal_next_record"] = int(
            self._replay_cursor if self._replay_cursor is not None
            else self.persist.wal.next_record_id)
        sections: dict = {"carry": self.carry, "model": self.model,
                          "pending": self._buf.buffered()}
        for lane, q in self._persist_queues():
            sections[f"ingest_queue_{lane}"] = q.queued_events()
        if self.guard is not None and self.guard.has_checkpoint:
            ck_carry, ck_model, ck_chunk, ck_ctl = self.guard.checkpoint
            sections["guard_carry"] = ck_carry
            sections["guard_model"] = ck_model
            control["guard_ckpt"] = {"chunk": int(ck_chunk),
                                     "control": ck_ctl}
        path = self.persist.store.save(self._chunk_i, control, sections)
        self._last_snap_chunk = self._chunk_i
        return path

    def recover_from_disk(self) -> dict:
        """Restore the newest valid snapshot generation, then replay the
        WAL tail through the normal push path (DESIGN.md §13).

        Because admission, shedding, refresh and chunk grouping are all
        driven by event content and seeded PRNG chains — never wall
        clock — the recovered state is bitwise-identical to the
        uninterrupted run.  With an empty directory this is a no-op
        returning a zero report, so a fresh start and a recovery share
        one entry point.  Returns the recovery report (also embedded in
        the supervisor's final report).
        """
        if self.persist is None:
            raise ValueError("recover_from_disk needs rt.persist "
                             "(PersistConfig)")
        t0 = time.perf_counter()
        header, sections, meta = self.persist.store.load_latest()
        start_id, snap_chunk = 0, None
        if header is not None:
            self._apply_snapshot(header, sections)
            start_id = int(header["control"]["wal_next_record"])
            snap_chunk = int(header["chunk_index"])
        records = self.persist.wal.records_since(start_id)
        self._replaying = True
        try:
            for rid, ev in records:
                self._replay_cursor = rid + 1
                self._ingest_events(jax.tree.map(jnp.asarray, ev))
                self._maybe_snapshot()
        finally:
            self._replaying = False
            self._replay_cursor = None
        return {
            "snapshot_chunk": snap_chunk,
            "snapshot_path": None if meta["path"] is None
            else os.path.basename(meta["path"]),
            "rejected_snapshots": meta["rejected"],
            "wal_start_record": int(start_id),
            "replayed_records": len(records),
            "recovery_wall_s": time.perf_counter() - t0,
        }

    def _apply_snapshot(self, header: dict, sections: dict) -> None:
        to_dev = functools.partial(jax.tree.map, jnp.asarray)
        self.carry = to_dev(PS.decode_tree(*sections["carry"], self.carry,
                                           what="carry"))
        self.model = to_dev(PS.decode_tree(*sections["model"], self.model,
                                           what="model"))
        ctl = header["control"]
        tmpl = PS.event_template()
        pend = None
        if "pending" in sections:
            pend = to_dev(PS.decode_tree(*sections["pending"], tmpl,
                                         what="pending", strict=False))
        self._buf.restore(pend, ctl["buf_next_start"])
        for lane, q in self._persist_queues():
            key = f"ingest_queue_{lane}"
            batch = None
            if key in sections:
                batch = to_dev(PS.decode_tree(*sections[key], tmpl,
                                              what=key, strict=False))
            q.restore_queued(batch)
        self._restore_control_state(ctl, scope="full")
        self._last_snap_chunk = self._chunk_i
        if self.guard is not None:
            if "guard_ckpt" in ctl and "guard_carry" in sections:
                gc = PS.decode_tree(*sections["guard_carry"], self.carry,
                                    what="guard_carry")
                gm = PS.decode_tree(*sections["guard_model"], self.model,
                                    what="guard_model")
                self.guard.load_checkpoint(
                    jax.tree.map(np.array, gc), jax.tree.map(np.array, gm),
                    ctl["guard_ckpt"]["chunk"],
                    ctl["guard_ckpt"]["control"])
            else:
                self.guard.save(self.carry, self.model, self._chunk_i,
                                control=self._control_state(scope="guard"))

    # -- chunk execution (overridden by the lane runtime) -------------------
    def _run(self, chunk: eng.EventBatch, start: int):
        return eng.run_engine_chunk(self.cfg, self.model, chunk, self.carry,
                                    eng.wrap_event_index(start))

    def _refresh_on(self) -> bool:
        r = self.rt.refresh
        return r is not None and r.every_chunks > 0

    def _maybe_refresh(self) -> bool:
        if not self._refresh_on() \
           or self._chunk_i % self.rt.refresh.every_chunks != 0:
            return False
        FT.kill_point("refresh")
        self.model, self.carry, did = RF.refresh_model(
            self.specs, self.cfg, self.model, self.carry, self.rt.refresh,
            self.refresh_state)
        return did

    # -- ingestion ----------------------------------------------------------
    def push(self, events: eng.EventBatch,
             flush: bool = False) -> list[TM.ChunkStats]:
        """Ingest events; run every full chunk now available.  With
        ``flush`` the sub-chunk remainder runs too (end of stream).

        Consecutive full chunks run as macro-batched GROUPS — one device
        dispatch per up-to-``group_chunks`` chunks, never crossing a
        refresh boundary — with identical results and per-chunk stats to
        chunk-at-a-time execution (tests/test_runtime.py).

        With an ingest front-end (``rt.ingest``) events pass admission
        control first — the admitted subset queues, and up to
        ``pump_chunks`` chunks drain into execution per push.  While
        quarantined (ladder rung 3) pushes are refused outright.

        With ``rt.persist`` the batch is appended (and flushed) to the
        write-ahead log BEFORE any processing — admission included — so
        a crash mid-push replays the whole push through this same path
        and re-derives every decision (DESIGN.md §13)."""
        if self.persist is not None and not self._replaying:
            self.persist.wal.append(events)
        stats = self._ingest_events(events)
        if flush:
            stats += self.flush()
        if self.persist is not None and not self._replaying:
            self._maybe_snapshot()
        return stats

    def _ingest_events(self, events: eng.EventBatch) -> list[TM.ChunkStats]:
        if self._quarantined:
            self._quarantine_refuse(events)
            if self._quarantined:
                return []
            # the refusal ticked the ladder out of quarantine: fall
            # through and ingest this push normally
        if self.ingest is not None:
            self._record_admission(self.ingest.offer(events))
            return self._pump()
        start, region, n_chunks = self._buf.push_region(events)
        return self._run_region(start, region, n_chunks)

    def _quarantine_refuse(self, events: eng.EventBatch) -> None:
        n = chunker.num_events(events, self._buf.axis)
        self.quarantine_dropped += n
        if self.ladder is not None:
            self._apply_ladder(self.ladder.quarantine_tick(self._chunk_i))

    def _pump(self, drain: bool = False) -> list[TM.ChunkStats]:
        limit = self.rt.ingest.pump_chunks
        budget = None if limit <= 0 else limit * self.rt.chunk_size
        ev = self.ingest.take(budget, drain=drain)
        if ev is None:
            return []
        start, region, n_chunks = self._buf.push_region(ev)
        return self._run_region(start, region, n_chunks)

    def flush(self) -> list[TM.ChunkStats]:
        """Drain the ingest queue, then the buffered remainder as one
        final short chunk."""
        stats: list[TM.ChunkStats] = []
        if self.ingest is not None:
            while not self._quarantined:
                ev = self.ingest.take(None, drain=True)
                if ev is None:
                    break
                start, region, n_chunks = self._buf.push_region(ev)
                stats += self._run_region(start, region, n_chunks)
        stats += [self._run_piece(start, chunk)
                  for start, chunk in self._buf.drain()]
        return stats

    def _group_limit(self) -> int:
        return self.rt.effective_group_chunks()

    def _chunks_to_boundary(self) -> int:
        """Chunks until the next refresh decision — groups must not cross
        it, or the host would lose its control cadence."""
        if not self._refresh_on():
            return 1 << 30
        every = self.rt.refresh.every_chunks
        return every - (self._chunk_i % every)

    def _run_region(self, start: int, region: eng.EventBatch | None,
                    n_chunks: int) -> list[TM.ChunkStats]:
        stats: list[TM.ChunkStats] = []
        cs, axis, j = self.rt.chunk_size, self._buf.axis, 0
        while j < n_chunks:
            g = min(n_chunks - j, self._group_limit(),
                    self._chunks_to_boundary())
            # push_region owns the region (never aliases the caller's
            # batch), so the common whole-region group skips the slice.
            piece = region if j == 0 and g == n_chunks else \
                chunker.slice_events(region, j * cs, (j + g) * cs, axis)
            if g == 1:
                stats.append(self._run_piece(start + j * cs, piece))
            else:
                stats += self._run_group(start + j * cs, piece, g)
            j += g
        return stats

    # -- grouped execution (one dispatch per chunk group) -------------------
    def _run_grouped(self, piece: eng.EventBatch, start: int, g: int):
        ev = jax.tree.map(
            lambda x: x.reshape((g, -1) + x.shape[1:]), piece)
        return _run_group_single(self.cfg, self.model, ev, self.carry,
                                 eng.wrap_event_index(start),
                                 self.rt.scan_unroll)

    def _run_group(self, start: int, piece: eng.EventBatch,
                   g: int) -> list[TM.ChunkStats]:
        before = self._snapshot or TM.counter_snapshot(self.carry)
        cs = self.rt.chunk_size
        n_lanes = 1 if self._buf.axis == 0 \
            else jax.tree.leaves(piece)[0].shape[0]
        t0 = time.perf_counter()
        self.carry, vecs = self._run_grouped(piece, start, g)
        vecs = np.asarray(vecs)                # ONE transfer for g chunks
        wall = time.perf_counter() - t0
        FT.kill_point("chunk")
        out = []
        for b in range(g):
            self._chunk_i += 1
            out.append(TM.summarize_chunk(
                self._chunk_i - 1, start + b * cs, n_lanes * cs, n_lanes,
                vecs[b], before, wall / g))
            before = TM.counters_from_vec(vecs[b])
        # g never crosses a refresh boundary, so at most the LAST chunk of
        # the group lands on one.
        t1 = time.perf_counter()
        refreshed = self._maybe_refresh()
        out[-1].refreshed = refreshed
        out[-1].refresh_wall_s = time.perf_counter() - t1
        self._snapshot = before
        for s in out:
            self.telemetry.append(s)
            self.events_processed += s.n_events
        self._event_cursor = start + g * cs
        self._after_chunk(out)
        return out

    def _run_piece(self, start: int, chunk: eng.EventBatch) -> TM.ChunkStats:
        # The previous chunk's stats vector doubles as this chunk's
        # counter baseline (refresh never touches the counters), so the
        # steady state costs exactly ONE device→host transfer per chunk:
        # the ~12-float `device_chunk_stats` vector, whose host read is
        # also the sync point the wall-clock measurement needs.
        before = self._snapshot or TM.counter_snapshot(self.carry)
        n = chunker.num_events(chunk, self._buf.axis)
        n_lanes = 1 if self._buf.axis == 0 \
            else jax.tree.leaves(chunk)[0].shape[0]
        t0 = time.perf_counter()
        self.carry, outs = self._run(chunk, start)
        vec = np.asarray(TM.device_chunk_stats(outs, self.carry))
        wall = time.perf_counter() - t0
        FT.kill_point("chunk")
        self._chunk_i += 1
        t1 = time.perf_counter()
        refreshed = self._maybe_refresh()
        refresh_wall = time.perf_counter() - t1
        stats = TM.summarize_chunk(
            self._chunk_i - 1, start, n_lanes * n, n_lanes, vec, before,
            wall, refreshed=refreshed, refresh_wall_s=refresh_wall)
        self._snapshot = TM.counters_from_vec(vec)
        self.telemetry.append(stats)
        self.events_processed += stats.n_events
        self._event_cursor = start + n
        self._after_chunk([stats])
        return stats


class MultiTenantRuntime(StreamRuntime):
    """L independent tenant lanes, vmapped per chunk (repro.runtime.lanes).

    Events are pushed lane-stacked — every ``EventBatch`` leaf carries a
    leading ``(L,)`` axis (``lanes.stack``) — and lanes advance in lockstep
    over aligned chunk windows.  Models may be shared
    (``lanes.broadcast_model``) or per-lane; refresh runs PER LANE from
    each lane's own carry, so tenants adapt to their own stream's drift.
    On a multi-device mesh, pass ``mesh`` to spread lanes × patterns via
    ``repro.dist.sharding.run_chunk_lanes_sharded``.
    """

    def __init__(self, cfg: eng.EngineConfig, model: eng.EngineModel,
                 num_lanes: int, rt: RuntimeConfig | None = None,
                 specs: Sequence[pat.PatternSpec] | None = None,
                 carry: eng.Carry | None = None, seed: int = 0, mesh=None):
        self.num_lanes = num_lanes
        self.mesh = mesh
        if carry is None:
            carry = LN.init_lane_carries(cfg, num_lanes, seed=seed)
        super().__init__(cfg, model, rt=rt, specs=specs, carry=carry,
                         seed=seed)
        # chunk over the EVENT axis (axis 1 of lane-stacked leaves)
        self._buf = chunker.ChunkBuffer(self.rt.chunk_size, axis=1)
        self.refresh_state = [RF.RefreshState() for _ in range(num_lanes)]

    def _make_ingest(self):
        # One bounded queue PER TENANT LANE, re-aligned into lockstep
        # lane-stacked batches on take (repro.runtime.ingest).
        return IG.IngestFrontEnd(self.rt.ingest, self.num_lanes)

    def _guard_lanes(self) -> int | None:
        return self.num_lanes

    def _trim_call(self, i, frac):
        return GD.trim_store_lanes(self.cfg, self.model, self.carry, i,
                                   frac)

    def _guard_restore(self, viols: list[GD.GuardViolation]) -> None:
        lanes_bad = sorted({v.lane for v in viols if v.lane is not None})
        if not lanes_bad:
            return super()._guard_restore(viols)
        # Per-lane rollback: only the poisoned lanes reset; their
        # neighbors keep live state bit for bit.
        self.carry, self.model = self.guard.restore(
            self.carry, self.model, lanes=lanes_bad)
        self._snapshot = None
        if self.ingest is not None \
                and self.rt.guard.quarantine_offers > 0:
            for lane in lanes_bad:
                purged = self.ingest.quarantine_lane(
                    lane, self.rt.guard.quarantine_offers)
                self.quarantine_dropped += purged
        # Rewind the poisoned lanes' admission state (token bucket,
        # watermark latches) to the checkpoint alongside their arrays.
        ctl = self.guard.checkpoint_control
        lanes_ctl = None if ctl is None \
            else ctl.get("ingest", {}).get("lanes")
        if lanes_ctl is not None and self.ingest is not None:
            for lane in lanes_bad:
                self.ingest.queues[lane].restore_control_state(
                    lanes_ctl[lane])
        self.telemetry.record_event("guard_restore", self._chunk_i, {
            "from_chunk": self.guard.checkpoint_chunk,
            "lanes": lanes_bad})

    def _run(self, chunk: eng.EventBatch, start: int):
        start_i = eng.wrap_event_index(start)
        if self.mesh is not None:
            from repro.dist import sharding as SH
            return SH.run_chunk_lanes_sharded(
                self.cfg, self.model, chunk, self.carry, start_i,
                mesh=self.mesh)
        return LN.run_chunk_lanes_donated(self.cfg, self.model, chunk,
                                          self.carry, start_i)

    def _group_limit(self) -> int:
        # The sharded path has no grouped runner — chunk-at-a-time.
        return 1 if self.mesh is not None \
            else self.rt.effective_group_chunks()

    def _run_grouped(self, piece: eng.EventBatch, start: int, g: int):
        # (L, g·cs, ...) → (g, L, cs, ...): chunk axis leads the scan.
        def rs(x):
            x = x.reshape((x.shape[0], g, -1) + x.shape[2:])
            return jnp.swapaxes(x, 0, 1)
        ev = jax.tree.map(rs, piece)
        return _run_group_lanes(self.cfg, self.model, ev, self.carry,
                                eng.wrap_event_index(start),
                                self.rt.scan_unroll)

    def _maybe_refresh(self) -> bool:
        if not self._refresh_on() \
           or self._chunk_i % self.rt.refresh.every_chunks != 0:
            return False
        FT.kill_point("refresh")
        models, carries, did = [], [], False
        for lane in range(self.num_lanes):
            m, c, d = RF.refresh_model(
                self.specs, self.cfg, LN.unstack_lane(self.model, lane),
                LN.unstack_lane(self.carry, lane), self.rt.refresh,
                self.refresh_state[lane])
            models.append(m)
            carries.append(c)
            did |= d
        if did:
            self.model = LN.stack(models)
            self.carry = LN.stack(carries)
        return did

    def merged_carry(self) -> eng.Carry:
        """All lanes folded into one L·P-pattern carry (engine.merge_carries)
        — the global view telemetry and reporting aggregate over."""
        return eng.merge_carries(self.carry)
