"""The streaming runtime: chunk lifecycle orchestration (DESIGN.md §7).

``StreamRuntime`` (one tenant) and ``MultiTenantRuntime`` (L vmapped tenant
lanes) drive the engine chunk-by-chunk over unbounded streams:

    push(events) ─→ ChunkBuffer ─→ [run_engine_chunk / run_chunk_lanes]
         ▲                              │ donated carry, traced start
         │ host-side control            ▼
         └── telemetry ◄── refresh? ◄── counters

Between chunks the host reads telemetry, and — on the refresh cadence —
re-estimates the Markov/utility model and the latency regression from the
carry's accumulated observations (``repro.runtime.refresh``), so the
shedder tracks drifting stream statistics.  The carry is donated into
every chunk, so steady-state memory is constant regardless of how long
the stream runs.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as ctr
from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.runtime import chunker, lanes as LN, refresh as RF, telemetry as TM


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    chunk_size: int = 1024
    refresh: RF.RefreshConfig | None = None
    # Macro-batching (DESIGN.md §8): up to this many consecutive full
    # chunks run in ONE device dispatch (a lax.scan over chunks with the
    # per-chunk telemetry vectors computed in-scan), amortizing per-chunk
    # slicing/dispatch/transfer costs.  Groups never cross a refresh
    # boundary, so the host keeps its control cadence.  None (the
    # default) sizes the group from the chunk size
    # (``chunker.suggested_group_chunks``: small chunks group until one
    # dispatch covers ~8k events); 1 disables grouping.
    group_chunks: int | None = None
    # Unroll factor for the outer chunk scan inside a grouped dispatch
    # (lax.scan ``unroll=``): >1 trades compile time for fewer loop-back
    # edges on very small chunks.  1 keeps the plain scan.
    scan_unroll: int = 1

    def effective_group_chunks(self) -> int:
        if self.group_chunks is None:
            return chunker.suggested_group_chunks(self.chunk_size)
        return max(1, self.group_chunks)


def _make_group_runner(scan_fn, chunk_axis: int):
    """A donating jit that runs B consecutive chunks in ONE dispatch:
    a lax.scan over the leading chunk axis whose body IS the engine's
    event scan, so results are bitwise-identical to B sequential chunk
    calls; per-chunk telemetry vectors are computed in-scan.  The two
    instances differ only in the engine scan and where the chunk size
    sits in the event leaves ((B, chunk, ...) vs (B, L, chunk, ...))."""

    @functools.partial(jax.jit, static_argnames=("cfg", "unroll"),
                       donate_argnames=("carry", "events"))
    def run(cfg: eng.EngineConfig, model: eng.EngineModel,
            events: eng.EventBatch, carry: eng.Carry, start: jax.Array,
            unroll: int = 1):
        lead = jax.tree.leaves(events)[0]
        b, cs = lead.shape[0], lead.shape[chunk_axis]
        starts = start + cs * jnp.arange(b, dtype=jnp.int32)

        def body(c, x):
            ev_b, s = x
            c, outs = scan_fn(cfg, model, ev_b, c, s)
            return c, TM.device_chunk_stats(outs, c)

        return jax.lax.scan(body, carry, (events, starts),
                            unroll=max(1, min(unroll, b)))

    return run


_run_group_single = ctr.contract(
    "runtime._run_group_single", donate=("carry", "events"),
    max_while=14, max_cond=24, max_compiles=2,
    max_temp_bytes=ctr.hot_path_temp_budget,
    max_gather_bytes=ctr.hot_path_gather_budget)(
        _make_group_runner(eng._scan_events_backend, chunk_axis=1))
_run_group_lanes = ctr.contract(
    "runtime._run_group_lanes", donate=("carry", "events"),
    max_while=14, max_cond=24, max_compiles=2)(
        _make_group_runner(eng._scan_events_lanes_backend, chunk_axis=2))


class StreamRuntime:
    """Single-tenant chunked runtime over one event stream.

    ``push`` ingests any number of events (the tail shorter than a chunk
    stays buffered); ``flush`` drains the remainder.  Chunked execution is
    bitwise-identical to one monolithic ``run_engine`` scan of the same
    events — chunking changes memory behavior and control cadence, never
    results.
    """

    def __init__(self, cfg: eng.EngineConfig, model: eng.EngineModel,
                 rt: RuntimeConfig | None = None,
                 specs: Sequence[pat.PatternSpec] | None = None,
                 carry: eng.Carry | None = None, seed: int = 0):
        self.cfg = cfg
        self.model = model
        self.rt = rt or RuntimeConfig()
        self.specs = list(specs) if specs is not None else None
        if self._refresh_on() and not cfg.gather_stats:
            raise ValueError("model refresh needs cfg.gather_stats=True "
                             "(the carry must accumulate observations)")
        if self._refresh_on() and self.specs is None:
            raise ValueError("model refresh needs the PatternSpec list")
        if self._refresh_on():
            # Refresh must never change array shapes mid-stream (that
            # would retrace the chunk executable): widen the utility
            # tables to refresh width up front.
            self.model = RF.prepare_model(self.specs, self.model,
                                          self.rt.refresh)
        self.carry = carry if carry is not None else eng.init_carry(
            cfg, seed=seed)
        self.telemetry = TM.TelemetryLog()
        self.refresh_state = RF.RefreshState()
        self._buf = chunker.ChunkBuffer(self.rt.chunk_size)
        self._chunk_i = 0
        self.events_processed = 0
        self._snapshot: dict[str, float] | None = None

    # -- chunk execution (overridden by the lane runtime) -------------------
    def _run(self, chunk: eng.EventBatch, start: int):
        return eng.run_engine_chunk(self.cfg, self.model, chunk, self.carry,
                                    eng.wrap_event_index(start))

    def _refresh_on(self) -> bool:
        r = self.rt.refresh
        return r is not None and r.every_chunks > 0

    def _maybe_refresh(self) -> bool:
        if not self._refresh_on() \
           or self._chunk_i % self.rt.refresh.every_chunks != 0:
            return False
        self.model, self.carry, did = RF.refresh_model(
            self.specs, self.cfg, self.model, self.carry, self.rt.refresh,
            self.refresh_state)
        return did

    # -- ingestion ----------------------------------------------------------
    def push(self, events: eng.EventBatch,
             flush: bool = False) -> list[TM.ChunkStats]:
        """Ingest events; run every full chunk now available.  With
        ``flush`` the sub-chunk remainder runs too (end of stream).

        Consecutive full chunks run as macro-batched GROUPS — one device
        dispatch per up-to-``group_chunks`` chunks, never crossing a
        refresh boundary — with identical results and per-chunk stats to
        chunk-at-a-time execution (tests/test_runtime.py)."""
        start, region, n_chunks = self._buf.push_region(events)
        stats = self._run_region(start, region, n_chunks)
        if flush:
            stats += self.flush()
        return stats

    def flush(self) -> list[TM.ChunkStats]:
        """Drain the buffered remainder as one final short chunk."""
        return [self._run_piece(start, chunk)
                for start, chunk in self._buf.drain()]

    def _group_limit(self) -> int:
        return self.rt.effective_group_chunks()

    def _chunks_to_boundary(self) -> int:
        """Chunks until the next refresh decision — groups must not cross
        it, or the host would lose its control cadence."""
        if not self._refresh_on():
            return 1 << 30
        every = self.rt.refresh.every_chunks
        return every - (self._chunk_i % every)

    def _run_region(self, start: int, region: eng.EventBatch | None,
                    n_chunks: int) -> list[TM.ChunkStats]:
        stats: list[TM.ChunkStats] = []
        cs, axis, j = self.rt.chunk_size, self._buf.axis, 0
        while j < n_chunks:
            g = min(n_chunks - j, self._group_limit(),
                    self._chunks_to_boundary())
            # push_region owns the region (never aliases the caller's
            # batch), so the common whole-region group skips the slice.
            piece = region if j == 0 and g == n_chunks else \
                chunker.slice_events(region, j * cs, (j + g) * cs, axis)
            if g == 1:
                stats.append(self._run_piece(start + j * cs, piece))
            else:
                stats += self._run_group(start + j * cs, piece, g)
            j += g
        return stats

    # -- grouped execution (one dispatch per chunk group) -------------------
    def _run_grouped(self, piece: eng.EventBatch, start: int, g: int):
        ev = jax.tree.map(
            lambda x: x.reshape((g, -1) + x.shape[1:]), piece)
        return _run_group_single(self.cfg, self.model, ev, self.carry,
                                 eng.wrap_event_index(start),
                                 self.rt.scan_unroll)

    def _run_group(self, start: int, piece: eng.EventBatch,
                   g: int) -> list[TM.ChunkStats]:
        before = self._snapshot or TM.counter_snapshot(self.carry)
        cs = self.rt.chunk_size
        n_lanes = 1 if self._buf.axis == 0 \
            else jax.tree.leaves(piece)[0].shape[0]
        t0 = time.perf_counter()
        self.carry, vecs = self._run_grouped(piece, start, g)
        vecs = np.asarray(vecs)                # ONE transfer for g chunks
        wall = time.perf_counter() - t0
        out = []
        for b in range(g):
            self._chunk_i += 1
            out.append(TM.summarize_chunk(
                self._chunk_i - 1, start + b * cs, n_lanes * cs, n_lanes,
                vecs[b], before, wall / g))
            before = TM.counters_from_vec(vecs[b])
        # g never crosses a refresh boundary, so at most the LAST chunk of
        # the group lands on one.
        t1 = time.perf_counter()
        refreshed = self._maybe_refresh()
        out[-1].refreshed = refreshed
        out[-1].refresh_wall_s = time.perf_counter() - t1
        self._snapshot = before
        for s in out:
            self.telemetry.append(s)
            self.events_processed += s.n_events
        return out

    def _run_piece(self, start: int, chunk: eng.EventBatch) -> TM.ChunkStats:
        # The previous chunk's stats vector doubles as this chunk's
        # counter baseline (refresh never touches the counters), so the
        # steady state costs exactly ONE device→host transfer per chunk:
        # the ~12-float `device_chunk_stats` vector, whose host read is
        # also the sync point the wall-clock measurement needs.
        before = self._snapshot or TM.counter_snapshot(self.carry)
        n = chunker.num_events(chunk, self._buf.axis)
        n_lanes = 1 if self._buf.axis == 0 \
            else jax.tree.leaves(chunk)[0].shape[0]
        t0 = time.perf_counter()
        self.carry, outs = self._run(chunk, start)
        vec = np.asarray(TM.device_chunk_stats(outs, self.carry))
        wall = time.perf_counter() - t0
        self._chunk_i += 1
        t1 = time.perf_counter()
        refreshed = self._maybe_refresh()
        refresh_wall = time.perf_counter() - t1
        stats = TM.summarize_chunk(
            self._chunk_i - 1, start, n_lanes * n, n_lanes, vec, before,
            wall, refreshed=refreshed, refresh_wall_s=refresh_wall)
        self._snapshot = TM.counters_from_vec(vec)
        self.telemetry.append(stats)
        self.events_processed += stats.n_events
        return stats


class MultiTenantRuntime(StreamRuntime):
    """L independent tenant lanes, vmapped per chunk (repro.runtime.lanes).

    Events are pushed lane-stacked — every ``EventBatch`` leaf carries a
    leading ``(L,)`` axis (``lanes.stack``) — and lanes advance in lockstep
    over aligned chunk windows.  Models may be shared
    (``lanes.broadcast_model``) or per-lane; refresh runs PER LANE from
    each lane's own carry, so tenants adapt to their own stream's drift.
    On a multi-device mesh, pass ``mesh`` to spread lanes × patterns via
    ``repro.dist.sharding.run_chunk_lanes_sharded``.
    """

    def __init__(self, cfg: eng.EngineConfig, model: eng.EngineModel,
                 num_lanes: int, rt: RuntimeConfig | None = None,
                 specs: Sequence[pat.PatternSpec] | None = None,
                 carry: eng.Carry | None = None, seed: int = 0, mesh=None):
        self.num_lanes = num_lanes
        self.mesh = mesh
        if carry is None:
            carry = LN.init_lane_carries(cfg, num_lanes, seed=seed)
        super().__init__(cfg, model, rt=rt, specs=specs, carry=carry,
                         seed=seed)
        # chunk over the EVENT axis (axis 1 of lane-stacked leaves)
        self._buf = chunker.ChunkBuffer(self.rt.chunk_size, axis=1)
        self.refresh_state = [RF.RefreshState() for _ in range(num_lanes)]

    def _run(self, chunk: eng.EventBatch, start: int):
        start_i = eng.wrap_event_index(start)
        if self.mesh is not None:
            from repro.dist import sharding as SH
            return SH.run_chunk_lanes_sharded(
                self.cfg, self.model, chunk, self.carry, start_i,
                mesh=self.mesh)
        return LN.run_chunk_lanes_donated(self.cfg, self.model, chunk,
                                          self.carry, start_i)

    def _group_limit(self) -> int:
        # The sharded path has no grouped runner — chunk-at-a-time.
        return 1 if self.mesh is not None \
            else self.rt.effective_group_chunks()

    def _run_grouped(self, piece: eng.EventBatch, start: int, g: int):
        # (L, g·cs, ...) → (g, L, cs, ...): chunk axis leads the scan.
        def rs(x):
            x = x.reshape((x.shape[0], g, -1) + x.shape[2:])
            return jnp.swapaxes(x, 0, 1)
        ev = jax.tree.map(rs, piece)
        return _run_group_lanes(self.cfg, self.model, ev, self.carry,
                                eng.wrap_event_index(start),
                                self.rt.scan_unroll)

    def _maybe_refresh(self) -> bool:
        if not self._refresh_on() \
           or self._chunk_i % self.rt.refresh.every_chunks != 0:
            return False
        models, carries, did = [], [], False
        for lane in range(self.num_lanes):
            m, c, d = RF.refresh_model(
                self.specs, self.cfg, LN.unstack_lane(self.model, lane),
                LN.unstack_lane(self.carry, lane), self.rt.refresh,
                self.refresh_state[lane])
            models.append(m)
            carries.append(c)
            did |= d
        if did:
            self.model = LN.stack(models)
            self.carry = LN.stack(carries)
        return did

    def merged_carry(self) -> eng.Carry:
        """All lanes folded into one L·P-pattern carry (engine.merge_carries)
        — the global view telemetry and reporting aggregate over."""
        return eng.merge_carries(self.carry)
