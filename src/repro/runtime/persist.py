"""Durable snapshots + write-ahead event log (DESIGN.md §13).

Everything the runtime is — the donated carry, the deployed model, the
PRNG key chain, and the host-side control state (ladder rung + streaks,
token-bucket clocks, watermark latches, refresh state, telemetry) —
lives in one process.  This module makes that state durable with two
artifacts, sized so that recovery is *provably bitwise*:

1. **Snapshots** — a versioned container holding every pytree flattened
   in ``jax.tree_util`` order with a ``{path, dtype, shape}`` manifest
   (``repro.cep.engine.pytree_manifest``), a JSON control block, and a
   CRC32 over the whole body.  Writes are atomic (tmp + fsync + rename
   + directory fsync) and rotate across ``keep_generations`` files;
   ``load_latest`` CRC-rejects torn generations and falls back to the
   previous one.

2. **Write-ahead log** — every ``push`` batch is appended (and flushed)
   to a segment file BEFORE the runtime processes it.  Records carry
   globally monotone ids; a snapshot stores ``wal_next_record``, the
   first id NOT absorbed into it.  Recovery = restore newest valid
   snapshot + re-push records ``>= wal_next_record`` through the normal
   chunk path.  Because admission, shedding and refresh are all clocked
   by event arrival time and seeded PRNG chains (never wall clock), the
   replay re-derives every decision exactly and the recovered state is
   bitwise-identical to the uninterrupted run.

The guard's in-memory checkpoint (repro.runtime.guard) is one more
consumer of the same codec: its host copies and control dict ride along
inside the durable snapshot, so a recovered process can still roll back
to its last good in-memory checkpoint.
"""
from __future__ import annotations

import dataclasses
import glob
import json
import os
import struct
import zlib

import jax
import numpy as np

from repro.cep import engine as eng

SNAP_MAGIC = b"PSPSNAP\x01"
SNAP_VERSION = 1
WAL_MAGIC = b"PSPWAL\x01\x00"
_REC_MAGIC = 0x50455631  # "PEV1"
_REC_HEAD = struct.Struct("<IQII")   # magic, record id, manifest len, blob len


class PersistError(ValueError):
    """Base class for durable-state errors (all are actionable)."""


class CorruptSnapshotError(PersistError):
    """Torn/truncated/wrong-magic/wrong-version/CRC-failing snapshot.
    ``SnapshotStore.load_latest`` treats this as 'try the previous
    generation'; direct loads surface it."""


class ManifestMismatchError(PersistError):
    """The snapshot's leaf manifest does not match the live tree — a
    config/shape mismatch, not corruption.  Never falls back silently:
    loading an incompatible snapshot into a differently-shaped runtime
    is operator error and must be surfaced."""


class CorruptSegmentError(PersistError):
    """A WAL segment failed to parse (bad magic, torn record, CRC)."""


@dataclasses.dataclass(frozen=True)
class PersistConfig:
    """Durability knobs (validated at construction)."""
    dir: str                        # snapshot + WAL directory
    snapshot_every_chunks: int = 8  # snapshot cadence (checked per push)
    keep_generations: int = 3       # snapshot files retained
    wal_fsync_every: int = 1        # fsync cadence in appends; <=0 = flush
                                    # to the OS only (process-crash safe,
                                    # not power-loss safe)

    def __post_init__(self):
        if not self.dir:
            raise ValueError("persist.dir must name a directory")
        if self.snapshot_every_chunks < 1:
            raise ValueError("persist.snapshot_every_chunks must be >= 1: "
                             f"{self.snapshot_every_chunks}")
        if self.keep_generations < 1:
            raise ValueError("persist.keep_generations must be >= 1: "
                             f"{self.keep_generations}")


# -- leaf codec -------------------------------------------------------------
def encode_tree(tree) -> tuple[list[dict], bytes]:
    """Flatten ``tree`` to (manifest, payload): leaves in jax flatten
    order, each a contiguous little-endian-native byte run described by
    one ``{path, dtype, shape}`` manifest entry."""
    manifest, blobs = [], []
    for entry, leaf in zip(eng.pytree_manifest(tree),
                           jax.tree.leaves(tree)):
        arr = np.ascontiguousarray(np.asarray(leaf))
        manifest.append(entry)
        blobs.append(arr.tobytes())
    return manifest, b"".join(blobs)


def decode_tree(manifest: list[dict], blob: bytes, template,
                what: str = "tree", strict: bool = True):
    """Rebuild a pytree with ``template``'s structure from codec output.

    ``strict`` validates dtype AND shape per leaf against the template
    (carry/model: a mismatch means the snapshot belongs to a different
    config); non-strict validates structure only (event batches, whose
    event-axis length legitimately varies).  Leaves come back as host
    numpy views into ``blob``.
    """
    exp = eng.pytree_manifest(template)
    if len(exp) != len(manifest):
        raise ManifestMismatchError(
            f"{what}: snapshot has {len(manifest)} leaves, live tree has "
            f"{len(exp)} — snapshot was written by a different config")
    bad = []
    for e, m in zip(exp, manifest):
        if e["path"] != m["path"]:
            bad.append(f"{m['path']} (expected {e['path']})")
        elif strict and (e["dtype"] != m["dtype"]
                         or e["shape"] != list(m["shape"])):
            bad.append(f"{m['path']}: {m['dtype']}{m['shape']} != live "
                       f"{e['dtype']}{e['shape']}")
    if bad:
        raise ManifestMismatchError(
            f"{what}: manifest mismatch on {len(bad)} leaves (snapshot "
            f"from a different config/shape): " + "; ".join(bad[:4]))
    leaves, off = [], 0
    for m in manifest:
        dt = np.dtype(m["dtype"])
        count = int(np.prod(m["shape"], dtype=np.int64)) if m["shape"] \
            else 1
        nbytes = dt.itemsize * count
        if off + nbytes > len(blob):
            raise CorruptSnapshotError(
                f"{what}: payload truncated at leaf {m['path']} "
                f"(need {off + nbytes} bytes, have {len(blob)})")
        arr = np.frombuffer(blob, dtype=dt, count=count, offset=off)
        leaves.append(arr.reshape(tuple(m["shape"])))
        off += nbytes
    if off != len(blob):
        raise CorruptSnapshotError(
            f"{what}: {len(blob) - off} trailing payload bytes")
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(template), leaves)


def event_template() -> eng.EventBatch:
    """A structure-only EventBatch for non-strict decodes (shapes and
    dtypes come from the snapshot manifest)."""
    return eng.EventBatch(*([np.zeros(0)] * len(eng.EventBatch._fields)))


# -- snapshot container -----------------------------------------------------
def build_snapshot_bytes(chunk_index: int, control: dict,
                         sections: dict) -> bytes:
    """``MAGIC | <u32 version, u32 header_len> | header JSON | payload |
    u32 CRC32(everything after MAGIC)``.  ``sections`` maps name →
    pytree; None values are skipped."""
    secmeta, blobs, off = {}, [], 0
    for name in sorted(sections):
        tree = sections[name]
        if tree is None:
            continue
        man, blob = encode_tree(tree)
        secmeta[name] = {"manifest": man, "offset": off,
                         "nbytes": len(blob)}
        blobs.append(blob)
        off += len(blob)
    header = {"format": "pspice-snapshot", "version": SNAP_VERSION,
              "chunk_index": int(chunk_index), "control": control,
              "sections": secmeta}
    hj = json.dumps(header, sort_keys=True).encode()
    body = struct.pack("<II", SNAP_VERSION, len(hj)) + hj + b"".join(blobs)
    return SNAP_MAGIC + body + struct.pack("<I", zlib.crc32(body))


def parse_snapshot_bytes(data: bytes, path: str = "<bytes>"
                         ) -> tuple[dict, dict]:
    """Validate + parse a snapshot file: returns ``(header, sections)``
    with ``sections[name] == (manifest, payload_bytes)``.  CRC is checked
    FIRST (over version + header + payload), so a torn write of any part
    — including the version field — reads as corruption, and only an
    intact file can fail the version check."""
    n_min = len(SNAP_MAGIC) + 8 + 4
    if len(data) < n_min:
        raise CorruptSnapshotError(
            f"{path}: {len(data)} bytes is shorter than the fixed "
            f"snapshot framing ({n_min}) — torn or not a snapshot")
    if data[:len(SNAP_MAGIC)] != SNAP_MAGIC:
        raise CorruptSnapshotError(f"{path}: bad magic — not a pSPICE "
                                   "snapshot file")
    body, (crc,) = data[len(SNAP_MAGIC):-4], struct.unpack("<I", data[-4:])
    if zlib.crc32(body) != crc:
        raise CorruptSnapshotError(
            f"{path}: CRC mismatch — torn or corrupted write; the "
            "previous generation (if any) is the newest valid state")
    version, hlen = struct.unpack("<II", body[:8])
    if version != SNAP_VERSION:
        raise CorruptSnapshotError(
            f"{path}: snapshot version {version}; this build reads "
            f"version {SNAP_VERSION} only")
    try:
        header = json.loads(body[8:8 + hlen])
    except ValueError as e:
        raise CorruptSnapshotError(f"{path}: header is not valid JSON "
                                   f"({e})") from e
    payload = body[8 + hlen:]
    sections = {}
    for name, sm in header.get("sections", {}).items():
        blob = payload[sm["offset"]:sm["offset"] + sm["nbytes"]]
        if len(blob) != sm["nbytes"]:
            raise CorruptSnapshotError(
                f"{path}: section {name} extends past the payload")
        sections[name] = (sm["manifest"], blob)
    return header, sections


def _fsync_dir(path: str) -> None:
    fd = os.open(path, os.O_RDONLY)
    try:
        os.fsync(fd)
    finally:
        os.close(fd)


def atomic_write(path: str, data: bytes) -> None:
    """tmp + fsync + rename + directory fsync: readers see either the
    previous generation or the complete new one, never a torn file."""
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    _fsync_dir(os.path.dirname(path) or ".")


class SnapshotStore:
    """Generation-rotated snapshot files: ``snap-<chunk>.ckpt``."""

    def __init__(self, dir: str, keep_generations: int = 3):
        self.dir = dir
        self.keep = max(1, keep_generations)
        os.makedirs(dir, exist_ok=True)

    def paths(self) -> list[str]:
        return sorted(glob.glob(os.path.join(self.dir, "snap-*.ckpt")))

    def save(self, chunk_index: int, control: dict, sections: dict) -> str:
        from repro.runtime import faults as FT

        data = build_snapshot_bytes(chunk_index, control, sections)
        path = os.path.join(self.dir, f"snap-{int(chunk_index):010d}.ckpt")
        ks = FT.active_kill_switch()
        if ks is not None and ks.pending("snapshot"):
            # Crash harness: die MID-WRITE the way a non-atomic writer
            # would — a torn file at the FINAL path, which recovery must
            # CRC-reject in favor of the previous generation.
            with open(path, "wb") as f:
                f.write(data[:max(len(SNAP_MAGIC) + 4, len(data) // 2)])
                f.flush()
                os.fsync(f.fileno())
            ks.kill()
        atomic_write(path, data)
        self._prune()
        return path

    def _prune(self) -> None:
        for p in self.paths()[:-self.keep]:
            os.remove(p)

    def load_latest(self) -> tuple[dict | None, dict | None, dict]:
        """Newest generation that parses + passes CRC; torn/corrupt ones
        are recorded in ``meta['rejected']`` and skipped.  Returns
        ``(header, sections, meta)`` — ``(None, None, meta)`` when no
        valid generation exists (recovery then replays the WAL from
        record 0 against the initial state)."""
        rejected = []
        for path in reversed(self.paths()):
            with open(path, "rb") as f:
                data = f.read()
            try:
                header, sections = parse_snapshot_bytes(data, path)
            except CorruptSnapshotError as e:
                rejected.append({"path": os.path.basename(path),
                                 "error": str(e)})
                continue
            return header, sections, {"path": path, "rejected": rejected}
        return None, None, {"path": None, "rejected": rejected}


# -- write-ahead log --------------------------------------------------------
class WriteAheadLog:
    """Append-only event-batch log across ``wal-<seq>.seg`` segments.

    Record ids are globally monotone across segments; ``append`` writes
    and FLUSHES before returning (fsync on the configured cadence), so
    once the runtime starts processing a push, its events are already
    durable against process death.  A snapshot stores the first
    unabsorbed id; replay never re-appends (the records are already on
    disk), and the next post-recovery append opens a fresh segment.
    """

    def __init__(self, dir: str, fsync_every: int = 1):
        self.dir = dir
        self.fsync_every = fsync_every
        os.makedirs(dir, exist_ok=True)
        self._f = None
        self._appends = 0
        last_id, last_seq = -1, -1
        for seq, path in self.segments():
            last_seq = max(last_seq, seq)
            for rid, _man, _blob in _iter_segment(path):
                last_id = max(last_id, rid)
        self._next_id = last_id + 1
        self._next_seq = last_seq + 1

    def segments(self) -> list[tuple[int, str]]:
        out = []
        for path in sorted(glob.glob(os.path.join(self.dir, "wal-*.seg"))):
            stem = os.path.basename(path)[4:-4]
            out.append((int(stem), path))
        return out

    @property
    def next_record_id(self) -> int:
        return self._next_id

    def append(self, events) -> int:
        if self._f is None:
            path = os.path.join(self.dir, f"wal-{self._next_seq:08d}.seg")
            self._next_seq += 1
            self._f = open(path, "wb")
            self._f.write(WAL_MAGIC)
        man, blob = encode_tree(events)
        mj = json.dumps(man, sort_keys=True).encode()
        rid = self._next_id
        head = _REC_HEAD.pack(_REC_MAGIC, rid, len(mj), len(blob))
        rec = head + mj + blob
        self._f.write(rec + struct.pack("<I", zlib.crc32(rec[4:])))
        self._f.flush()
        self._appends += 1
        if self.fsync_every > 0 \
                and self._appends % self.fsync_every == 0:
            os.fsync(self._f.fileno())
        self._next_id = rid + 1
        return rid

    def records_since(self, start_id: int) -> list[tuple[int, object]]:
        """All ``(record_id, EventBatch)`` with id >= ``start_id``, in id
        order.  Strict: any torn segment raises ``CorruptSegmentError``
        (the append path flushes before processing starts, so kill-based
        crashes never tear the tail — a torn segment means real damage)."""
        tmpl = event_template()
        out = []
        for _seq, path in self.segments():
            for rid, man, blob in _iter_segment(path):
                if rid >= start_id:
                    out.append((rid, decode_tree(man, blob, tmpl,
                                                 what=os.path.basename(path),
                                                 strict=False)))
        out.sort(key=lambda r: r[0])
        return out

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None


def _iter_segment(path: str):
    """Yield ``(record_id, manifest, blob)`` per record, strictly."""
    with open(path, "rb") as f:
        data = f.read()
    if data[:len(WAL_MAGIC)] != WAL_MAGIC:
        raise CorruptSegmentError(f"{path}: bad segment magic — not a "
                                  "pSPICE WAL segment")
    off = len(WAL_MAGIC)
    while off < len(data):
        if off + _REC_HEAD.size > len(data):
            raise CorruptSegmentError(
                f"{path}: torn record header at offset {off}")
        magic, rid, mlen, blen = _REC_HEAD.unpack_from(data, off)
        if magic != _REC_MAGIC:
            raise CorruptSegmentError(
                f"{path}: bad record magic at offset {off}")
        end = off + _REC_HEAD.size + mlen + blen + 4
        if end > len(data):
            raise CorruptSegmentError(
                f"{path}: torn record {rid} at offset {off} (need "
                f"{end - len(data)} more bytes)")
        body = data[off + 4:end - 4]
        (crc,) = struct.unpack_from("<I", data, end - 4)
        if zlib.crc32(body) != crc:
            raise CorruptSegmentError(
                f"{path}: CRC mismatch on record {rid} at offset {off}")
        mj = data[off + _REC_HEAD.size:off + _REC_HEAD.size + mlen]
        blob = data[off + _REC_HEAD.size + mlen:end - 4]
        yield rid, json.loads(mj), blob
        off = end


class Persistence:
    """One runtime's durability bundle: store + WAL under one dir."""

    def __init__(self, cfg: PersistConfig):
        self.cfg = cfg
        os.makedirs(cfg.dir, exist_ok=True)
        self.store = SnapshotStore(cfg.dir, cfg.keep_generations)
        self.wal = WriteAheadLog(cfg.dir, cfg.wal_fsync_every)
