"""Chunked ingestion: fixed-size micro-batches over unbounded streams
(DESIGN.md §7).

``run_engine`` scans a fully materialized stream; a deployed operator sees
an unbounded one.  The chunker turns any sequence of ``EventBatch`` pushes
into fixed-size chunks: full chunks stream through ONE compiled executable
of ``run_engine_chunk`` (the chunk start index is a traced scalar), the
remainder is buffered until the next push, and ``drain`` flushes it as one
smaller tail chunk (a single extra compile at most).  Because event
indices are global, chunked execution is bitwise-identical to the
monolithic scan — tests/test_runtime.py proves it per chunk size.

``axis`` selects the event axis: 0 for plain event batches, 1 for
lane-stacked ones (leading ``(L,)`` lane axis, repro.runtime.lanes).
"""
from __future__ import annotations

from typing import Iterator

import jax
import jax.numpy as jnp

from repro.cep.engine import EventBatch


def num_events(events: EventBatch, axis: int = 0) -> int:
    return jax.tree.leaves(events)[0].shape[axis]


# Per-dispatch event budget the auto-grouping policy targets: small chunks
# group until one device dispatch covers ~this many events, which is where
# the per-chunk slicing/dispatch overhead measurably flattens out
# (BENCH_engine.json chunk_sweep; chunk=256 went from 12.6% over the
# monolithic scan at the old fixed group of 16 to parity at 32).
GROUP_EVENT_BUDGET = 8192


def suggested_group_chunks(chunk_size: int) -> int:
    """Default macro-batch size (chunks per dispatch) for a chunk size.

    Chunks below 1024 events group until a dispatch covers at most
    ``GROUP_EVENT_BUDGET`` events — the budget is a CAP, not a floor: a
    dispatch must never exceed ~8k events, or per-dispatch peak memory and
    tail latency grow past what the budget was sized for.  (A floor here
    was the historical bug: chunk sizes 513–1023 got ``max(16, ...)`` == 16
    and dispatched up to ~16k events, double the documented budget.)
    Larger chunks keep the legacy group of 16 (already past the flat part
    of the curve; those dispatches are intentionally budget-exempt)."""
    if chunk_size <= 0:
        raise ValueError(f"chunk_size must be positive: {chunk_size}")
    if chunk_size >= 1024:
        return 16
    return max(1, GROUP_EVENT_BUDGET // chunk_size)


def _take(x, start: int, stop: int, axis: int):
    idx = [slice(None)] * axis + [slice(start, stop)]
    y = x[tuple(idx)]
    # A full-range slice returns the SAME array object in jax.  Sliced
    # pieces feed DONATING jits (run_engine_chunk, the group runners), so
    # an aliasing slice would hand the caller's own buffers to donation
    # and delete them under their feet — force a copy in that case.
    return y.copy() if y is x else y


def slice_events(events: EventBatch, start: int, stop: int,
                 axis: int = 0) -> EventBatch:
    return jax.tree.map(lambda x: _take(x, start, stop, axis), events)


def concat_events(a: EventBatch | None, b: EventBatch,
                  axis: int = 0) -> EventBatch:
    if a is None or num_events(a, axis) == 0:
        return b
    return jax.tree.map(lambda x, y: jnp.concatenate([x, y], axis=axis),
                        a, b)


def iter_chunks(events: EventBatch, chunk_size: int, start: int = 0,
                axis: int = 0) -> Iterator[tuple[int, EventBatch]]:
    """Yield ``(global_start, chunk)`` pairs covering ``events``; the last
    chunk may be shorter (non-divisor streams are first-class)."""
    n = num_events(events, axis)
    for s in range(0, n, chunk_size):
        yield start + s, slice_events(events, s, min(s + chunk_size, n),
                                      axis)


class ChunkBuffer:
    """Reorders arbitrary-size pushes into fixed-size chunks.

    ``push`` returns the full chunks now available (each tagged with its
    global start index); a trailing remainder stays buffered.  ``drain``
    returns the remainder as one final short chunk.
    """

    def __init__(self, chunk_size: int, axis: int = 0):
        if chunk_size <= 0:
            raise ValueError(f"chunk_size must be positive: {chunk_size}")
        self.chunk_size = chunk_size
        self.axis = axis
        self._pending: EventBatch | None = None
        self._next_start = 0  # global index of the first buffered event

    @property
    def pending(self) -> int:
        return 0 if self._pending is None \
            else num_events(self._pending, self.axis)

    @property
    def next_start(self) -> int:
        return self._next_start

    def buffered(self) -> EventBatch | None:
        """The sub-chunk remainder (None when empty) — what a durable
        snapshot must carry so a recovered buffer resumes mid-chunk."""
        return self._pending

    def restore(self, pending: EventBatch | None, next_start: int) -> None:
        """Reset buffer state from a snapshot (repro.runtime.persist)."""
        self._pending = pending
        self._next_start = int(next_start)

    def push(self, events: EventBatch) -> list[tuple[int, EventBatch]]:
        start, region, n_chunks = self.push_region(events)
        if n_chunks == 0:
            return []
        return list(iter_chunks(region, self.chunk_size, start=start,
                                axis=self.axis))

    def push_region(self, events: EventBatch) \
            -> tuple[int, EventBatch | None, int]:
        """Like ``push`` but returns the full-chunk region UNSLICED:
        ``(global_start, region, n_full_chunks)`` with ``region`` holding
        ``n_full_chunks · chunk_size`` events (None when no full chunk is
        available).  The runtime reshapes the region into a (B, chunk, …)
        batch and scans whole chunk GROUPS per device dispatch
        (DESIGN.md §8) instead of paying per-chunk slicing + dispatch.
        The tail stays buffered exactly as with ``push``.

        Ownership contract: the returned region (and everything ``drain``
        later returns) NEVER aliases the pushed batch — ``_take`` copies
        full-range slices — so it is safe to feed donating jits."""
        buf = concat_events(self._pending, events, self.axis)
        n = num_events(buf, self.axis)
        n_full = (n // self.chunk_size) * self.chunk_size
        start = self._next_start
        region = slice_events(buf, 0, n_full, self.axis) if n_full else None
        self._pending = slice_events(buf, n_full, n, self.axis) \
            if n > n_full else None
        self._next_start += n_full
        return start, region, n_full // self.chunk_size

    def drain(self) -> list[tuple[int, EventBatch]]:
        if self._pending is None:
            return []
        out = [(self._next_start, self._pending)]
        self._next_start += num_events(self._pending, self.axis)
        self._pending = None
        return out
