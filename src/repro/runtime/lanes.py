"""Tenant lanes: vmapped multi-stream execution (DESIGN.md §7).

A lane is one tenant's independent operator: its own event stream (own
arrival rate), its own carry, its own utility tables / latency model.  All
lanes share one static ``EngineConfig``, so the per-chunk step vmaps over
the lane axis — L scans collapse into ONE scan of lane-batched ops, which
is where the multi-tenant throughput win comes from (bench_runtime.py).

Lane-stacked pytrees are ordinary ``EngineModel`` / ``EventBatch`` /
``Carry`` structures whose every leaf grew a leading ``(L,)`` axis; build
them with ``stack`` / ``broadcast_model``, recover one lane with
``unstack_lane``.  For meshes, ``repro.dist.sharding.run_chunk_lanes_sharded``
shard_maps this same vmapped step so lanes × patterns spread across
devices.
"""
from __future__ import annotations

import functools
from typing import Any, Sequence

import jax
import jax.numpy as jnp

from repro.analysis import contracts as ctr
from repro.cep import engine as eng

PyTree = Any


def stack(trees: Sequence[PyTree]) -> PyTree:
    """Stack per-lane pytrees (models, carries, event batches) on axis 0."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def unstack_lane(tree: PyTree, lane: int) -> PyTree:
    return jax.tree.map(lambda x: x[lane], tree)


def num_lanes(tree: PyTree) -> int:
    return jax.tree.leaves(tree)[0].shape[0]


def broadcast_model(model: eng.EngineModel, n: int) -> eng.EngineModel:
    """Replicate one model across n lanes (lanes may diverge later via
    per-lane refresh — each lane's tables refit from its own carry)."""
    return jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.asarray(x)[None],
                                   (n,) + jnp.asarray(x).shape).copy(),
        model)


def init_lane_carries(cfg: eng.EngineConfig, n: int, seed: int = 0,
                      lat_capacity: int = 4096) -> eng.Carry:
    """n independent carries (distinct PRNG streams), lane-stacked."""
    return stack([eng.init_carry(cfg, seed=seed + i,
                                 lat_capacity=lat_capacity)
                  for i in range(n)])


@ctr.contract("runtime.run_chunk_lanes", donate=("carry",),
              max_while=12, max_cond=24, max_compiles=1)
@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("carry",))
def run_chunk_lanes(cfg: eng.EngineConfig, model: eng.EngineModel,
                    events: eng.EventBatch, carry: eng.Carry,
                    start: jax.Array) -> tuple[eng.Carry, eng.StepOut]:
    """Lane-batched ``run_engine_chunk`` over the leading lane axis.

    ``start`` is shared: lanes advance in lockstep over aligned chunk
    windows (each lane still has its own arrival clock inside its
    EventBatch).  The lane-stacked carry is donated, like the single-lane
    chunk step; events are NOT (callers legitimately re-push the same
    lane-stacked batch — the runtime's steady-state loop uses
    ``run_chunk_lanes_donated`` on its freshly sliced chunks instead).
    Uses the engine's ``_step_lanes`` body — a scalar any-lane shed gate
    instead of vmapping the per-lane ``lax.cond`` (which would run the
    expensive shed path every event) — and stays bitwise-identical per
    lane to running each lane through ``run_engine`` on its own
    (tests/test_runtime.py).
    """
    return eng._scan_events_lanes_backend(cfg, model, events, carry,
                                          start)


@ctr.contract("runtime.run_chunk_lanes_donated",
              donate=("carry", "events"),
              max_while=12, max_cond=24, max_compiles=1)
@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("carry", "events"))
def run_chunk_lanes_donated(cfg: eng.EngineConfig, model: eng.EngineModel,
                            events: eng.EventBatch, carry: eng.Carry,
                            start: jax.Array) -> tuple[eng.Carry,
                                                       eng.StepOut]:
    """``run_chunk_lanes`` that ALSO donates the chunk's event buffers —
    the scan-entry lane→time transpose and the StepOut columns reuse the
    arriving chunk's storage instead of fresh allocations.  Only for
    callers that consume each chunk exactly once (the MultiTenantRuntime
    steady-state loop feeds it freshly sliced ChunkBuffer pieces)."""
    return eng._scan_events_lanes_backend(cfg, model, events, carry,
                                          start)
