"""repro.runtime — multi-tenant streaming runtime over the CEP engine.

The layer between the engine (one scan) and the serving surfaces:
chunked ingestion with a donated carry (constant-memory unbounded
streams), online Markov/utility model refresh between chunks, vmapped
tenant lanes, and per-chunk telemetry.  See DESIGN.md §7.
"""
from repro.runtime.chunker import (ChunkBuffer, concat_events, iter_chunks,
                                   num_events, slice_events)
from repro.runtime.lanes import (broadcast_model, init_lane_carries,
                                 num_lanes, run_chunk_lanes,
                                 run_chunk_lanes_donated, stack,
                                 unstack_lane)
from repro.runtime.refresh import (RefreshConfig, RefreshState,
                                   prepare_model, refit_latency_model,
                                   refresh_model, table_width)
from repro.runtime.service import (MultiTenantRuntime, RuntimeConfig,
                                   StreamRuntime)
from repro.runtime.telemetry import (ChunkStats, TelemetryLog,
                                     counter_snapshot, device_chunk_stats,
                                     summarize_chunk)

__all__ = [
    "ChunkBuffer", "concat_events", "iter_chunks", "num_events",
    "slice_events", "broadcast_model", "init_lane_carries", "num_lanes",
    "run_chunk_lanes", "run_chunk_lanes_donated", "stack", "unstack_lane",
    "RefreshConfig",
    "RefreshState", "prepare_model", "refit_latency_model", "refresh_model",
    "table_width",
    "MultiTenantRuntime", "RuntimeConfig", "StreamRuntime", "ChunkStats",
    "TelemetryLog", "counter_snapshot", "device_chunk_stats",
    "summarize_chunk",
]
