"""repro.runtime — multi-tenant streaming runtime over the CEP engine.

The layer between the engine (one scan) and the serving surfaces:
chunked ingestion with a donated carry (constant-memory unbounded
streams), online Markov/utility model refresh between chunks, vmapped
tenant lanes, per-chunk telemetry, and the resilience layer (bounded
admission front-end, degradation ladder, carry guard/recovery, fault
injection) plus durable crash recovery (versioned snapshots + a
write-ahead event log, repro.runtime.persist; the process-level chaos
harness lives in repro.runtime.supervisor).  See DESIGN.md §7, §8,
§12, §13.
"""
from repro.runtime.chunker import (ChunkBuffer, concat_events, iter_chunks,
                                   num_events, slice_events)
from repro.runtime.faults import (FAULT_KINDS, KILL_ENV, KILL_SITES,
                                  PROCESS_FAULTS, STATE_FAULTS,
                                  STREAM_FAULTS, FaultConfig, FaultInjector,
                                  KillSwitch, install_kill_from_env,
                                  install_kill_switch, kill_point)
from repro.runtime.guard import (CARRY_CHECKS, MODEL_CHECKS, CarryGuard,
                                 GuardConfig, GuardViolation,
                                 carry_check_lanes, carry_check_vec,
                                 model_check_lanes, model_check_vec,
                                 trim_store, trim_store_lanes)
from repro.runtime.ingest import (AdmitReport, IngestConfig, IngestFrontEnd,
                                  IngestQueue, neutral_like, take_rows)
from repro.runtime.lanes import (broadcast_model, init_lane_carries,
                                 num_lanes, run_chunk_lanes,
                                 run_chunk_lanes_donated, stack,
                                 unstack_lane)
from repro.runtime.persist import (CorruptSegmentError,
                                   CorruptSnapshotError,
                                   ManifestMismatchError, PersistConfig,
                                   Persistence, PersistError, SnapshotStore,
                                   WriteAheadLog, decode_tree, encode_tree)
from repro.runtime.refresh import (RefreshConfig, RefreshState,
                                   prepare_model, refit_latency_model,
                                   refresh_model, table_width)
from repro.runtime.service import (RUNG_INPUT_SHED, RUNG_NAMES, RUNG_NORMAL,
                                   RUNG_PM_TRIM, RUNG_QUARANTINE,
                                   DegradationLadder, LadderConfig,
                                   MultiTenantRuntime, RuntimeConfig,
                                   StreamRuntime)
from repro.runtime.telemetry import (ChunkStats, RuntimeEvent, TelemetryLog,
                                     counter_snapshot, device_chunk_stats,
                                     summarize_chunk)

__all__ = [
    "ChunkBuffer", "concat_events", "iter_chunks", "num_events",
    "slice_events",
    "FAULT_KINDS", "KILL_ENV", "KILL_SITES", "PROCESS_FAULTS",
    "STATE_FAULTS", "STREAM_FAULTS", "FaultConfig", "FaultInjector",
    "KillSwitch", "install_kill_from_env", "install_kill_switch",
    "kill_point",
    "CorruptSegmentError", "CorruptSnapshotError", "ManifestMismatchError",
    "PersistConfig", "Persistence", "PersistError", "SnapshotStore",
    "WriteAheadLog", "decode_tree", "encode_tree",
    "CARRY_CHECKS", "MODEL_CHECKS", "CarryGuard", "GuardConfig",
    "GuardViolation", "carry_check_lanes", "carry_check_vec",
    "model_check_lanes", "model_check_vec", "trim_store",
    "trim_store_lanes",
    "AdmitReport", "IngestConfig", "IngestFrontEnd", "IngestQueue",
    "neutral_like", "take_rows",
    "broadcast_model", "init_lane_carries", "num_lanes",
    "run_chunk_lanes", "run_chunk_lanes_donated", "stack", "unstack_lane",
    "RefreshConfig",
    "RefreshState", "prepare_model", "refit_latency_model", "refresh_model",
    "table_width",
    "RUNG_INPUT_SHED", "RUNG_NAMES", "RUNG_NORMAL", "RUNG_PM_TRIM",
    "RUNG_QUARANTINE", "DegradationLadder", "LadderConfig",
    "MultiTenantRuntime", "RuntimeConfig", "StreamRuntime", "ChunkStats",
    "RuntimeEvent", "TelemetryLog", "counter_snapshot",
    "device_chunk_stats", "summarize_chunk",
]
