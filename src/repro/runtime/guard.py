"""Carry/model invariant guard + checkpoint/restore (DESIGN.md §12).

The engine's carry is donated into every chunk: one NaN that slips in —
a poisoned refresh, a corrupted table, broken input — contaminates every
subsequent chunk and is unrecoverable, because the pre-fault buffers no
longer exist.  The guard makes corruption (a) DETECTABLE at chunk-group
granularity via one fused on-device check that crosses to the host as a
handful of booleans, and (b) RECOVERABLE via periodic host-side carry +
model checkpoints (true copies — the live arrays are donation fodder).

Checks are intentionally cheap (all-reduces over arrays the chunk just
touched) and derive every bound from the pytree leaves themselves, so
one jitted function serves any config and vmaps over tenant lanes.
Checks run BEFORE checkpointing, so a poisoned state is never saved.

``trim_store`` is the degradation ladder's PM-trim rung: a between-chunk
invocation of the engine's own Algorithm-2 shed path (`eng._shed_now`)
dropping a fixed fraction of active PMs, paying the same simulated shed
cost and bumping the same counters as an in-scan shed.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.cep import engine as eng

# Check-vector slot names — the single place that orders them.
CARRY_CHECKS = ("finite_time", "finite_latency_ring", "store_consistent",
                "counters_sane", "finite_obs")
MODEL_CHECKS = ("finite_tables", "finite_latency_model", "finite_params")


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    check_every_chunks: int = 1       # invariant-check cadence
    checkpoint_every_chunks: int = 8  # checkpoint cadence (on clean checks)
    restore_on_violation: bool = True
    quarantine_offers: int = 2        # lane quarantine length after restore

    def __post_init__(self):
        if self.check_every_chunks < 1:
            raise ValueError("guard.check_every_chunks must be >= 1: "
                             f"{self.check_every_chunks}")
        if self.checkpoint_every_chunks < 1:
            raise ValueError("guard.checkpoint_every_chunks must be >= 1: "
                             f"{self.checkpoint_every_chunks}")
        if self.quarantine_offers < 0:
            raise ValueError("guard.quarantine_offers must be >= 0: "
                             f"{self.quarantine_offers}")


def _all_finite(*xs) -> jax.Array:
    return jnp.stack([jnp.isfinite(x).all() for x in xs]).all()


def _carry_checks(carry: eng.Carry) -> jax.Array:
    """(len(CARRY_CHECKS),) bool vector; every bound derived from leaf
    shapes so the same trace serves any config and vmaps over lanes."""
    pms = carry.pms
    M = carry.obs_counts.shape[-1]
    K = carry.ring.shape[-1]
    finite_time = _all_finite(carry.sim_time, carry.prev_arrival,
                              carry.ema_gap) & (carry.ema_gap > 0)
    finite_ring = _all_finite(carry.lat_samples_n, carry.lat_samples_l)
    # Active PMs must hold a representable automaton state; ring pointers
    # must index the ring.  (Inactive slots may hold stale garbage.)
    state_ok = jnp.where(pms.active,
                         (pms.state >= 1) & (pms.state <= M), True).all()
    ptr_ok = ((carry.ring_ptr >= 0) & (carry.ring_ptr < K)).all()
    nonneg = lambda x: jnp.isfinite(x).all() & (x >= 0).all()  # noqa: E731
    counters_ok = (nonneg(carry.complex_count) & nonneg(carry.pms_created)
                   & nonneg(carry.pms_shed) & nonneg(carry.shed_calls)
                   & nonneg(carry.overflow) & nonneg(carry.ebl_dropped)
                   & (carry.ebl_frac >= 0).all()
                   & (carry.ebl_frac <= 1).all())
    finite_obs = _all_finite(carry.obs_counts, carry.obs_rewards)
    return jnp.stack([finite_time, finite_ring, state_ok & ptr_ok,
                      counters_ok, finite_obs])


def _model_checks(model: eng.EngineModel) -> jax.Array:
    """(len(MODEL_CHECKS),) bool vector for the deployed model."""
    finite_tables = (jnp.isfinite(model.ut_tables).all()
                     & (model.ut_bins >= 1).all())
    finite_lat = _all_finite(model.f_model.a, model.f_model.b,
                             model.g_model.a, model.g_model.b)
    finite_params = _all_finite(model.proc_cost, model.ebl_raw_mean)
    return jnp.stack([finite_tables, finite_lat, finite_params])


carry_check_vec = jax.jit(_carry_checks)
model_check_vec = jax.jit(_model_checks)
carry_check_lanes = jax.jit(jax.vmap(_carry_checks))
model_check_lanes = jax.jit(jax.vmap(_model_checks))


def _trim_one(cfg: eng.EngineConfig, model: eng.EngineModel,
              carry: eng.Carry, i: jax.Array, frac: jax.Array) -> eng.Carry:
    n_active = carry.pms.active.sum().astype(jnp.float32)
    rho = jnp.ceil(frac * n_active).astype(jnp.int32)
    return eng._shed_now(cfg, model, carry, i, rho)[0]


trim_store = jax.jit(_trim_one, static_argnames=("cfg",))


@functools.partial(jax.jit, static_argnames=("cfg",))
def trim_store_lanes(cfg: eng.EngineConfig, model: eng.EngineModel,
                     carry: eng.Carry, i: jax.Array,
                     frac: jax.Array) -> eng.Carry:
    return jax.vmap(lambda m, c: _trim_one(cfg, m, c, i, frac))(model,
                                                                carry)


@dataclasses.dataclass
class GuardViolation:
    scope: str              # "carry" | "model"
    failed: list[str]       # CARRY_CHECKS / MODEL_CHECKS names that failed
    lane: int | None = None

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


def _host_copy(tree):
    """True host copies of every leaf — the live arrays are donated into
    the next chunk, so ``np.asarray`` (possibly zero-copy on CPU) is NOT
    safe here."""
    return jax.tree.map(lambda x: np.array(x), tree)


def _to_device(tree):
    return jax.tree.map(jnp.asarray, tree)


class CarryGuard:
    """Invariant checks + last-good checkpoint for one runtime's state.

    ``lanes=None`` guards a single-tenant carry; ``lanes=L`` expects
    lane-stacked carry/model pytrees and checks/restores PER LANE, so one
    poisoned tenant never resets its neighbors.
    """

    def __init__(self, cfg: GuardConfig, lanes: int | None = None):
        self.cfg = cfg
        self.lanes = lanes
        # (carry_np, model_np, chunk_i, control) — ``control`` is the
        # runtime's host-side control state in the durable snapshot
        # codec's JSON form (repro.runtime.persist), captured at save so
        # a restore rewinds the ladder rung/streaks and admission state
        # along with the arrays, not just the pytrees.
        self._ckpt: tuple | None = None
        self.checks_run = 0
        self.violations = 0
        self.restores = 0
        self.checkpoints = 0

    @property
    def has_checkpoint(self) -> bool:
        return self._ckpt is not None

    @property
    def checkpoint_chunk(self) -> int | None:
        return None if self._ckpt is None else self._ckpt[2]

    @property
    def checkpoint_control(self) -> dict | None:
        return None if self._ckpt is None else self._ckpt[3]

    @property
    def checkpoint(self) -> tuple | None:
        """(carry_np, model_np, chunk_i, control) — read by the durable
        snapshot so a recovered process keeps its last good rollback."""
        return self._ckpt

    def save(self, carry: eng.Carry, model: eng.EngineModel,
             chunk_i: int, control: dict | None = None) -> None:
        self._ckpt = (_host_copy(carry), _host_copy(model), int(chunk_i),
                      control)
        self.checkpoints += 1

    def load_checkpoint(self, carry_np, model_np, chunk_i: int,
                        control: dict | None) -> None:
        """Install an externally decoded checkpoint (snapshot recovery);
        does NOT count as a new checkpoint."""
        self._ckpt = (carry_np, model_np, int(chunk_i), control)

    def check(self, carry: eng.Carry,
              model: eng.EngineModel) -> list[GuardViolation]:
        """Run the fused on-device checks; returns [] when healthy."""
        self.checks_run += 1
        out: list[GuardViolation] = []
        if self.lanes is None:
            cv = np.asarray(carry_check_vec(carry))
            mv = np.asarray(model_check_vec(model))
            if not cv.all():
                out.append(GuardViolation("carry", [
                    CARRY_CHECKS[i] for i in np.nonzero(~cv)[0]]))
            if not mv.all():
                out.append(GuardViolation("model", [
                    MODEL_CHECKS[i] for i in np.nonzero(~mv)[0]]))
        else:
            cv = np.asarray(carry_check_lanes(carry))
            mv = np.asarray(model_check_lanes(model))
            for lane in range(self.lanes):
                if not cv[lane].all():
                    out.append(GuardViolation("carry", [
                        CARRY_CHECKS[i]
                        for i in np.nonzero(~cv[lane])[0]], lane=lane))
                if not mv[lane].all():
                    out.append(GuardViolation("model", [
                        MODEL_CHECKS[i]
                        for i in np.nonzero(~mv[lane])[0]], lane=lane))
        self.violations += len(out)
        return out

    def restore(self, carry: eng.Carry, model: eng.EngineModel,
                lanes: list[int] | None = None
                ) -> tuple[eng.Carry, eng.EngineModel]:
        """Reset state from the last good checkpoint.  With ``lanes`` only
        those lanes roll back (lane-stacked pytrees); everyone else keeps
        their live state bit-for-bit."""
        if self._ckpt is None:
            raise RuntimeError("CarryGuard.restore called before any "
                               "checkpoint was saved")
        ck_carry, ck_model = self._ckpt[0], self._ckpt[1]
        self.restores += 1
        if lanes is None or self.lanes is None:
            return _to_device(ck_carry), _to_device(ck_model)

        def merge(cur, ck):
            host = np.array(cur)
            host[np.asarray(lanes)] = ck[np.asarray(lanes)]
            return jnp.asarray(host)

        return (jax.tree.map(merge, carry, ck_carry),
                jax.tree.map(merge, model, ck_model))

    def counters(self) -> dict:
        return {"checks_run": self.checks_run,
                "violations": self.violations,
                "restores": self.restores,
                "checkpoints": self.checkpoints}

    def restore_counters(self, d: dict) -> None:
        """Reload the forensic counters from a durable snapshot."""
        self.checks_run = int(d["checks_run"])
        self.violations = int(d["violations"])
        self.restores = int(d["restores"])
        self.checkpoints = int(d["checkpoints"])
