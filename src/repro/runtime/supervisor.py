"""Process-level chaos harness: SIGKILL + restart + bitwise recovery
(DESIGN.md §13).

The in-process fault matrix (bench_faults) proves the runtime survives
corrupted STATE; this module proves it survives losing the PROCESS.  A
child worker runs a persist-enabled :class:`MatchRuntime` over a seeded
workload with a kill switch armed at one of the instrumented sites
(``faults.KILL_SITES``: mid-chunk, mid-refresh, mid-snapshot-write).
The supervisor launches it, watches it die with SIGKILL, relaunches it
WITHOUT the switch, and the restarted child recovers from the newest
valid snapshot + WAL tail and finishes the stream.  The final report —
carry sha256, telemetry counters, decoded match sets — must be bitwise
identical to an uninterrupted run, which bench_recovery checks across
every backend × shedder cell.

The child is this module run as ``__main__`` (``python -m
repro.runtime.supervisor --child``): kill specs travel in the
``PSPICE_KILL`` environment variable so the harness exercises the same
entry path an external process manager would use.
"""
from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os
import signal
import subprocess
import sys

import jax
import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro.runtime import chunker, faults as FT, persist as PS
from repro.runtime import service as RT

# Simulated-cost scale matching benchmarks/bench_faults.py: chunk wall
# times land in the ladder's measurable range on small chaos workloads.
COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4,
            c_shed_pm=1.5e-6, c_ebl=6e-5)


class MatchRuntime(RT.StreamRuntime):
    """StreamRuntime that accumulates decoded match identities.

    Matches emitted BEFORE a snapshot are not re-emitted by WAL replay
    (replay starts at the snapshot), so the accumulator rides inside the
    snapshot via the ``_persist_extra`` hook — exactly the pattern an
    exactly-once downstream sink needs.  Requires ``cfg.emit_matches``
    and forces ``group_chunks=1`` (match decode is per chunk).
    """

    def __init__(self, cfg, model, rt, **kw):
        if not cfg.emit_matches:
            raise ValueError("MatchRuntime needs cfg.emit_matches")
        rt = dataclasses.replace(rt, group_chunks=1)
        super().__init__(cfg, model, rt, **kw)
        self.matches: list[set[tuple]] = [set() for _ in
                                          range(cfg.num_patterns)]

    def _run(self, chunk, start):
        carry, outs = super()._run(chunk, start)
        # Set-union is idempotent, so a chunk that ran but died before
        # its snapshot re-absorbs the same identities on replay.
        for p, s in enumerate(eng.match_sets(outs, start)):
            self.matches[p] |= s
        return carry, outs

    def _run_group(self, start, piece, n_chunks):  # group_chunks == 1
        raise AssertionError("MatchRuntime must run chunk-at-a-time")

    def _persist_extra(self) -> dict:
        return {"matches": [sorted([list(map(int, m)) for m in s])
                            for s in self.matches]}

    def _persist_restore_extra(self, extra: dict) -> None:
        if "matches" in extra:
            self.matches = [{tuple(m) for m in s}
                            for s in extra["matches"]]


def build_workload(spec: dict):
    """Seeded (specs, cfg, model, events) — every knob from the spec
    dict, so the parent, the killed child and the restarted child build
    the IDENTICAL workload from the JSON spec alone."""
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(
        cp, max_pms=spec["max_pms"], latency_bound=0.005,
        gather_stats=True, emit_matches=True, shedder=spec["shedder"],
        backend=spec["backend"], block_events=spec.get("block_events", 16),
        **COST)
    model = eng.make_model(cp, cfg)
    rate = spec.get("rate_mult", 3.0) / (cfg.c_base
                                         + cfg.c_match * 0.3 * cfg.max_pms)
    raw = streams.gen_stock(spec["n"], num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=101)
    ev = streams.classify(specs, raw, rate=rate, seed=7)
    return specs, cfg, model, ev


def runtime_config(spec: dict, persist_dir: str | None) -> RT.RuntimeConfig:
    return RT.RuntimeConfig(
        chunk_size=spec["chunk"],
        refresh=RT.RF.RefreshConfig(
            every_chunks=spec.get("refresh_every", 4),
            min_observations=spec.get("min_observations", 64.0)),
        ingest=RT.IG.IngestConfig(max_queue_events=1 << 15,
                                  high_watermark=1 << 13,
                                  low_watermark=1 << 11, seed=5),
        ladder=RT.LadderConfig(escalate_streak=2, deescalate_streak=2,
                               latency_bound=0.01),
        guard=RT.GD.GuardConfig(check_every_chunks=1,
                                checkpoint_every_chunks=4),
        persist=None if persist_dir is None else PS.PersistConfig(
            dir=persist_dir,
            snapshot_every_chunks=spec.get("snapshot_every", 4)))


def carry_sha(srt: RT.StreamRuntime) -> str:
    h = hashlib.sha256()
    for leaf in jax.tree.leaves(srt.carry):
        h.update(np.asarray(leaf).tobytes())
    return h.hexdigest()


# Wall-clock aggregate fields: real time, not recovered state — excluded
# from every divergence comparison.
WALL_FIELDS = ("wall_s", "refresh_wall_s", "events_per_s")


def semantic_counters(srt: RT.StreamRuntime) -> dict:
    return {k: v for k, v in srt.telemetry.aggregate().items()
            if k not in WALL_FIELDS}


def run_service(spec: dict, persist_dir: str | None = None,
                telemetry_dump: str | None = None) -> dict:
    """One worker lifetime: recover (or cold-start), push the remaining
    stream, flush, report.  A cold start and a post-crash restart are THE
    SAME code path — recovery with an empty directory is a no-op."""
    specs, cfg, model, ev = build_workload(spec)
    srt = MatchRuntime(cfg, model, runtime_config(spec, persist_dir),
                       specs=specs)
    recovery = None
    if persist_dir is not None:
        recovery = srt.recover_from_disk()
        if recovery["replayed_records"] or recovery["snapshot_chunk"] \
                is not None:
            # Satellite hook: a REAL recovery dumps the restored
            # telemetry for post-mortem before new chunks dilute it.
            dump = telemetry_dump or os.path.join(
                persist_dir, "telemetry_recovered.json")
            with open(dump, "w") as f:
                json.dump(srt.telemetry.to_json(), f)
    # Resume the push loop after the last durable record: record ids are
    # global and one push == one record, so the WAL length IS the cursor.
    push = spec["push"]
    start_push = 0 if persist_dir is None \
        else srt.persist.wal.next_record_id
    n = chunker.num_events(ev)
    for s in range(start_push * push, n, push):
        srt.push(chunker.slice_events(ev, s, min(s + push, n)))
    srt.flush()
    return {
        "carry_sha": carry_sha(srt),
        "counters": semantic_counters(srt),
        "matches": [sorted([list(map(int, m)) for m in s])
                    for s in srt.matches],
        "events_processed": int(srt.events_processed),
        "recovery": recovery,
    }


def child_main(argv: list[str]) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--spec", required=True, help="workload spec JSON")
    ap.add_argument("--dir", required=True, help="persistence directory")
    ap.add_argument("--out", required=True, help="final report JSON path")
    args = ap.parse_args(argv)
    FT.install_kill_from_env()
    report = run_service(json.loads(args.spec), persist_dir=args.dir)
    PS.atomic_write(args.out,
                    json.dumps(report, sort_keys=True).encode())
    return 0


class Supervisor:
    """Launch the child worker, expect the armed SIGKILL, relaunch until
    the report file appears."""

    def __init__(self, workdir: str):
        self.workdir = workdir
        self.attempts: list[dict] = []

    def _launch(self, spec: dict, out: str, kill: str | None):
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.dirname(os.path.dirname(os.path.dirname(
                os.path.abspath(__file__))))]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        env.setdefault("JAX_PLATFORMS", "cpu")
        env.pop(FT.KILL_ENV, None)
        if kill is not None:
            env[FT.KILL_ENV] = kill
        cmd = [sys.executable, "-m", "repro.runtime.supervisor", "--child",
               "--spec", json.dumps(spec),
               "--dir", os.path.join(self.workdir, "persist"),
               "--out", out]
        return subprocess.run(cmd, env=env, capture_output=True, text=True)

    def run(self, spec: dict, kill: str | None,
            max_restarts: int = 2) -> dict:
        """Returns {report, attempts, killed, recovered}; raises when the
        child fails for any reason other than the armed kill."""
        out = os.path.join(self.workdir, "report.json")
        killed = False
        for attempt in range(max_restarts + 1):
            want_kill = kill if attempt == 0 else None
            proc = self._launch(spec, out, want_kill)
            self.attempts.append({"attempt": attempt, "kill": want_kill,
                                  "returncode": proc.returncode})
            if proc.returncode == 0:
                with open(out, "rb") as f:
                    report = json.loads(f.read())
                return {"report": report, "attempts": self.attempts,
                        "killed": killed,
                        "recovered": killed and attempt > 0}
            if want_kill is not None \
                    and proc.returncode == -signal.SIGKILL:
                killed = True     # the armed crash — restart and recover
                continue
            raise RuntimeError(
                f"child attempt {attempt} failed rc={proc.returncode} "
                f"(kill={want_kill!r}):\n{proc.stderr[-2000:]}")
        raise RuntimeError(f"child did not finish in {max_restarts + 1} "
                           "attempts")


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if argv and argv[0] == "--child":
        return child_main(argv[1:])
    raise SystemExit("repro.runtime.supervisor is the chaos-harness child "
                     "entry point; drive it via benchmarks/"
                     "bench_recovery.py or Supervisor.run")


if __name__ == "__main__":
    sys.exit(main())
