"""Deterministic fault injection for chaos runs (DESIGN.md §12).

Every fault a resilient front-end must survive, as a SEEDED, REPLAYABLE
transformation: stream faults rewrite an ``EventBatch`` before it is
offered to the runtime (bursts, duplicates, reordering, stalls), state
faults corrupt the live carry or model between chunks (NaN/Inf into the
refresh accumulators or utility tables, latency spikes, lane poison).
All randomness comes from one ``np.random.default_rng(seed)``, and every
applied fault is appended to ``FaultInjector.log`` — two injectors with
the same seed and call sequence produce bit-identical chaos, which is
what lets ``benchmarks/bench_faults.py`` gate CI on exact outcomes.

Stream faults preserve the arrival-time monotonicity the engine's
simulated-time model assumes (a burst COMPRESSES gaps, a stall inserts a
silence then a pile-up); what they stress is the rate the admission
controller and shedder see, not the data-layer contract.
"""
from __future__ import annotations

import dataclasses
import os
import signal

import jax
import jax.numpy as jnp
import numpy as np

from repro.cep import engine as eng
from repro.runtime.chunker import num_events

STREAM_FAULTS = ("burst", "duplicate", "reorder", "stall")
STATE_FAULTS = ("nan_refresh", "table_corrupt", "lane_poison",
                "latency_spike")
# Process faults kill the WHOLE process (SIGKILL: no handlers, no atexit)
# at a seeded site — the fault the durable persistence layer exists for
# (DESIGN.md §13).  They are planned via ``FaultInjector.plan_kill`` and
# executed by a ``KillSwitch`` armed at the module's kill points.
PROCESS_FAULTS = ("process_kill",)
FAULT_KINDS = STREAM_FAULTS + STATE_FAULTS + PROCESS_FAULTS

# Instrumented death sites: after a chunk's device dispatch returns but
# before its host bookkeeping lands; after the refresh cadence check
# fires; and inside the snapshot writer (which dies mid-write, leaving a
# deliberately torn file for recovery to CRC-reject).
KILL_SITES = ("chunk", "refresh", "snapshot")
KILL_ENV = "PSPICE_KILL"   # "site:after" spec for subprocess children


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    kinds: tuple[str, ...] = FAULT_KINDS
    seed: int = 0
    p_fault: float = 0.5       # per-call chance each enabled fault fires
    burst_factor: float = 8.0  # arrival-gap compression inside a burst
    burst_len: int = 256
    dup_len: int = 64
    reorder_len: int = 128
    stall_gap_s: float = 0.5   # silence inserted before the pile-up
    spike_s: float = 0.25      # sim-time jump for latency_spike
    nan_frac: float = 0.02     # fraction of entries corrupted to NaN/Inf

    def __post_init__(self):
        unknown = set(self.kinds) - set(FAULT_KINDS)
        if unknown:
            raise ValueError(f"unknown fault kinds {sorted(unknown)}; "
                             f"expected a subset of {FAULT_KINDS}")
        if not 0.0 <= self.p_fault <= 1.0:
            raise ValueError("faults.p_fault is a probability and must be "
                             f"in [0, 1]: {self.p_fault}")
        if not 0.0 < self.nan_frac <= 1.0:
            raise ValueError("faults.nan_frac must be in (0, 1]: "
                             f"{self.nan_frac}")


def _np_leaves(events: eng.EventBatch) -> eng.EventBatch:
    return jax.tree.map(lambda x: np.array(x), events)


class FaultInjector:
    """Seeded source of stream/state faults with a replay log."""

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.log: list[dict] = []
        self._call = 0

    def _fires(self, kind: str) -> bool:
        # The rng draw happens for every ENABLED kind so the stream of
        # random numbers — and hence the replay — depends only on cfg.
        return kind in self.cfg.kinds \
            and self.rng.random() < self.cfg.p_fault

    def _note(self, kind: str, **detail) -> None:
        self.log.append({"call": self._call, "kind": kind, **detail})

    # -- stream faults -----------------------------------------------------
    def corrupt_events(self, events: eng.EventBatch,
                       axis: int = 0) -> eng.EventBatch:
        """Apply whichever enabled stream faults fire to one push batch.
        ``axis`` is the event axis (1 for lane-stacked batches; lane
        leaves share the fault, like a front-end-wide hiccup would)."""
        self._call += 1
        n = num_events(events, axis)
        if n < 4:
            return events
        ev = _np_leaves(events)
        # arrival's event axis is its LAST for both layouts ((n,) / (L, n))
        # so the burst/stall transforms below can index axis=-1.
        arrival = np.array(ev.arrival)
        if self._fires("duplicate"):
            m = min(self.cfg.dup_len, n // 2)
            s = int(self.rng.integers(0, n - m))
            # Each event of the window delivered twice IN PLACE, so the
            # duplicated arrivals stay monotone (at-least-once delivery).
            idx = np.concatenate([np.arange(0, s),
                                  np.repeat(np.arange(s, s + m), 2),
                                  np.arange(s + m, n)])
            ev = _take_rows(ev, idx, axis)
            arrival = np.array(ev.arrival)
            n = idx.size
            self._note("duplicate", start=s, len=m)
        if self._fires("reorder"):
            m = min(self.cfg.reorder_len, n // 2)
            s = int(self.rng.integers(0, n - m))
            perm = np.arange(n)
            perm[s:s + m] = s + self.rng.permutation(m)
            # Reorder payloads only; arrivals keep their monotone order
            # (out-of-order CONTENT at in-order timestamps).
            old_arrival = arrival.copy()
            ev = _take_rows(ev, perm, axis)
            ev = ev._replace(arrival=old_arrival)
            arrival = old_arrival
            self._note("reorder", start=s, len=m)
        if self._fires("burst"):
            m = min(self.cfg.burst_len, n // 2)
            s = int(self.rng.integers(0, n - m))
            arrival = _compress_gaps(arrival, s, m, self.cfg.burst_factor)
            ev = ev._replace(arrival=arrival)
            self._note("burst", start=s, len=m,
                       factor=self.cfg.burst_factor)
        if self._fires("stall"):
            m = min(self.cfg.burst_len, n // 2)
            s = int(self.rng.integers(0, n - m))
            arrival = _stall(arrival, s, m, self.cfg.stall_gap_s)
            ev = ev._replace(arrival=arrival)
            self._note("stall", start=s, len=m, gap=self.cfg.stall_gap_s)
        return jax.tree.map(jnp.asarray, ev)

    # -- state faults ------------------------------------------------------
    def corrupt_carry(self, carry: eng.Carry,
                      lane: int | None = None) -> eng.Carry:
        """Whichever enabled carry faults fire, applied between chunks.
        ``lane`` targets one lane of a lane-stacked carry."""
        self._call += 1
        at = (lambda x, v: x.at[lane].set(v)) if lane is not None \
            else (lambda x, v: jnp.asarray(v, x.dtype))
        if self._fires("nan_refresh"):
            oc = np.array(carry.obs_counts if lane is None
                          else carry.obs_counts[lane])
            flat = oc.reshape(-1)
            k = max(1, int(self.cfg.nan_frac * flat.size))
            flat[self.rng.choice(flat.size, size=k, replace=False)] = np.nan
            carry = carry._replace(
                obs_counts=carry.obs_counts.at[lane].set(oc)
                if lane is not None else jnp.asarray(oc))
            ring = np.array(carry.lat_samples_l if lane is None
                            else carry.lat_samples_l[lane])
            ring[self.rng.integers(0, ring.shape[-1])] = np.inf
            carry = carry._replace(
                lat_samples_l=carry.lat_samples_l.at[lane].set(ring)
                if lane is not None else jnp.asarray(ring))
            self._note("nan_refresh", lane=lane, n_nan=k)
        if self._fires("latency_spike"):
            st = carry.sim_time[lane] if lane is not None \
                else carry.sim_time
            carry = carry._replace(
                sim_time=at(carry.sim_time, st + self.cfg.spike_s))
            self._note("latency_spike", lane=lane, spike=self.cfg.spike_s)
        if self._fires("lane_poison"):
            carry = carry._replace(
                sim_time=at(carry.sim_time, jnp.nan),
                ema_gap=at(carry.ema_gap, jnp.nan))
            self._note("lane_poison", lane=lane)
        return carry

    def corrupt_model(self, model: eng.EngineModel,
                      lane: int | None = None) -> eng.EngineModel:
        """NaN/Inf into the deployed utility tables + latency regression
        (what a bad refresh would deploy if the gate missed it)."""
        self._call += 1
        if not self._fires("table_corrupt"):
            return model
        ut = np.array(model.ut_tables if lane is None
                      else model.ut_tables[lane])
        flat = ut.reshape(-1)
        k = max(1, int(self.cfg.nan_frac * flat.size))
        pick = self.rng.choice(flat.size, size=k, replace=False)
        flat[pick[::2]] = np.nan
        flat[pick[1::2]] = np.inf
        model = model._replace(
            ut_tables=model.ut_tables.at[lane].set(ut)
            if lane is not None else jnp.asarray(ut))
        f = model.f_model
        bad_a = f.a.at[lane].set(jnp.nan) if lane is not None \
            else jnp.full_like(f.a, jnp.nan)
        model = model._replace(
            f_model=type(f)(a=bad_a, b=f.b, kind=f.kind))
        self._note("table_corrupt", lane=lane, n_bad=k)
        return model

    # -- process faults ----------------------------------------------------
    def plan_kill(self, site: str, lo: int = 1, hi: int = 4
                  ) -> "KillSwitch":
        """Seeded kill plan: SIGKILL on the Nth hit of ``site`` with
        N ~ U[lo, hi] drawn from the injector's own rng stream (logged,
        so the same seed plans the same death)."""
        if "process_kill" not in self.cfg.kinds:
            raise ValueError("plan_kill needs 'process_kill' in "
                             f"FaultConfig.kinds: {self.cfg.kinds}")
        if lo < 1 or hi < lo:
            raise ValueError(f"plan_kill needs 1 <= lo <= hi: [{lo},{hi}]")
        self._call += 1
        after = int(self.rng.integers(lo, hi + 1))
        self._note("process_kill", site=site, after=after)
        return KillSwitch(site, after)


class KillSwitch:
    """Dies by SIGKILL on the Nth hit of one instrumented site.

    Installed per process (``install_kill_switch`` or the ``PSPICE_KILL``
    env spec, which is how the supervisor arms a child); the runtime's
    kill points cost one None-check when no switch is armed, so the
    production path stays untouched.
    """

    def __init__(self, site: str, after: int):
        if site not in KILL_SITES:
            raise ValueError(f"unknown kill site {site!r}; expected one "
                             f"of {KILL_SITES}")
        if after < 1:
            raise ValueError(f"kill after-count must be >= 1: {after}")
        self.site = site
        self.after = int(after)
        self.hits = 0

    @classmethod
    def from_spec(cls, spec: str) -> "KillSwitch":
        site, _, after = spec.partition(":")
        return cls(site, int(after or 1))

    def spec(self) -> str:
        return f"{self.site}:{self.after}"

    def pending(self, site: str) -> bool:
        """Count a hit of ``site``; True exactly when it is time to die
        (callers with pre-death work — the torn snapshot write — check
        this and then call ``kill``)."""
        if site != self.site:
            return False
        self.hits += 1
        return self.hits == self.after

    def kill(self) -> None:
        os.kill(os.getpid(), signal.SIGKILL)
        os._exit(137)   # unreachable on POSIX; belt and braces


_KILL: KillSwitch | None = None


def install_kill_switch(ks: KillSwitch | None) -> KillSwitch | None:
    """Arm (or with None, disarm) the process kill switch; returns the
    previously armed one."""
    global _KILL
    prev, _KILL = _KILL, ks
    return prev


def active_kill_switch() -> KillSwitch | None:
    return _KILL


def install_kill_from_env(environ=os.environ) -> KillSwitch | None:
    """Arm from the ``PSPICE_KILL=site:after`` env spec if present — the
    supervisor's channel into its subprocess children."""
    spec = environ.get(KILL_ENV)
    if not spec:
        return None
    ks = KillSwitch.from_spec(spec)
    install_kill_switch(ks)
    return ks


def kill_point(site: str) -> None:
    """Instrumented death site: a no-op unless an armed switch's count
    expires here, in which case the process dies by SIGKILL NOW."""
    if _KILL is not None and _KILL.pending(site):
        _KILL.kill()


def _take_rows(ev: eng.EventBatch, idx: np.ndarray,
               axis: int) -> eng.EventBatch:
    return jax.tree.map(lambda x: np.take(x, idx, axis=axis), ev)


def _compress_gaps(arrival: np.ndarray, s: int, m: int,
                   factor: float) -> np.ndarray:
    """Divide inter-arrival gaps inside [s, s+m) by ``factor`` and shift
    the tail down so the sequence stays monotone — an instantaneous rate
    multiplication, the paper's canonical overload."""
    a = arrival.copy()
    seg = np.take(a, np.arange(s, s + m), axis=-1)
    first = np.take(seg, [0], axis=-1)
    compressed = first + (seg - first) / factor
    delta = np.take(seg, [-1], axis=-1) - np.take(compressed, [-1], axis=-1)
    idx_seg = [slice(None)] * (a.ndim - 1) + [slice(s, s + m)]
    idx_tail = [slice(None)] * (a.ndim - 1) + [slice(s + m, None)]
    a[tuple(idx_seg)] = compressed
    a[tuple(idx_tail)] = a[tuple(idx_tail)] - delta
    return a


def _stall(arrival: np.ndarray, s: int, m: int, gap: float) -> np.ndarray:
    """A silence of ``gap`` seconds at index ``s``, then the stalled
    events arrive in a pile-up (all at once), then the stream resumes
    shifted — what a stuck upstream producer looks like."""
    a = arrival.copy()
    idx_seg = [slice(None)] * (a.ndim - 1) + [slice(s, s + m)]
    idx_tail = [slice(None)] * (a.ndim - 1) + [slice(s + m, None)]
    pile = np.take(a, [s], axis=-1) + gap
    a[tuple(idx_seg)] = np.broadcast_to(pile, a[tuple(idx_seg)].shape)
    a[tuple(idx_tail)] = a[tuple(idx_tail)] + gap
    return a
