"""Online model refresh between chunks (paper §III-C/§III-D; DESIGN.md §7).

The monolithic runner builds the Markov/utility model once from a warm-up
run.  A continuously running operator must keep adapting: stream statistics
drift, so the transition matrices — and with them the completion
probabilities, remaining-time tables and the latency regression ``f`` —
go stale.  Chunk boundaries give the host a natural cadence: the engine's
carry already accumulates ``obs_counts`` / ``obs_rewards`` (when
``gather_stats`` is on) and the ``(n_pm, t_proc)`` latency ring, so a
refresh is a pure re-estimation from the carry, no extra stream pass.

Refreshes are gated twice: a minimum observation count (don't fit noise)
and an optional drift threshold on the transition-matrix MSE between the
deployed and freshly-estimated chains (``markov.needs_retraining``, §III-D)
so stable streams skip the rebuild cost.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.core import markov, overload as ovl, utility as util


@dataclasses.dataclass(frozen=True)
class RefreshConfig:
    every_chunks: int = 4          # cadence; <= 0 disables refresh
    min_observations: float = 256.0  # total transition obs before first fit
    drift_threshold: float = 0.0   # max per-pattern T-MSE gate; 0 = always
    bin_size: int = 64
    use_remaining_time: bool = True
    refit_latency: bool = True     # refit f from the carry's latency ring
    decay: float = 1.0             # obs decay applied after each refresh
                                   # (<1 = exponential forgetting, so the
                                   # model tracks drift instead of the
                                   # all-time average)


@dataclasses.dataclass
class RefreshState:
    """What the refresher remembers between invocations."""
    last_T: np.ndarray | None = None   # (P, M, M) deployed transition chains
    refresh_count: int = 0
    skipped_drift: int = 0
    skipped_obs: int = 0
    skipped_nonfinite: int = 0   # NaN-safe gate fired (DESIGN.md §12)

    def to_control(self) -> dict:
        """JSON control form for the durable snapshot codec.  float32 →
        Python float → float32 is exact (repr round-trip), so the drift
        gate computes the same MSE after recovery."""
        lt = None
        if self.last_T is not None:
            lt = {"dtype": self.last_T.dtype.str,
                  "shape": list(self.last_T.shape),
                  "data": self.last_T.reshape(-1).tolist()}
        return {"last_T": lt, "refresh_count": self.refresh_count,
                "skipped_drift": self.skipped_drift,
                "skipped_obs": self.skipped_obs,
                "skipped_nonfinite": self.skipped_nonfinite}

    @classmethod
    def from_control(cls, d: dict) -> "RefreshState":
        lt = d["last_T"]
        arr = None if lt is None else np.asarray(
            lt["data"], dtype=np.dtype(lt["dtype"])).reshape(lt["shape"])
        return cls(last_T=arr,
                   refresh_count=int(d["refresh_count"]),
                   skipped_drift=int(d["skipped_drift"]),
                   skipped_obs=int(d["skipped_obs"]),
                   skipped_nonfinite=int(d["skipped_nonfinite"]))


def table_width(specs: Sequence[pat.PatternSpec], bin_size: int) -> int:
    """Bins a refreshed utility table will occupy: max ceil(ws/bs)."""
    return max(1, max(-(-s.window_size // bin_size) for s in specs))


def prepare_model(specs: Sequence[pat.PatternSpec], model: eng.EngineModel,
                  rcfg: RefreshConfig) -> eng.EngineModel:
    """Pre-widen ``ut_tables`` to the width refresh will produce.

    A refresh must never change the model pytree's shapes — that would
    retrace the chunk executable mid-stream (seconds of compile hidden in
    a steady-state loop).  Widening up front (edge-replicated bins, a
    no-op for lookups) keeps every post-refresh chunk on the original
    executable.  Works on single and lane-stacked models (the bin axis is
    always second-to-last).
    """
    width = table_width(specs, rcfg.bin_size)
    cur = model.ut_tables.shape[-2]
    if cur >= width:
        return model
    pad = [(0, 0)] * model.ut_tables.ndim
    pad[-2] = (0, width - cur)
    return model._replace(ut_tables=jnp.pad(model.ut_tables, pad,
                                            mode="edge"))


def estimate_chains(specs: Sequence[pat.PatternSpec], cfg: eng.EngineConfig,
                    obs_counts, obs_rewards):
    """Per-pattern (T, R) from the carry's accumulated observations."""
    Ts, Rs = [], []
    for p, spec in enumerate(specs):
        m = spec.num_states
        stats = markov.TransitionStats(
            counts=jnp.asarray(obs_counts[p, :m, :m]),
            reward_sum=jnp.asarray(obs_rewards[p, :m, :m]))
        Ts.append(markov.estimate_transition_matrix(stats))
        Rs.append(markov.estimate_reward_matrix(
            stats, default_reward=cfg.c_match * float(spec.proc_cost)))
    return Ts, Rs


def _stack_T(Ts, max_states: int) -> np.ndarray:
    out = np.zeros((len(Ts), max_states, max_states), np.float32)
    for p, T in enumerate(Ts):
        m = T.shape[0]
        out[p, :m, :m] = np.asarray(T)
    return out


def refit_latency_model(carry: eng.Carry) -> ovl.LatencyModel:
    """Refit f: n_pm -> l_p from the carry's rolling latency ring.

    ``lat_ptr`` increments once per event and, on a multi-billion-event
    stream, wraps negative (int32); by then the ring has long been full,
    so a wrapped pointer means every slot is valid — without the guard
    the mask would go all-zero and the fit would degenerate.
    """
    S = carry.lat_samples_n.shape[0]
    n_valid = jnp.where(carry.lat_ptr < 0, S,
                        jnp.minimum(carry.lat_ptr, S))
    valid = jnp.arange(S) < n_valid
    return ovl.fit_latency_model(carry.lat_samples_n, carry.lat_samples_l,
                                 valid)


def refresh_model(specs: Sequence[pat.PatternSpec], cfg: eng.EngineConfig,
                  model: eng.EngineModel, carry: eng.Carry,
                  rcfg: RefreshConfig, state: RefreshState,
                  ) -> tuple[eng.EngineModel, eng.Carry, bool]:
    """Re-estimate the utility tables (+ latency model) from the carry.

    Returns ``(model, carry, refreshed)``; the carry comes back with its
    observation accumulators decayed by ``rcfg.decay`` when a refresh ran.
    Mutates ``state`` (refresh/skip counters, deployed chains).
    """
    # NaN-safe gate (DESIGN.md §12): a poisoned accumulator must SKIP the
    # refresh, not deploy corrupt tables.  Note `nan < threshold` is False
    # — the min-observation gate alone would wave NaNs straight through.
    obs_c = np.asarray(carry.obs_counts)
    obs_r = np.asarray(carry.obs_rewards)
    if not (np.isfinite(obs_c).all() and np.isfinite(obs_r).all()):
        state.skipped_nonfinite += 1
        return model, carry, False
    total_obs = float(obs_c.sum())
    if total_obs < rcfg.min_observations:
        state.skipped_obs += 1
        return model, carry, False

    Ts, Rs = estimate_chains(specs, cfg, carry.obs_counts, carry.obs_rewards)
    fresh = _stack_T(Ts, cfg.max_states)
    if rcfg.drift_threshold > 0 and state.last_T is not None:
        mse = float(max(
            markov.transition_matrix_mse(jnp.asarray(state.last_T[p]),
                                         jnp.asarray(fresh[p]))
            for p in range(len(specs))))
        if mse <= rcfg.drift_threshold:
            state.skipped_drift += 1
            return model, carry, False

    tables = [util.build_utility_table(
        T, R, window_size=spec.window_size, bin_size=rcfg.bin_size,
        weight=spec.weight, use_remaining_time=rcfg.use_remaining_time)
        for spec, T, R in zip(specs, Ts, Rs)]
    ut_stacked, ut_bins = util.stack_tables(tables,
                                            max_states=cfg.max_states)
    # stack_tables may widen the bin axis vs the deployed model; keep the
    # deployed width so the EngineModel pytree structure (and the compiled
    # chunk executable) never changes mid-stream.
    B = model.ut_tables.shape[1]
    if ut_stacked.shape[1] < B:
        ut_stacked = jnp.pad(
            ut_stacked, ((0, 0), (0, B - ut_stacked.shape[1]), (0, 0)))
    elif ut_stacked.shape[1] > B:
        ut_stacked = ut_stacked[:, :B]
    # Same NaN discipline for the freshly built tables and the latency
    # refit: a non-finite product (e.g. an Inf-polluted latency ring that
    # degenerates the regression) keeps the deployed model.
    if not np.isfinite(np.asarray(ut_stacked)).all():
        state.skipped_nonfinite += 1
        return model, carry, False
    f_model = model.f_model
    if rcfg.refit_latency:
        cand = refit_latency_model(carry)
        if bool(np.isfinite(np.asarray(cand.a)).all()
                and np.isfinite(np.asarray(cand.b)).all()):
            f_model = cand
        else:
            state.skipped_nonfinite += 1
    model = model._replace(ut_tables=ut_stacked, ut_bins=ut_bins,
                           f_model=f_model)
    if rcfg.decay < 1.0:
        carry = carry._replace(obs_counts=carry.obs_counts * rcfg.decay,
                               obs_rewards=carry.obs_rewards * rcfg.decay)
    state.last_T = fresh
    state.refresh_count += 1
    return model, carry, True
