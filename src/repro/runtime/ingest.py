"""Bounded ingestion front-end with admission control (DESIGN.md §12).

The engine's own shedding (pSPICE PM shedding, E-BL input drops) assumes
events have already been ADMITTED into the stream.  A serving front-end
faces an earlier failure mode: the producer outruns the service and the
un-ingested backlog grows without bound.  ``IngestQueue`` sits between
``push`` and the ``ChunkBuffer`` and applies, in order:

1. a token-bucket admission controller clocked by EVENT ARRIVAL TIME (not
   wall clock, so chaos runs replay bit-for-bit): sustained input above
   ``admit_rate`` events/sec sheds the excess uniformly;
2. watermark-based uniform input shedding with hysteresis — above
   ``high_watermark`` queued events a drop probability ramps toward
   ``shed_max`` (eSPICE-style input-level shedding, the ladder rung BELOW
   pSPICE PM shedding), and stays engaged until depth falls back under
   ``low_watermark``;
3. a hard bound: events that would push the queue past
   ``max_queue_events`` are rejected outright and the ``AdmitReport``
   raises its backpressure flag so the caller can slow the producer.

All randomness flows through one ``jax.random`` key split per admission
decision (the engine's stream discipline), so two queues with the same
seed and the same offer sequence admit identical event sets.

``IngestFrontEnd`` runs one queue per tenant lane and re-aligns the
per-lane admitted streams into the lockstep lane-stacked batches
``MultiTenantRuntime`` consumes, substituting NEUTRAL events (class 0,
no window-open: they advance sim-time but can never spawn or complete a
match) for quarantined lanes and ragged tails.
"""
from __future__ import annotations

import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.cep.engine import EventBatch
from repro.runtime import chunker


@dataclasses.dataclass(frozen=True)
class IngestConfig:
    """Static front-end knobs (validated at construction)."""
    max_queue_events: int = 1 << 16   # hard bound; beyond it offers reject
    high_watermark: int = 1 << 14     # shedding engages above this depth
    low_watermark: int = 1 << 12      # ... and disengages below this one
    shed_max: float = 0.9             # watermark shed probability ceiling
    admit_rate: float = 0.0           # events/sec token refill; <= 0 = off
    admit_burst: float = 4096.0       # bucket capacity (events)
    pump_chunks: int = 0              # chunks drained per push; <= 0 = all
    seed: int = 0

    def __post_init__(self):
        if self.max_queue_events < 1:
            raise ValueError("ingest.max_queue_events must be >= 1: "
                             f"{self.max_queue_events}")
        if not (0 <= self.low_watermark <= self.high_watermark
                <= self.max_queue_events):
            raise ValueError(
                "ingest watermarks must satisfy 0 <= low_watermark <= "
                "high_watermark <= max_queue_events: got "
                f"low={self.low_watermark}, high={self.high_watermark}, "
                f"max={self.max_queue_events}")
        if not 0.0 <= self.shed_max <= 1.0:
            raise ValueError("ingest.shed_max is a drop probability and "
                             f"must be in [0, 1]: {self.shed_max}")
        if self.admit_rate > 0 and self.admit_burst < 1.0:
            raise ValueError("ingest.admit_burst must be >= 1 event when "
                             f"admit_rate is on: {self.admit_burst}")


@dataclasses.dataclass
class AdmitReport:
    """One offer's admission outcome (host-side, appended per offer)."""
    offered: int
    admitted: int
    shed: int            # dropped by bucket/watermark/forced shedding
    rejected: int        # dropped by the hard queue bound
    depth: int           # queue depth after the offer
    drop_p: float        # combined drop probability applied
    backpressure: bool   # caller should slow the producer
    quarantined: bool = False


def take_rows(events: EventBatch, idx, axis: int = 0) -> EventBatch:
    """Row-gather every leaf along the event axis (new arrays, owned)."""
    idx = jnp.asarray(np.asarray(idx, np.int32))
    return jax.tree.map(lambda x: jnp.take(x, idx, axis=axis), events)


def neutral_like(events: EventBatch) -> EventBatch:
    """Same-shape events that are inert to every pattern: class 0
    (irrelevant), no window-open, no binding/id.  They still advance
    sim-time by c_base each — a quarantined lane keeps its clock moving
    without matching anything.  Arrival times are preserved."""
    return events._replace(
        ev_class=jnp.zeros_like(events.ev_class),
        ev_bind=jnp.full_like(events.ev_bind, -1),
        ev_open=jnp.zeros_like(events.ev_open),
        ev_id=jnp.full_like(events.ev_id, -1),
        ev_rand=jnp.ones_like(events.ev_rand),   # never E-BL sampled
        ebl_raw=jnp.zeros_like(events.ebl_raw))


class IngestQueue:
    """Bounded FIFO of event batches with seeded admission control."""

    def __init__(self, cfg: IngestConfig, axis: int = 0):
        self.cfg = cfg
        self.axis = axis
        self._queue: collections.deque[EventBatch] = collections.deque()
        self._depth = 0
        self._key = jax.random.PRNGKey(cfg.seed)
        self._tokens = float(cfg.admit_burst)
        self._clock: float | None = None   # last arrival seen (stream time)
        self._shedding = False             # watermark hysteresis latch
        # The degradation ladder's input-shed rung sets this directly.
        self.forced_drop = 0.0
        self.reports: list[AdmitReport] = []
        self.total_offered = 0
        self.total_admitted = 0
        self.total_shed = 0
        self.total_rejected = 0

    @property
    def depth(self) -> int:
        return self._depth

    # -- admission ---------------------------------------------------------
    def _watermark_p(self) -> float:
        c = self.cfg
        if self._shedding:
            if self._depth < c.low_watermark:
                self._shedding = False
        elif self._depth > c.high_watermark:
            self._shedding = True
        if not self._shedding:
            return 0.0
        span = max(c.max_queue_events - c.low_watermark, 1)
        frac = (self._depth - c.low_watermark) / span
        return min(c.shed_max, c.shed_max * frac)

    def _bucket_p(self, n: int, t_now: float) -> float:
        c = self.cfg
        if c.admit_rate <= 0 or n == 0:
            return 0.0
        if self._clock is not None:
            dt = max(0.0, t_now - self._clock)
            self._tokens = min(float(c.admit_burst),
                               self._tokens + dt * c.admit_rate)
        self._clock = t_now
        avail = self._tokens
        return 0.0 if n <= avail else 1.0 - avail / n

    def offer(self, events: EventBatch) -> AdmitReport:
        """Admit (a uniform subset of) ``events`` into the queue."""
        n = chunker.num_events(events, self.axis)
        t_now = float(np.max(np.asarray(events.arrival))) if n else 0.0
        p = max(self._watermark_p(), self._bucket_p(n, t_now),
                float(self.forced_drop))
        if n == 0:
            rep = AdmitReport(0, 0, 0, 0, self._depth, p,
                              self._depth > self.cfg.high_watermark)
            self.reports.append(rep)
            return rep
        if p >= 1.0:
            kept = 0
            events = None
        elif p > 0.0:
            self._key, sub = jax.random.split(self._key)
            keep = jax.random.uniform(sub, (n,)) >= p
            idx = np.nonzero(np.asarray(keep))[0]
            kept = int(idx.size)
            events = take_rows(events, idx, self.axis) if kept else None
        else:
            kept = n
        shed = n - kept
        # Hard bound: reject what does not fit (drop-from-tail).
        room = self.cfg.max_queue_events - self._depth
        rejected = max(0, kept - room)
        if rejected:
            keep_n = kept - rejected
            events = chunker.slice_events(events, 0, keep_n, self.axis) \
                if keep_n else None
            kept = keep_n
        if kept:
            self._queue.append(events)
            self._depth += kept
            self._tokens = max(0.0, self._tokens - kept)
        rep = AdmitReport(
            offered=n, admitted=kept, shed=shed, rejected=rejected,
            depth=self._depth, drop_p=float(p),
            backpressure=rejected > 0
            or self._depth > self.cfg.high_watermark)
        self.reports.append(rep)
        self.total_offered += n
        self.total_admitted += kept
        self.total_shed += shed
        self.total_rejected += rejected
        return rep

    # -- drain -------------------------------------------------------------
    def take(self, max_events: int | None = None,
             drain: bool = False) -> EventBatch | None:
        """Dequeue up to ``max_events`` admitted events in arrival order.
        ``drain`` is accepted for signature parity with
        ``IngestFrontEnd.take`` (a single queue has no lane raggedness)."""
        k = self._depth if max_events is None \
            else min(self._depth, max_events)
        if k <= 0:
            return None
        pieces, got = [], 0
        while got < k:
            batch = self._queue[0]
            n = chunker.num_events(batch, self.axis)
            if n <= k - got:
                pieces.append(batch)
                self._queue.popleft()
                got += n
            else:
                cut = k - got
                pieces.append(chunker.slice_events(batch, 0, cut, self.axis))
                self._queue[0] = chunker.slice_events(batch, cut, n,
                                                      self.axis)
                got += cut
        self._depth -= k
        out = pieces[0]
        for p in pieces[1:]:
            out = chunker.concat_events(out, p, self.axis)
        return out

    def purge(self) -> int:
        """Drop everything queued (lane quarantine); returns the count."""
        n = self._depth
        self._queue.clear()
        self._depth = 0
        return n

    # -- durable state (repro.runtime.persist) -----------------------------
    def control_state(self) -> dict:
        """JSON control state: everything a bitwise-identical replay of
        future offers needs — bucket tokens + arrival clock, watermark
        latch, forced drop, the PRNG key, and the counters.  (Queued
        EVENTS travel separately as a snapshot array section; the
        ``reports`` list is in-memory forensics and is not restored.)"""
        return {"tokens": self._tokens, "clock": self._clock,
                "shedding": self._shedding,
                "forced_drop": float(self.forced_drop),
                "key": np.asarray(self._key).tolist(),
                "totals": [self.total_offered, self.total_admitted,
                           self.total_shed, self.total_rejected]}

    def restore_control_state(self, d: dict) -> None:
        self._tokens = float(d["tokens"])
        self._clock = None if d["clock"] is None else float(d["clock"])
        self._shedding = bool(d["shedding"])
        self.forced_drop = float(d["forced_drop"])
        self._key = jnp.asarray(np.asarray(d["key"], dtype=np.uint32))
        (self.total_offered, self.total_admitted, self.total_shed,
         self.total_rejected) = (int(x) for x in d["totals"])

    def queued_events(self) -> EventBatch | None:
        """Everything queued as ONE batch (arrival order), or None."""
        if self._depth == 0:
            return None
        batches = list(self._queue)
        out = batches[0]
        for b in batches[1:]:
            out = chunker.concat_events(out, b, self.axis)
        return out

    def restore_queued(self, events: EventBatch | None) -> None:
        """Reset the queue contents from a snapshot section.  A single
        concatenated batch dequeues identically to the original deque
        (``take`` slices across batch boundaries anyway)."""
        self._queue.clear()
        self._depth = 0
        if events is not None:
            n = chunker.num_events(events, self.axis)
            if n:
                self._queue.append(events)
                self._depth = n


class IngestFrontEnd:
    """Per-lane ``IngestQueue`` set for ``MultiTenantRuntime``.

    Offers accept lane-stacked batches (leading ``(L,)`` axis) and fan out
    per lane; ``take`` re-aligns the admitted streams into a lockstep
    lane-stacked batch.  Because per-lane shedding is independent, lane
    depths diverge — ``take`` dequeues the aligned minimum and leaves the
    rest queued; ``drain=True`` (end of stream) pads short lanes with
    neutral events instead so nothing stays stranded.  Quarantined lanes
    contribute neutral substitutes until their tick count expires.
    """

    def __init__(self, cfg: IngestConfig, num_lanes: int):
        self.cfg = cfg
        self.num_lanes = num_lanes
        self.queues = [
            IngestQueue(dataclasses.replace(cfg, seed=cfg.seed + lane))
            for lane in range(num_lanes)]
        self._quarantine: dict[int, int] = {}   # lane -> remaining offers

    @property
    def depth(self) -> int:
        """Aligned depth: events dequeuable NOW in lockstep."""
        ds = [q.depth for lane, q in enumerate(self.queues)
              if lane not in self._quarantine]
        return min(ds) if ds else 0

    @property
    def max_depth(self) -> int:
        return max((q.depth for lane, q in enumerate(self.queues)
                    if lane not in self._quarantine), default=0)

    @property
    def forced_drop(self) -> float:
        return self.queues[0].forced_drop if self.queues else 0.0

    @forced_drop.setter
    def forced_drop(self, p: float) -> None:
        for q in self.queues:
            q.forced_drop = p

    @property
    def reports(self) -> list[AdmitReport]:
        return [r for q in self.queues for r in q.reports]

    def quarantined_lanes(self) -> list[int]:
        return sorted(self._quarantine)

    def quarantine_lane(self, lane: int, offers: int) -> int:
        """Quarantine ``lane`` for the next ``offers`` offer cycles; its
        queued events are purged and new offers dropped meanwhile."""
        self._quarantine[lane] = max(1, offers)
        return self.queues[lane].purge()

    def offer(self, events_lanes: EventBatch) -> list[AdmitReport]:
        reps = [self.offer_lane(lane,
                                jax.tree.map(lambda x: x[lane],
                                             events_lanes))
                for lane in range(self.num_lanes)]
        return reps

    def offer_lane(self, lane: int, events: EventBatch) -> AdmitReport:
        if lane in self._quarantine:
            n = chunker.num_events(events, 0)
            q = self.queues[lane]
            q.total_offered += n
            q.total_shed += n
            rep = AdmitReport(offered=n, admitted=0, shed=n, rejected=0,
                              depth=0, drop_p=1.0, backpressure=False,
                              quarantined=True)
            q.reports.append(rep)
            self._quarantine[lane] -= 1
            if self._quarantine[lane] <= 0:
                del self._quarantine[lane]
            return rep
        return self.queues[lane].offer(events)

    def take(self, max_events: int | None = None,
             drain: bool = False) -> EventBatch | None:
        active = [lane for lane in range(self.num_lanes)
                  if lane not in self._quarantine]
        if not active:
            return None
        depths = [self.queues[lane].depth for lane in active]
        k = max(depths) if drain else min(depths)
        if max_events is not None:
            k = min(k, max_events)
        if k <= 0:
            return None
        batches: list[EventBatch | None] = [None] * self.num_lanes
        ref = None
        for lane in active:
            b = self.queues[lane].take(k)
            batches[lane] = self._pad_neutral(b, k) if b is not None \
                else None
            if batches[lane] is not None and ref is None:
                ref = batches[lane]
        if ref is None:
            return None
        for lane in range(self.num_lanes):
            if batches[lane] is None:
                batches[lane] = neutral_like(ref)
        return jax.tree.map(lambda *xs: jnp.stack(xs), *batches)

    # -- durable state (repro.runtime.persist) -----------------------------
    def control_state(self) -> dict:
        """Per-lane queue control + the quarantine map; queued events per
        lane travel as separate snapshot sections keyed by lane index."""
        return {"lanes": [q.control_state() for q in self.queues],
                "quarantine": {str(k): int(v)
                               for k, v in self._quarantine.items()}}

    def restore_control_state(self, d: dict) -> None:
        for q, qd in zip(self.queues, d["lanes"]):
            q.restore_control_state(qd)
        self._quarantine = {int(k): int(v)
                            for k, v in d["quarantine"].items()}

    @staticmethod
    def _pad_neutral(events: EventBatch, k: int) -> EventBatch:
        n = chunker.num_events(events, 0)
        if n >= k:
            return events
        # Repeat the last row (keeps arrival monotone), neutralized.
        tail = neutral_like(take_rows(events, np.full(k - n, n - 1)))
        return chunker.concat_events(events, tail)
