"""Per-chunk runtime telemetry (DESIGN.md §7, §8).

Between chunks the host owns control — but the chunk-size overhead budget
(<10% vs the monolithic scan, BENCH_engine.json) leaves no room for the
old per-chunk pattern of four chunk-sized device→host copies plus numpy
percentiles plus half a dozen scalar reads.  All per-chunk reductions now
run ON DEVICE in one fused jit (``device_chunk_stats``) and cross to the
host as a single ~12-float vector per chunk; that transfer doubles as the
synchronization point the wall-clock measurement needs.  The log
aggregates into the throughput headline ``benchmarks/bench_runtime.py``
reports (events/sec, p50/p99 event latency, shed/overflow counters).
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.cep.engine import Carry, StepOut

# Carry accumulator scalars differenced per chunk.
_COUNTERS = ("pms_shed", "shed_calls", "overflow", "ebl_dropped")

# The device_chunk_stats vector layout — the SINGLE place that names the
# slots.  _chunk_stats_device stacks in this order; summarize_chunk and
# counters_from_vec read by name through _VEC.
_VEC_FIELDS = ("l_e_p50", "l_e_p99", "l_e_max", "n_pm_end", "shed_events",
               "dropped_events") + _COUNTERS + ("complex_count",)
_VEC = {name: i for i, name in enumerate(_VEC_FIELDS)}


def counter_snapshot(carry: Carry) -> dict[str, float]:
    """Host copies of the carry's scalar counters (+ total completions).
    Used once per stream for the first chunk's baseline; steady-state
    chunks reuse the counter tail of the previous ``device_chunk_stats``
    vector instead."""
    snap = {k: float(np.asarray(getattr(carry, k)).sum()) for k in _COUNTERS}
    snap["complex_count"] = float(np.asarray(carry.complex_count).sum())
    return snap


@jax.jit
def _chunk_stats_device(outs: StepOut, counters: tuple) -> jax.Array:
    l_e = outs.l_e.reshape(-1)
    if l_e.shape[0] == 0:
        # Zero-length chunk (an empty push/drain): there are no events to
        # reduce over — jnp.quantile/max on an empty axis would produce
        # NaN / raise.  The latency/count slots are zero; the cumulative
        # counter tail still reads the carry so the next chunk's baseline
        # stays correct.  Static shape ⇒ this branch resolves at trace.
        z = jnp.float32(0.0)
        pieces = [z, z, z, z, z, z]
    else:
        qs = jnp.quantile(l_e, jnp.array([0.5, 0.99], l_e.dtype))
        pieces = [qs[0], qs[1], l_e.max(),          # l_e_p50 / p99 / max
                  outs.n_pm[..., -1].sum(),         # n_pm_end
                  outs.shed.sum(), outs.dropped.sum()]
    pieces += [c.sum() for c in counters]       # _COUNTERS + complex_count
    assert len(pieces) == len(_VEC_FIELDS)
    return jnp.stack([jnp.asarray(p).astype(jnp.float32) for p in pieces])


def device_chunk_stats(outs: StepOut, carry: Carry) -> jax.Array:
    """Every per-chunk reduction fused into ONE device computation: l_e
    p50/p99/max, end-of-chunk PM count, shed/dropped event counts, and the
    carry's cumulative counters.  Returns a (11,) f32 vector — the single
    device→host transfer each chunk costs."""
    counters = tuple(getattr(carry, k) for k in _COUNTERS)
    counters += (carry.complex_count,)
    return _chunk_stats_device(outs, counters)


def counters_from_vec(vec: np.ndarray) -> dict[str, float]:
    """The cumulative-counter tail of a ``device_chunk_stats`` vector, in
    ``counter_snapshot``'s format (the next chunk's 'before')."""
    return {k: float(vec[_VEC[k]]) for k in _COUNTERS + ("complex_count",)}


@dataclasses.dataclass
class ChunkStats:
    chunk_index: int
    start: int                  # global index of the chunk's first event
    n_events: int               # events processed (all lanes)
    n_lanes: int
    wall_s: float
    events_per_s: float
    l_e_p50: float
    l_e_p99: float
    l_e_max: float
    n_pm_end: float             # active PMs after the chunk (all lanes)
    shed_events: int            # events at which a shed triggered
    dropped_events: int         # E-BL input drops
    pms_shed: float             # counter deltas over the chunk
    shed_calls: float
    overflow: float
    ebl_dropped: float
    completions: float
    refreshed: bool = False     # model refresh ran after this chunk
    refresh_wall_s: float = 0.0  # host time spent in/gating the refresh
    rung: int = 0               # degradation-ladder rung after this chunk

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class RuntimeEvent:
    """A discrete runtime occurrence (ladder transition, guard violation,
    guard restore, admission backpressure) — the mirror CI's chaos gate
    checks runtime decisions against (DESIGN.md §12)."""
    kind: str            # "ladder" | "guard_violation" | "guard_restore" |
                         # "admission"
    chunk_index: int
    detail: dict = dataclasses.field(default_factory=dict)

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


def summarize_chunk(chunk_index: int, start: int, n_events: int,
                    n_lanes: int, vec: np.ndarray,
                    before: dict[str, float], wall_s: float,
                    refreshed: bool = False,
                    refresh_wall_s: float = 0.0) -> ChunkStats:
    """Stats for one chunk from its ``device_chunk_stats`` vector (the
    chunk's one device→host transfer) + the previous chunk's cumulative
    counters."""
    after = counters_from_vec(vec)
    d = {k: after[k] - before[k] for k in before}
    v = lambda k: float(vec[_VEC[k]])  # noqa: E731
    return ChunkStats(
        chunk_index=chunk_index, start=start, n_events=n_events,
        n_lanes=n_lanes, wall_s=wall_s,
        events_per_s=n_events / max(wall_s, 1e-12),
        l_e_p50=v("l_e_p50"), l_e_p99=v("l_e_p99"), l_e_max=v("l_e_max"),
        n_pm_end=v("n_pm_end"),
        shed_events=int(v("shed_events")),
        dropped_events=int(v("dropped_events")),
        pms_shed=d["pms_shed"], shed_calls=d["shed_calls"],
        overflow=d["overflow"], ebl_dropped=d["ebl_dropped"],
        completions=d["complex_count"], refreshed=refreshed,
        refresh_wall_s=refresh_wall_s,
    )


class TelemetryLog:
    """Append-only chunk log with run-level aggregation."""

    def __init__(self):
        self.chunks: list[ChunkStats] = []
        self.events: list[RuntimeEvent] = []

    def append(self, stats: ChunkStats) -> None:
        self.chunks.append(stats)

    def record_event(self, kind: str, chunk_index: int,
                     detail: dict | None = None) -> RuntimeEvent:
        ev = RuntimeEvent(kind, chunk_index, detail or {})
        self.events.append(ev)
        return ev

    def events_of(self, kind: str) -> list[RuntimeEvent]:
        return [e for e in self.events if e.kind == kind]

    def rows(self) -> list[dict]:
        return [c.to_row() for c in self.chunks]

    def event_rows(self) -> list[dict]:
        return [e.to_row() for e in self.events]

    def to_json(self) -> dict:
        """JSON-able forensic dump: chunk rows + runtime events + the
        aggregate.  Rides inside every durable snapshot
        (repro.runtime.persist) and in the supervisor's dump-on-recovery
        hook, so post-crash telemetry survives the process."""
        return {"chunks": self.rows(), "events": self.event_rows(),
                "aggregate": self.aggregate()}

    @classmethod
    def from_json(cls, d: dict) -> "TelemetryLog":
        """Rebuild a log from ``to_json`` output (the aggregate is
        recomputed from the rows, never trusted)."""
        log = cls()
        log.chunks = [ChunkStats(**row) for row in d.get("chunks", [])]
        log.events = [RuntimeEvent(**row) for row in d.get("events", [])]
        return log

    def aggregate(self) -> dict:
        if not self.chunks:
            return {"n_chunks": 0, "n_events": 0, "events_per_s": 0.0}
        n_events = sum(c.n_events for c in self.chunks)
        # Aggregate throughput charges the host-side refresh time too —
        # per-chunk events_per_s is processing-only.
        wall = sum(c.wall_s + c.refresh_wall_s for c in self.chunks)
        return {
            "n_chunks": len(self.chunks),
            "n_events": n_events,
            "wall_s": wall,
            "refresh_wall_s": sum(c.refresh_wall_s for c in self.chunks),
            "events_per_s": n_events / max(wall, 1e-12),
            "l_e_p50_max": max(c.l_e_p50 for c in self.chunks),
            "l_e_p99_max": max(c.l_e_p99 for c in self.chunks),
            "l_e_max": max(c.l_e_max for c in self.chunks),
            "pms_shed": sum(c.pms_shed for c in self.chunks),
            "shed_calls": sum(c.shed_calls for c in self.chunks),
            "overflow": sum(c.overflow for c in self.chunks),
            "ebl_dropped": sum(c.ebl_dropped for c in self.chunks),
            "completions": sum(c.completions for c in self.chunks),
            "refreshes": sum(1 for c in self.chunks if c.refreshed),
            "max_rung": max(c.rung for c in self.chunks),
            "ladder_transitions": len(self.events_of("ladder")),
            "guard_violations": len(self.events_of("guard_violation")),
            "guard_restores": len(self.events_of("guard_restore")),
        }
