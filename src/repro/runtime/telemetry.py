"""Per-chunk runtime telemetry (DESIGN.md §7).

Between chunks the host owns control, so telemetry is plain numpy over the
chunk's ``StepOut`` plus deltas of the carry's accumulator scalars — no
device-side bookkeeping beyond what the engine already carries.  The log
aggregates into the throughput headline ``benchmarks/bench_runtime.py``
reports (events/sec, p50/p99 event latency, shed/overflow counters).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cep.engine import Carry, StepOut

# Carry accumulator scalars differenced per chunk.
_COUNTERS = ("pms_shed", "shed_calls", "overflow", "ebl_dropped")


def counter_snapshot(carry: Carry) -> dict[str, float]:
    """Host copies of the carry's scalar counters (+ total completions)."""
    snap = {k: float(np.asarray(getattr(carry, k)).sum()) for k in _COUNTERS}
    snap["complex_count"] = float(np.asarray(carry.complex_count).sum())
    return snap


@dataclasses.dataclass
class ChunkStats:
    chunk_index: int
    start: int                  # global index of the chunk's first event
    n_events: int               # events processed (all lanes)
    n_lanes: int
    wall_s: float
    events_per_s: float
    l_e_p50: float
    l_e_p99: float
    l_e_max: float
    n_pm_end: float             # active PMs after the chunk (all lanes)
    shed_events: int            # events at which a shed triggered
    dropped_events: int         # E-BL input drops
    pms_shed: float             # counter deltas over the chunk
    shed_calls: float
    overflow: float
    ebl_dropped: float
    completions: float
    refreshed: bool = False     # model refresh ran after this chunk
    refresh_wall_s: float = 0.0  # host time spent in/gating the refresh

    def to_row(self) -> dict:
        return dataclasses.asdict(self)


def summarize_chunk(chunk_index: int, start: int, outs: StepOut,
                    before: dict[str, float], after: dict[str, float],
                    wall_s: float, refreshed: bool = False,
                    refresh_wall_s: float = 0.0) -> ChunkStats:
    """Stats for one chunk; ``outs`` leaves are (n,) or lane-stacked (L, n)."""
    l_e = np.asarray(outs.l_e, np.float64).ravel()
    n_lanes = 1 if np.asarray(outs.l_e).ndim == 1 else outs.l_e.shape[0]
    n_events = l_e.size
    n_pm_end = float(np.asarray(outs.n_pm).reshape(n_lanes, -1)[:, -1].sum())
    d = {k: after[k] - before[k] for k in before}
    return ChunkStats(
        chunk_index=chunk_index, start=start, n_events=n_events,
        n_lanes=n_lanes, wall_s=wall_s,
        events_per_s=n_events / max(wall_s, 1e-12),
        l_e_p50=float(np.percentile(l_e, 50)) if n_events else 0.0,
        l_e_p99=float(np.percentile(l_e, 99)) if n_events else 0.0,
        l_e_max=float(l_e.max()) if n_events else 0.0,
        n_pm_end=n_pm_end,
        shed_events=int(np.asarray(outs.shed).sum()),
        dropped_events=int(np.asarray(outs.dropped).sum()),
        pms_shed=d["pms_shed"], shed_calls=d["shed_calls"],
        overflow=d["overflow"], ebl_dropped=d["ebl_dropped"],
        completions=d["complex_count"], refreshed=refreshed,
        refresh_wall_s=refresh_wall_s,
    )


class TelemetryLog:
    """Append-only chunk log with run-level aggregation."""

    def __init__(self):
        self.chunks: list[ChunkStats] = []

    def append(self, stats: ChunkStats) -> None:
        self.chunks.append(stats)

    def rows(self) -> list[dict]:
        return [c.to_row() for c in self.chunks]

    def aggregate(self) -> dict:
        if not self.chunks:
            return {"n_chunks": 0, "n_events": 0, "events_per_s": 0.0}
        n_events = sum(c.n_events for c in self.chunks)
        # Aggregate throughput charges the host-side refresh time too —
        # per-chunk events_per_s is processing-only.
        wall = sum(c.wall_s + c.refresh_wall_s for c in self.chunks)
        return {
            "n_chunks": len(self.chunks),
            "n_events": n_events,
            "wall_s": wall,
            "refresh_wall_s": sum(c.refresh_wall_s for c in self.chunks),
            "events_per_s": n_events / max(wall, 1e-12),
            "l_e_p50_max": max(c.l_e_p50 for c in self.chunks),
            "l_e_p99_max": max(c.l_e_p99 for c in self.chunks),
            "l_e_max": max(c.l_e_max for c in self.chunks),
            "pms_shed": sum(c.pms_shed for c in self.chunks),
            "shed_calls": sum(c.shed_calls for c in self.chunks),
            "overflow": sum(c.overflow for c in self.chunks),
            "ebl_dropped": sum(c.ebl_dropped for c in self.chunks),
            "completions": sum(c.completions for c in self.chunks),
            "refreshes": sum(1 for c in self.chunks if c.refreshed),
        }
