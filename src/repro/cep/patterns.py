"""CEP pattern/query definitions and compilation to dense transition tables.

We support the paper's four query families (§IV-A):
  Q1  seq(RE_1; ...; RE_k)                 — sequence operator
  Q2  seq with repetition (e.g. RE_1;RE_1;RE_2;...)
  Q3  seq(STR; any(n, DF_1..DF_n))         — sequence-with-any
  Q4  any(n, B_1..B_n)                     — any operator (slide windows)

All with skip-till-next-match semantics: a PM either advances on a matching
event or stays (see DESIGN.md §3 for the semantics note).  A pattern compiles
to:
  - an event classifier (dataset-specific; see repro/data) that yields, per
    event: class c ∈ [0, C] (0 = irrelevant), binding value b (e.g. stop id,
    striker id; -1 = none), distinctness id (e.g. bus/defender id), and a
    window-open flag;
  - a dense transition table trans[m, C+1] for SEQ-kind patterns
    (states 0..m-1; 0 = φ initial, m-1 = final);
  - ANY-kind patterns count distinct ids: state = number matched.

States are 0-indexed here: state 0 = φ (never stored — PMs spawn at state 1),
final = m-1.  This matches the paper's s_1..s_m with an index shift.
"""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence

import numpy as np

KIND_SEQ = 0
KIND_ANY = 1

SPAWN_AT_OPEN = 0      # PM spawns when the window-open event arrives (Q1-Q3)
SPAWN_IN_WINDOWS = 1   # PMs spawn inside slide-opened windows (Q4)


@dataclasses.dataclass(frozen=True)
class PatternSpec:
    """Static description of one query."""
    name: str
    kind: int                       # KIND_SEQ | KIND_ANY
    spawn_mode: int                 # SPAWN_AT_OPEN | SPAWN_IN_WINDOWS
    class_sequence: tuple[int, ...]  # SEQ: required class at each position
    num_classes: int                # C (classes 1..C; 0 = irrelevant)
    any_n: int                      # ANY: distinct matches required
    window_size: int                # ws, in events
    slide: int                      # SPAWN_IN_WINDOWS: window slide, in events
    weight: float = 1.0             # w_q (pattern importance)
    uses_binding: bool = False      # PM binding must equal event binding
    proc_cost: float = 1.0          # relative per-PM-per-event match cost
                                    # (the tau_Q1/tau_Q2 knob of Fig. 8)
    any_spawn_counts: bool = False  # ANY: does the spawning event itself
                                    # count as the first distinct match?
                                    # (Q4: yes — first delayed bus; Q3: no —
                                    # the opener is the striker, not a DF.)

    @property
    def num_states(self) -> int:
        if self.kind == KIND_SEQ:
            return len(self.class_sequence) + 1
        # ANY: φ, spawn state, then remaining distinct matches.
        return self.any_n + (1 if self.any_spawn_counts else 2)

    @property
    def final_state(self) -> int:
        return self.num_states - 1


def seq_pattern(name: str, class_sequence: Sequence[int], num_classes: int,
                window_size: int, weight: float = 1.0,
                proc_cost: float = 1.0,
                uses_binding: bool = False) -> PatternSpec:
    """Q1/Q2-style sequence (with repetition allowed in class_sequence)."""
    return PatternSpec(
        name=name, kind=KIND_SEQ, spawn_mode=SPAWN_AT_OPEN,
        class_sequence=tuple(class_sequence), num_classes=num_classes,
        any_n=0, window_size=window_size, slide=0, weight=weight,
        uses_binding=uses_binding, proc_cost=proc_cost)


def seq_any_pattern(name: str, any_n: int, window_size: int,
                    weight: float = 1.0,
                    proc_cost: float = 1.0) -> PatternSpec:
    """Q3: seq(OPEN; any(n, ...)) — window opens on the leading event (e.g.
    striker ball possession), then n distinct class-1 events bound to the
    opener complete the pattern."""
    return PatternSpec(
        name=name, kind=KIND_ANY, spawn_mode=SPAWN_AT_OPEN,
        class_sequence=(), num_classes=1, any_n=any_n,
        window_size=window_size, slide=0, weight=weight,
        uses_binding=True, proc_cost=proc_cost)


def any_pattern(name: str, any_n: int, window_size: int, slide: int,
                weight: float = 1.0, proc_cost: float = 1.0) -> PatternSpec:
    """Q4: any(n, ...) over count-based slide-opened windows; PMs spawn per
    distinct binding (e.g. bus stop) inside each open window."""
    return PatternSpec(
        name=name, kind=KIND_ANY, spawn_mode=SPAWN_IN_WINDOWS,
        class_sequence=(), num_classes=1, any_n=any_n,
        window_size=window_size, slide=slide, weight=weight,
        uses_binding=True, proc_cost=proc_cost, any_spawn_counts=True)


def build_transition_table(spec: PatternSpec,
                           max_states: int | None = None,
                           max_classes: int | None = None) -> np.ndarray:
    """Dense trans[m, C+1]: next state given current state and event class.

    SEQ: state j advances to j+1 iff class == class_sequence[j-1]... states
    are 0-indexed with state j meaning "j positions matched", so a PM at state
    j (1 <= j < m-1) needs class_sequence[j] to advance (position j, because
    the opener consumed position 0).  Final state is absorbing.

    ANY: state j advances on class 1 (distinctness enforced at runtime).
    """
    m = spec.num_states
    C = spec.num_classes
    M = max_states or m
    K = (max_classes or C) + 1
    trans = np.tile(np.arange(M, dtype=np.int32)[:, None], (1, K))
    if spec.kind == KIND_SEQ:
        for j in range(1, m - 1):
            needed = spec.class_sequence[j]
            trans[j, needed] = j + 1
    else:
        for j in range(1, m - 1):
            trans[j, 1] = j + 1
    # Final state absorbing; state 0 (φ) never advances via the table —
    # spawning is handled by the engine.
    return trans


@dataclasses.dataclass
class CompiledPatterns:
    """A batch of patterns compiled to padded dense arrays for the engine."""
    specs: tuple[PatternSpec, ...]
    trans: np.ndarray        # (P, M, C+1) int32
    kind: np.ndarray         # (P,) int32
    spawn_mode: np.ndarray   # (P,) int32
    window_size: np.ndarray  # (P,) int32
    slide: np.ndarray        # (P,) int32
    final_state: np.ndarray  # (P,) int32
    weight: np.ndarray       # (P,) float32
    uses_binding: np.ndarray  # (P,) bool
    proc_cost: np.ndarray    # (P,) float32
    spawn_counts: np.ndarray  # (P,) bool — ANY spawn consumes one match

    @property
    def num_patterns(self) -> int:
        return len(self.specs)

    @property
    def max_states(self) -> int:
        return self.trans.shape[1]


def compile_patterns(specs: Sequence[PatternSpec]) -> CompiledPatterns:
    M = max(s.num_states for s in specs)
    C = max(s.num_classes for s in specs)
    trans = np.stack([build_transition_table(s, M, C) for s in specs])
    return CompiledPatterns(
        specs=tuple(specs),
        trans=trans,
        kind=np.array([s.kind for s in specs], np.int32),
        spawn_mode=np.array([s.spawn_mode for s in specs], np.int32),
        window_size=np.array([s.window_size for s in specs], np.int32),
        slide=np.array([max(s.slide, 1) for s in specs], np.int32),
        final_state=np.array([s.final_state for s in specs], np.int32),
        weight=np.array([s.weight for s in specs], np.float32),
        uses_binding=np.array([s.uses_binding for s in specs], bool),
        proc_cost=np.array([s.proc_cost for s in specs], np.float32),
        spawn_counts=np.array([s.any_spawn_counts for s in specs], bool),
    )


# ---------------------------------------------------------------------------
# Paper queries (§IV-A), parameterized the way the evaluation varies them.
# ---------------------------------------------------------------------------

def make_q1(window_size: int, num_symbols: int = 10,
            weight: float = 1.0, proc_cost: float = 1.0) -> PatternSpec:
    """Q1: seq(RE_1; ...; RE_10).  Class j == rising quote of symbol j."""
    return seq_pattern("Q1", class_sequence=list(range(1, num_symbols + 1)),
                       num_classes=num_symbols, window_size=window_size,
                       weight=weight, proc_cost=proc_cost)


Q2_ORDER = (1, 1, 2, 3, 2, 4, 2, 5, 6, 7, 2, 8, 9, 10)


def make_q2(window_size: int, weight: float = 1.0,
            proc_cost: float = 1.0) -> PatternSpec:
    """Q2: sequence with repetition (paper's exact repetition order)."""
    return seq_pattern("Q2", class_sequence=list(Q2_ORDER), num_classes=10,
                       window_size=window_size, weight=weight,
                       proc_cost=proc_cost)


def make_q3(any_n: int, window_size: int, weight: float = 1.0,
            proc_cost: float = 1.0) -> PatternSpec:
    """Q3: seq(STR; any(n, DF...)) — n defenders against the striker."""
    return seq_any_pattern("Q3", any_n=any_n, window_size=window_size,
                           weight=weight, proc_cost=proc_cost)


def make_q4(any_n: int, window_size: int, slide: int = 500,
            weight: float = 1.0, proc_cost: float = 1.0) -> PatternSpec:
    """Q4: any(n, B...) — n distinct buses delayed at the same stop."""
    return any_pattern("Q4", any_n=any_n, window_size=window_size,
                       slide=slide, weight=weight, proc_cost=proc_cost)
