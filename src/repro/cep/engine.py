"""Vectorized CEP operator with pSPICE load shedding (paper §III).

The operator keeps a fixed-capacity dense PM store per pattern and advances
EVERY active PM against each incoming event in one vectorized step; the whole
stream is one ``lax.scan`` (or, for unbounded streams, consecutive
``run_engine_chunk`` scans driven by ``repro.runtime`` — DESIGN.md §7).
Latency is tracked with a deterministic
simulated-time model calibrated against the real (wall-clock) cost of the
jitted engine — see DESIGN.md §3 "Wall-clock latency → simulated-time model".

Per event step (order matters, mirrors the paper's operator):
  1. expire PMs whose window closed,
  2. overload check (Alg. 1) → optional shed (Alg. 2 / PM-BL) via lax.cond,
  3. E-BL input-drop decision (black-box baseline only),
  4. advance PMs (SEQ table lookup / ANY distinct count), detect completions,
  5. spawn PMs (window-open events / slide-window ring),
  6. gather <q, s, s', t> observations (model-building phase),
  7. advance simulated time, record latency telemetry.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import contracts as ctr
from repro.analysis.tracing import count_traces
from repro.cep import patterns as pat
from repro.core import overload as ovl
from repro.core import shedder as shd
from repro.kernels import block_step as kblock
from repro.kernels import ops as kops
from repro.kernels import tiling as ktile

Array = jax.Array

SHED_NONE, SHED_PSPICE, SHED_PMBL, SHED_EBL = "none", "pspice", "pmbl", "ebl"

BACKEND_XLA, BACKEND_PALLAS = "xla", "pallas"
BACKEND_PALLAS_BLOCK = "pallas_block"
# Backends whose shed path routes through repro.kernels (DESIGN.md §8/§10).
_KERNEL_BACKENDS = (BACKEND_PALLAS, BACKEND_PALLAS_BLOCK)


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Static (hashable) engine configuration — one jit cache entry each."""
    num_patterns: int
    max_states: int          # M (padded)
    max_classes: int         # C (padded), classes 0..C
    max_pms: int = 2048      # N PM slots per pattern
    max_any_ids: int = 8     # distinctness-set capacity for ANY patterns
    ring_size: int = 8       # open-window ring for SPAWN_IN_WINDOWS
    latency_bound: float = 1.0
    safety_buffer: float = 0.0
    # Simulated-time cost model (seconds). The paper's operator load comes
    # from matching events against PMs (c_match · n_pm, scaled per pattern by
    # proc_cost) plus per-event window/bookkeeping cost c_base; shedding costs
    # c_shed_base + c_shed_pm · n_pm — the O(N) histogram-threshold plan
    # (utility lookup + a constant number of bucket passes per PM; the old
    # sort plan was O(N log N), which the linear g model under-predicted at
    # large stores).  E-BL pays c_ebl per dropped event.
    c_base: float = 2e-6
    c_match: float = 1e-7
    c_shed_base: float = 5e-6
    c_shed_pm: float = 2e-9   # per-PM shed cost, recalibrated to the O(N) plan
    c_ebl: float = 5e-7
    # Hot-path dispatch (DESIGN.md §8).  backend: "xla" runs the jnp
    # reference ops; "pallas" routes advance / utility lookup / shed
    # through repro.kernels.ops (compiled on TPU, interpret elsewhere) —
    # bitwise-equivalent (tests/test_backend.py); "pallas_block" replaces
    # the per-event scan with one fused kernel launch per
    # ``block_events`` events (kernels/block_step.py, DESIGN.md §10) —
    # the PM store stays resident across the block and the scan runs
    # over blocks.  Algorithm-2 fires are handled IN-KERNEL by default
    # (``block_shed="fused"``: the threshold select runs against the
    # store-resident utility column, PRNG keys are precomputed host-side
    # and threaded in); ``block_shed="replay"`` pins the legacy
    # block-split protocol — bail at the fire, replay that event through
    # the host ``_step``, re-enter — which stays as the oracle, and is
    # forced whenever ``shed_plan="sort"`` (the fused path implements
    # the threshold plan only).  All bitwise-equivalent
    # (tests/test_block_backend.py, eval/oracle.py).
    # spawn_alloc / shed_plan keep the legacy O(N log N) paths selectable
    # as oracles and as the baseline benchmarks/bench_engine.py measures
    # against.
    backend: str = BACKEND_XLA          # "xla" | "pallas" | "pallas_block"
    block_events: int = 32              # W — events fused per block launch
    block_shed: str = "fused"           # "fused" (in-kernel Alg. 2) | "replay"
    spawn_alloc: str = "cumsum"         # "cumsum" (O(N)) | "argsort" (legacy)
    shed_plan: str = "threshold"        # "threshold" (O(N)) | "sort" (legacy)
    # Static pattern census (DESIGN.md §8): when every pattern shares one
    # kind / spawn mode, the step skips the other family's per-event ops
    # (the O(A·N) idset machinery for SEQ-only sets, the O(K·N)
    # window-spawn exists-check for AT_OPEN-only sets) — bitwise-identical
    # to "mixed", which always computes both and selects.
    # ``runner.default_config`` fills these in from the compiled patterns.
    kinds: str = "mixed"                # "seq" | "any" | "mixed"
    spawn_modes: str = "mixed"          # "at_open" | "in_windows" | "mixed"
    # Match emission (repro.eval, DESIGN.md §9): when on, every step also
    # emits the identity of each completed match — (open_idx, bind) of the
    # completing PM, -1 where no completion — so a run's MATCH SET (not
    # just its completion counts) can be extracted and diffed against the
    # NumPy oracle / a no-shed ground truth.  Off (the default) the fields
    # are zero-width (P, 0) arrays: same pytree structure, no hot-path
    # cost, no retrace of existing configs.
    emit_matches: bool = False
    gather_stats: bool = False
    shedder: str = SHED_NONE
    # E-BL drop-fraction controller: model-based feedforward (drop enough to
    # match the arrival rate) + backlog-proportional term, with decay when
    # not overloaded.
    ebl_backlog_gain: float = 0.5
    ebl_decay: float = 0.997
    # When the drop budget exceeds what low-utility types can supply, the
    # remainder spreads uniformly across all types (He et al.'s weighted
    # sampling degrades toward uniform under pressure): effective priority
    # = floor + (1-floor)·raw.
    ebl_floor: float = 0.25

    def __post_init__(self):
        # Config-time validation: EngineConfigs are built both by
        # runner.default_config and by bare dataclasses.replace all over
        # the benchmarks/tests — a bad knob must fail HERE, not as a
        # ZeroDivisionError or silent xla fallback deep inside a trace.
        if self.backend not in (BACKEND_XLA, BACKEND_PALLAS,
                                BACKEND_PALLAS_BLOCK):
            raise ValueError(
                f"unknown engine backend {self.backend!r}; expected one "
                f"of ('{BACKEND_XLA}', '{BACKEND_PALLAS}', "
                f"'{BACKEND_PALLAS_BLOCK}')")
        if self.block_events < 1:
            raise ValueError(
                f"block_events must be >= 1: {self.block_events}")
        for name in ("num_patterns", "max_states", "max_classes",
                     "max_pms", "max_any_ids", "ring_size"):
            v = getattr(self, name)
            if v < 1:
                raise ValueError(
                    f"{name} must be >= 1 (it sizes a store/table axis): "
                    f"{v}")
        if not self.latency_bound > 0:
            raise ValueError(
                "latency_bound must be > 0 seconds — the overload "
                "detector (Alg. 1) compares realized event latency l_e "
                f"against it: {self.latency_bound}")
        if self.safety_buffer < 0:
            raise ValueError(
                "safety_buffer must be >= 0 seconds (it tightens the "
                f"latency bound, never loosens it): {self.safety_buffer}")
        for name in ("c_base", "c_match", "c_shed_base", "c_shed_pm",
                     "c_ebl"):
            v = getattr(self, name)
            if v < 0:
                raise ValueError(
                    f"cost constant {name} must be >= 0 seconds (simulated-"
                    f"time costs are non-negative): {v}")
        for name in ("ebl_floor", "ebl_decay"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(
                    f"{name} must be in [0, 1] (it scales/decays the E-BL "
                    f"drop fraction): {v}")
        if self.ebl_backlog_gain < 0:
            raise ValueError(
                "ebl_backlog_gain must be >= 0 (backlog-proportional term "
                f"of the E-BL drop controller): {self.ebl_backlog_gain}")
        if self.shedder not in (SHED_NONE, SHED_PSPICE, SHED_PMBL,
                                SHED_EBL):
            raise ValueError(
                f"unknown shedder {self.shedder!r}; expected one of "
                f"('{SHED_NONE}', '{SHED_PSPICE}', '{SHED_PMBL}', "
                f"'{SHED_EBL}')")
        if self.spawn_alloc not in ("cumsum", "argsort"):
            raise ValueError(f"unknown spawn_alloc {self.spawn_alloc!r}; "
                             "expected 'cumsum' or 'argsort'")
        if self.shed_plan not in ("threshold", "sort"):
            raise ValueError(f"unknown shed_plan {self.shed_plan!r}; "
                             "expected 'threshold' or 'sort'")
        if self.block_shed not in ("fused", "replay"):
            raise ValueError(f"unknown block_shed {self.block_shed!r}; "
                             "expected 'fused' or 'replay'")
        if self.kinds not in ("seq", "any", "mixed"):
            raise ValueError(f"unknown kinds census {self.kinds!r}; "
                             "expected 'seq', 'any' or 'mixed'")
        if self.spawn_modes not in ("at_open", "in_windows", "mixed"):
            raise ValueError(
                f"unknown spawn_modes census {self.spawn_modes!r}; "
                "expected 'at_open', 'in_windows' or 'mixed'")

    @property
    def flat_pms(self) -> int:
        return self.num_patterns * self.max_pms


class EngineModel(NamedTuple):
    """Learned / compiled array-valued inputs (a pytree; not static)."""
    trans: Array          # (P, M, C+1) int32
    kind: Array           # (P,) int32
    spawn_mode: Array     # (P,) int32
    window_size: Array    # (P,) int32
    slide: Array          # (P,) int32
    final_state: Array    # (P,) int32
    proc_cost: Array      # (P,) float32 — relative match cost multiplier
    uses_binding: Array   # (P,) bool
    spawn_counts: Array   # (P,) bool — ANY spawn consumes the first match
    # pSPICE utility tables (stacked across patterns) + latency regressions.
    ut_tables: Array      # (P, B, M) float32
    ut_bins: Array        # (P,) int32
    f_model: ovl.LatencyModel
    g_model: ovl.LatencyModel
    # E-BL per-event raw drop priority (1 - normalized type utility).
    ebl_raw_mean: Array   # scalar float32


class EventBatch(NamedTuple):
    """Per-event classified inputs (precomputed by the data layer)."""
    ev_class: Array    # (n, P) int32 — class per pattern (0 = irrelevant)
    ev_bind: Array     # (n, P) int32 — binding value per pattern (-1 = none)
    ev_open: Array     # (n, P) bool  — window-open flag per pattern
    ev_id: Array       # (n,)  int32  — distinctness id (ANY patterns)
    ev_rand: Array     # (n,)  float32 — u(0,1) for E-BL sampling
    ebl_raw: Array     # (n,)  float32 — E-BL raw drop priority per event
    arrival: Array     # (n,)  float32 — arrival time (seconds)


class PMStore(NamedTuple):
    active: Array     # (P, N) bool
    state: Array      # (P, N) int32
    open_idx: Array   # (P, N) int32 — event index at window open
    bind: Array       # (P, N) int32
    idset: Array      # (P, N, A) int32 — matched distinct ids (ANY), -1 empty


class Carry(NamedTuple):
    pms: PMStore
    ring: Array          # (P, K) int32 window-open indices (-1 = empty)
    ring_ptr: Array      # (P,) int32
    sim_time: Array      # scalar f32
    key: Array           # PRNG key
    ebl_frac: Array      # scalar f32 — E-BL current drop fraction
    ema_gap: Array       # scalar f32 — EMA of inter-arrival gap (1/rate)
    prev_arrival: Array  # scalar f32
    # accumulators
    complex_count: Array  # (P,) f32
    pms_created: Array    # (P,) f32
    pms_shed: Array       # scalar f32
    shed_calls: Array     # scalar f32
    overflow: Array       # scalar f32 — spawns lost to a full store
    ebl_dropped: Array    # scalar f32
    obs_counts: Array     # (P, M, M) f32 transition counts
    obs_rewards: Array    # (P, M, M) f32 summed transition times
    lat_samples_n: Array  # (S,) f32  (n_pm, l_p) samples for fitting f
    lat_samples_l: Array  # (S,) f32
    lat_ptr: Array        # scalar int32


class StepOut(NamedTuple):
    l_e: Array       # realized event latency (s)
    n_pm: Array      # total active PMs after the step
    shed: Array      # bool — shed triggered at this event
    dropped: Array   # bool — event dropped by E-BL
    # Match identities (cfg.emit_matches; zero-width (P, 0) otherwise):
    # slot j of pattern p completed at this event iff match_open[p, j] >= 0,
    # in which case (match_open, match_bind)[p, j] are the completing PM's
    # window-open event index and binding value.
    match_open: Array   # (P, N | 0) int32 — open_idx of completed PM, -1
    match_bind: Array   # (P, N | 0) int32 — bind of completed PM, -1


# ---------------------------------------------------------------------------
# Engine construction
# ---------------------------------------------------------------------------

def make_model(cp: pat.CompiledPatterns, cfg: EngineConfig,
               ut_tables: Array | None = None, ut_bins: Array | None = None,
               f_model: ovl.LatencyModel | None = None,
               g_model: ovl.LatencyModel | None = None,
               ebl_raw_mean: float = 0.5) -> EngineModel:
    P, M = cp.num_patterns, cp.max_states
    # The census fields gate which per-event op families the step compiles
    # — an inconsistent census would silently produce wrong matches.
    kind, sm = np.asarray(cp.kind), np.asarray(cp.spawn_mode)
    if (cfg.kinds == "seq" and (kind != pat.KIND_SEQ).any()) or \
       (cfg.kinds == "any" and (kind != pat.KIND_ANY).any()):
        raise ValueError(f"cfg.kinds={cfg.kinds!r} but patterns have "
                         f"kinds {sorted(set(kind.tolist()))}")
    if (cfg.spawn_modes == "at_open" and
            (sm != pat.SPAWN_AT_OPEN).any()) or \
       (cfg.spawn_modes == "in_windows" and
            (sm != pat.SPAWN_IN_WINDOWS).any()):
        raise ValueError(f"cfg.spawn_modes={cfg.spawn_modes!r} but patterns "
                         f"have spawn modes {sorted(set(sm.tolist()))}")
    num_bins = 1 if ut_tables is None else ut_tables.shape[1]
    if ut_tables is None:
        ut_tables = jnp.ones((P, num_bins, M), jnp.float32)
    if ut_bins is None:
        ut_bins = jnp.ones((P,), jnp.int32)
    ident = ovl.LatencyModel(a=jnp.float32(cfg.c_match),
                             b=jnp.float32(cfg.c_base),
                             kind=jnp.int32(ovl.LINEAR))
    g_ident = ovl.LatencyModel(a=jnp.float32(cfg.c_shed_pm),
                               b=jnp.float32(cfg.c_shed_base),
                               kind=jnp.int32(ovl.LINEAR))
    return EngineModel(
        trans=jnp.asarray(cp.trans), kind=jnp.asarray(cp.kind),
        spawn_mode=jnp.asarray(cp.spawn_mode),
        window_size=jnp.asarray(cp.window_size),
        slide=jnp.asarray(cp.slide),
        final_state=jnp.asarray(cp.final_state),
        proc_cost=jnp.asarray(cp.proc_cost),
        uses_binding=jnp.asarray(cp.uses_binding),
        spawn_counts=jnp.asarray(cp.spawn_counts),
        ut_tables=jnp.asarray(ut_tables), ut_bins=jnp.asarray(ut_bins),
        f_model=f_model if f_model is not None else ident,
        g_model=g_model if g_model is not None else g_ident,
        ebl_raw_mean=jnp.float32(ebl_raw_mean),
    )


def init_carry(cfg: EngineConfig, seed: int = 0,
               lat_capacity: int = 4096) -> Carry:
    P, N, M, A, K = (cfg.num_patterns, cfg.max_pms, cfg.max_states,
                     cfg.max_any_ids, cfg.ring_size)
    pms = PMStore(
        active=jnp.zeros((P, N), bool),
        state=jnp.zeros((P, N), jnp.int32),
        open_idx=jnp.zeros((P, N), jnp.int32),
        bind=jnp.full((P, N), -1, jnp.int32),
        idset=jnp.full((P, N, A), -1, jnp.int32),
    )
    # Each scalar gets its OWN buffer: run_engine_chunk donates the carry,
    # and donating one buffer aliased across several leaves is an error.
    z = lambda: jnp.zeros((), jnp.float32)  # noqa: E731
    return Carry(
        pms=pms,
        ring=jnp.full((P, K), -1, jnp.int32),
        ring_ptr=jnp.zeros((P,), jnp.int32),
        sim_time=z(), key=jax.random.PRNGKey(seed), ebl_frac=z(),
        ema_gap=jnp.float32(1e-3), prev_arrival=z(),
        complex_count=jnp.zeros((P,), jnp.float32),
        pms_created=jnp.zeros((P,), jnp.float32),
        pms_shed=z(), shed_calls=z(), overflow=z(), ebl_dropped=z(),
        obs_counts=jnp.zeros((P, M, M), jnp.float32),
        obs_rewards=jnp.zeros((P, M, M), jnp.float32),
        lat_samples_n=jnp.zeros((lat_capacity,), jnp.float32),
        lat_samples_l=jnp.zeros((lat_capacity,), jnp.float32),
        lat_ptr=jnp.int32(0),
    )


# ---------------------------------------------------------------------------
# One event step
# ---------------------------------------------------------------------------

def _advance(cfg: EngineConfig, model: EngineModel, pms: PMStore,
             ev_class: Array, ev_bind: Array, ev_id: Array):
    """Advance all active PMs against one event.  Returns (pms, old_state,
    new_state, completed_per_pattern)."""
    P, N = cfg.num_patterns, cfg.max_pms
    c = ev_class[:, None]                      # (P,1)
    b = ev_bind[:, None]
    bind_ok = jnp.where(model.uses_binding[:, None], pms.bind == b, True)
    c_eff = jnp.where(bind_ok, c, 0)

    # SEQ: dense table lookup trans[p, state, c_eff] — ONE flat (P·N,)
    # gather (the old double take_along_axis materialized a (P, N, C+1)
    # intermediate every event).  Class 0 self-loops, so a failed binding
    # (c_eff = 0) keeps the state — which is also exactly what the Pallas
    # kernel's in-kernel binding check does.
    final = model.final_state[:, None]
    if cfg.kinds != "any":
        if cfg.backend == BACKEND_PALLAS:
            seq_next = kops.advance_seq_multi(
                pms.state, pms.bind, pms.active, model.trans, ev_class,
                ev_bind, model.final_state, model.uses_binding,
                interpret=kops.default_interpret())
        else:
            M, C1 = model.trans.shape[1], model.trans.shape[2]
            pidx = jnp.arange(P, dtype=jnp.int32)[:, None]
            flat_idx = (pidx * M + pms.state) * C1 + c_eff.astype(jnp.int32)
            seq_next = jnp.take(model.trans.reshape(-1), flat_idx)

    # ANY: distinct-count advance + idset insert at the next free position:
    # a PM at state j holds (j-1) ids if the spawn event didn't count (Q3)
    # or j ids if it did (Q4) — insertion slot is state-1 (+1 when
    # spawn_counts).  SEQ-only pattern sets skip all of it (the inserts
    # are dead: do_insert requires ~is_seq).
    if cfg.kinds != "seq":
        in_set = (pms.idset == ev_id).any(axis=-1)            # (P, N)
        any_match = (c_eff == 1) & ~in_set & (pms.state < final)
        any_next = pms.state + any_match.astype(jnp.int32)
        A = cfg.max_any_ids
        sc = model.spawn_counts.astype(jnp.int32)[:, None]
        slot = jnp.clip(pms.state - 1 + sc, 0, A - 1)
        is_seq = (model.kind == pat.KIND_SEQ)[:, None]
        do_insert = (~is_seq) & pms.active & any_match
        onehot = jax.nn.one_hot(slot, A, dtype=bool) & do_insert[..., None]
        idset = jnp.where(onehot, ev_id, pms.idset)

    if cfg.kinds == "seq":
        new_state = jnp.where(pms.active, seq_next, pms.state)
        idset = pms.idset
    elif cfg.kinds == "any":
        new_state = jnp.where(pms.active, any_next, pms.state)
    else:
        new_state = jnp.where(pms.active,
                              jnp.where(is_seq, seq_next, any_next),
                              pms.state)

    completed = pms.active & (new_state == final) & (pms.state != final)
    active = pms.active & ~completed
    pms2 = PMStore(active=active, state=new_state, open_idx=pms.open_idx,
                   bind=pms.bind, idset=idset)
    return pms2, pms.state, new_state, completed


def _spawn(cfg: EngineConfig, model: EngineModel, pms: PMStore, ring: Array,
           i: Array, ev_open: Array, ev_class: Array, ev_bind: Array,
           ev_id: Array):
    """Spawn new PMs.  Returns (pms, spawned_per_pattern, overflow_count).

    SPAWN_AT_OPEN: the window-open event itself spawns one PM at state 1.
    SPAWN_IN_WINDOWS: a class-1 event spawns a PM (state 1, bound to its
    binding value) in every ring window that lacks one.
    """
    P, N, K = cfg.num_patterns, cfg.max_pms, cfg.ring_size
    at_open = model.spawn_mode == pat.SPAWN_AT_OPEN

    # Candidate spawns: K slots per pattern. Candidate 0 doubles as the
    # AT_OPEN candidate.  The O(K·N) ring exists-check only runs when a
    # SPAWN_IN_WINDOWS pattern can exist (census: cfg.spawn_modes).
    if cfg.spawn_modes != "at_open":
        ring_valid = ring >= 0
        in_window = (i - ring) < model.window_size[:, None]
        exists = ((pms.active[:, None, :]) &
                  (pms.open_idx[:, None, :] == ring[:, :, None]) &
                  (pms.bind[:, None, :] == ev_bind[:, None, None])).any(-1)
        win_spawn = (ring_valid & in_window & ~exists &
                     (ev_class == 1)[:, None] & (~at_open)[:, None])
    open_spawn = (at_open & ev_open)[:, None] & (jnp.arange(K) == 0)
    if cfg.spawn_modes == "at_open":
        cand = open_spawn                                    # (P, K)
        cand_open_idx = jnp.broadcast_to(i, (P, K)).astype(jnp.int32)
    elif cfg.spawn_modes == "in_windows":
        cand = win_spawn
        cand_open_idx = ring
    else:
        cand = win_spawn | open_spawn
        cand_open_idx = jnp.where(at_open[:, None], i, ring)  # (P, K)

    # Allocate free slots: candidate r takes the (r+1)-th lowest-index
    # inactive slot (stable inactive-first order).
    n_free = (~pms.active).sum(axis=1)                          # (P,)
    rank = jnp.cumsum(cand, axis=1) - 1                        # (P, K)
    can_alloc = cand & (rank < n_free[:, None])
    overflow = (cand & ~can_alloc).sum()
    if cfg.spawn_alloc == "argsort":
        # Legacy allocator (the oracle the O(N) scheme is property-tested
        # against, and bench_engine.py's baseline): full per-event sort.
        free_order = jnp.argsort(pms.active, axis=1, stable=True)  # (P, N)
        slots = jnp.take_along_axis(free_order, jnp.clip(rank, 0, N - 1),
                                    axis=1)
    else:
        # O(N) free-list compaction: every inactive slot scatters its own
        # index at its rank among the free slots (masked-cumsum rank), so
        # `free_slots[p, r]` is precisely what the stable argsort put
        # there for r < n_free — bitwise-identical slot choices
        # (tests/test_backend.py).  Ranks ≥ n_free stay at the sentinel N;
        # they are only read where ~can_alloc masks the update to a
        # dropped OOB scatter, exactly like the legacy path's junk slots.
        free_rank = jnp.cumsum(~pms.active, axis=1) - 1        # (P, N)
        rowbase = jnp.arange(P, dtype=jnp.int32)[:, None] * N
        tgt = jnp.where(~pms.active, rowbase + free_rank, cfg.flat_pms)
        cols = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (P, N))
        free_slots = jnp.full((cfg.flat_pms,), N, jnp.int32).at[
            tgt.reshape(-1)].set(cols.reshape(-1),
                                 mode="drop").reshape(P, N)
        slots = jnp.take_along_axis(free_slots, jnp.clip(rank, 0, N - 1),
                                    axis=1)

    rows = jnp.arange(P)[:, None] * jnp.ones((1, K), jnp.int32)
    flatidx = (rows * N + slots).reshape(-1)
    sel = can_alloc.reshape(-1)

    upd = jnp.where(sel, flatidx, cfg.flat_pms)  # drop-mode OOB when not sel
    active = pms.active.reshape(-1).at[upd].set(True, mode="drop")
    state = pms.state.reshape(-1).at[upd].set(1, mode="drop")
    open_i = pms.open_idx.reshape(-1).at[upd].set(
        cand_open_idx.reshape(-1), mode="drop")
    bind = pms.bind.reshape(-1).at[upd].set(
        jnp.broadcast_to(ev_bind[:, None], (P, K)).reshape(-1), mode="drop")
    # Fresh idset row: the spawning event's id occupies slot 0 for patterns
    # where the spawn consumes the first distinct match (Q4).
    A = cfg.max_any_ids
    row0 = jnp.where(model.spawn_counts[:, None],
                     jnp.full((P, 1), ev_id, jnp.int32), -1)       # (P, 1)
    fresh = jnp.concatenate(
        [row0, jnp.full((P, A - 1), -1, jnp.int32)], axis=1)  # (P, A)
    fresh_pk = jnp.broadcast_to(fresh[:, None, :], (P, K, A)).reshape(-1, A)
    idset = pms.idset.reshape(cfg.flat_pms, A).at[upd].set(
        fresh_pk, mode="drop")

    spawned = can_alloc.sum(axis=1).astype(jnp.float32)
    pms2 = PMStore(active=active.reshape(P, N), state=state.reshape(P, N),
                   open_idx=open_i.reshape(P, N), bind=bind.reshape(P, N),
                   idset=idset.reshape(P, N, cfg.max_any_ids))
    return pms2, spawned, overflow.astype(jnp.float32)


def _shed_now(cfg: EngineConfig, model: EngineModel, c: Carry, i: Array,
              rho: Array) -> tuple[Carry, Array]:
    """Run the load shedder (Alg. 2 / PM-BL) and pay its simulated cost."""
    P, N = cfg.num_patterns, cfg.max_pms
    pms = c.pms
    n_before = pms.active.sum()
    r_w = model.window_size[:, None] - (i - pms.open_idx)
    flat_active = pms.active.reshape(-1)
    key, sub = jax.random.split(c.key)
    if cfg.shedder == SHED_PSPICE:
        if cfg.backend in _KERNEL_BACKENDS:
            # Kernel path: fused per-pattern utility lookup + the same
            # histogram-threshold plan with the Pallas bucket counter.
            interp = kops.default_interpret()
            u = kops.pm_utilities_multi(
                pms.state, r_w, pms.active, model.ut_tables, model.ut_bins,
                interpret=interp).reshape(-1)
            if cfg.shed_plan == "sort":
                new_flat = shd.drop_lowest_utility(
                    flat_active, jnp.where(flat_active, u, jnp.inf), rho)
            else:
                new_flat = kops.shed_lowest_threshold(flat_active, u, rho,
                                                      interpret=interp)
        else:
            pattern_id = jnp.repeat(jnp.arange(P, dtype=jnp.int32), N)
            new_flat = shd.shed(
                "pspice", key=sub, active=flat_active, rho=rho,
                stacked_tables=model.ut_tables, bin_sizes=model.ut_bins,
                pattern_id=pattern_id, state=pms.state.reshape(-1),
                r_w=r_w.reshape(-1), plan=cfg.shed_plan)
    else:  # PM-BL — O(N) select over uniform scores on either backend
        new_flat = shd.shed("pmbl", key=sub, active=flat_active, rho=rho,
                            plan=cfg.shed_plan)
    active = new_flat.reshape(P, N)
    dropped = (n_before - active.sum()).astype(jnp.float32)
    shed_cost = cfg.c_shed_base + cfg.c_shed_pm * n_before.astype(jnp.float32)
    c = c._replace(
        pms=pms._replace(active=active), key=key,
        sim_time=c.sim_time + shed_cost,
        pms_shed=c.pms_shed + dropped,
        shed_calls=c.shed_calls + 1.0)
    return c, dropped


def _pre_shed(cfg: EngineConfig, model: EngineModel, carry: Carry,
              i: Array, ev_open: Array,
              arrival: Array) -> tuple[Carry, Array, Array]:
    """Steps 1-2 up to the overload decision: expire windows, ring
    bookkeeping, queueing latency.  Returns (carry, l_q, n_pm)."""
    c = carry
    pms = c.pms

    # -- 1. expire closed windows ------------------------------------------
    expired = pms.active & ((i - pms.open_idx) >= model.window_size[:, None])
    pms = pms._replace(active=pms.active & ~expired)

    # -- ring update (window-open bookkeeping for SPAWN_IN_WINDOWS) ---------
    if cfg.spawn_modes == "at_open":
        ring, ring_ptr = c.ring, c.ring_ptr   # no in-window spawner exists
    else:
        in_win_mode = model.spawn_mode == pat.SPAWN_IN_WINDOWS
        opens = ev_open & in_win_mode
        ring = jnp.where(
            opens[:, None] &
            (jnp.arange(cfg.ring_size) == c.ring_ptr[:, None]), i, c.ring)
        ring_ptr = jnp.where(opens, (c.ring_ptr + 1) % cfg.ring_size,
                             c.ring_ptr)

    # -- 2. queueing latency & overload check (Alg. 1) ----------------------
    sim_time = jnp.maximum(c.sim_time, arrival)
    l_q = sim_time - arrival
    n_pm = pms.active.sum().astype(jnp.float32)
    c = c._replace(pms=pms, ring=ring, ring_ptr=ring_ptr, sim_time=sim_time)
    return c, l_q, n_pm


def _step(cfg: EngineConfig, model: EngineModel, carry: Carry,
          ev: tuple) -> tuple[Carry, StepOut]:
    (i, ev_class, ev_bind, ev_open, ev_id, ev_rand, ebl_raw, arrival) = ev
    c, l_q, n_pm = _pre_shed(cfg, model, carry, i, ev_open, arrival)

    did_shed = jnp.bool_(False)
    if cfg.shedder in (SHED_PSPICE, SHED_PMBL):
        dec = ovl.detect_overload(model.f_model, model.g_model, l_q,
                                  n_pm.astype(jnp.int32), cfg.latency_bound,
                                  cfg.safety_buffer)
        c = jax.lax.cond(
            dec.shed & (dec.rho > 0),
            lambda cc: _shed_now(cfg, model, cc, i, dec.rho)[0],
            lambda cc: cc, c)
        did_shed = dec.shed & (dec.rho > 0)
    return _post_shed(cfg, model, c, ev, l_q, n_pm, did_shed)


def _post_shed(cfg: EngineConfig, model: EngineModel, c: Carry,
               ev: tuple, l_q: Array, n_pm: Array,
               did_shed: Array) -> tuple[Carry, StepOut]:
    """Steps 3-7: E-BL drop, advance/spawn, observations, simulated time."""
    (i, ev_class, ev_bind, ev_open, ev_id, ev_rand, ebl_raw, arrival) = ev

    # -- 3. E-BL input drop --------------------------------------------------
    ev_dropped = jnp.bool_(False)
    gap = jnp.maximum(arrival - c.prev_arrival, 1e-9)
    ema_gap = 0.99 * c.ema_gap + 0.01 * gap
    c = c._replace(ema_gap=ema_gap, prev_arrival=arrival)
    if cfg.shedder == SHED_EBL:
        dec = ovl.detect_overload(model.f_model, model.g_model, l_q,
                                  n_pm.astype(jnp.int32), cfg.latency_bound,
                                  cfg.safety_buffer)
        # Feedforward: drop fraction d s.t. d·c_ebl + (1-d)·l_p == 1/rate,
        # plus backlog-proportional pressure to drain existing queueing.
        l_p_est = ovl.predict_latency(model.f_model, n_pm)
        d_ff = (l_p_est - ema_gap) / jnp.maximum(l_p_est - cfg.c_ebl, 1e-9)
        d_bk = cfg.ebl_backlog_gain * l_q / cfg.latency_bound
        d_need = jnp.clip(d_ff + d_bk, 0.0, 1.0)
        ebl_frac = jnp.where(dec.shed,
                             jnp.maximum(c.ebl_frac * cfg.ebl_decay, d_need),
                             c.ebl_frac * cfg.ebl_decay)
        raw_eff = cfg.ebl_floor + (1.0 - cfg.ebl_floor) * ebl_raw
        mean_eff = cfg.ebl_floor + (1.0 - cfg.ebl_floor) * model.ebl_raw_mean
        p_drop = jnp.clip(raw_eff * ebl_frac /
                          jnp.maximum(mean_eff, 1e-9), 0.0, 1.0)
        ev_dropped = ev_rand < p_drop
        c = c._replace(ebl_frac=ebl_frac,
                       ebl_dropped=c.ebl_dropped + ev_dropped)
        did_shed = dec.shed

    pms = c.pms
    live_class = jnp.where(ev_dropped, jnp.zeros_like(ev_class), ev_class)
    live_open = jnp.where(ev_dropped, jnp.zeros_like(ev_open), ev_open)

    # -- 4. advance + completions -------------------------------------------
    pms2, s_old, s_new, completed = _advance(cfg, model, pms, live_class,
                                             ev_bind, ev_id)
    n_completed = completed.sum(axis=1).astype(jnp.float32)
    if cfg.emit_matches:
        # Identity of each completed match: advance never moves PM payloads,
        # so the completing slot's open_idx / bind are still in place.
        m_open = jnp.where(completed, pms.open_idx, -1)
        m_bind = jnp.where(completed, pms.bind,
                           jnp.full_like(pms.bind, -1))
    else:
        m_open = jnp.zeros((cfg.num_patterns, 0), jnp.int32)
        m_bind = jnp.zeros((cfg.num_patterns, 0), jnp.int32)

    # -- 5. spawn -------------------------------------------------------------
    pms3, spawned, oflow = _spawn(cfg, model, pms2, c.ring, i, live_open,
                                  live_class, ev_bind, ev_id)

    # -- 6. observations (model-building phase only) -------------------------
    obs_counts, obs_rewards = c.obs_counts, c.obs_rewards
    if cfg.gather_stats:
        P, N, M = cfg.num_patterns, cfg.max_pms, cfg.max_states
        w = pms.active.astype(jnp.float32)                    # observed PMs
        t = (cfg.c_match * model.proc_cost)[:, None] * w      # per-PM time
        pidx = jnp.arange(P, dtype=jnp.int32)[:, None] * jnp.ones(
            (1, N), jnp.int32)
        flat = (pidx * M + s_old) * M + s_new
        obs_counts = obs_counts.reshape(-1).at[flat.reshape(-1)].add(
            w.reshape(-1)).reshape(P, M, M)
        obs_rewards = obs_rewards.reshape(-1).at[flat.reshape(-1)].add(
            t.reshape(-1)).reshape(P, M, M)

    # -- 7. simulated processing time & latency ------------------------------
    n_active_p = pms.active.sum(axis=1).astype(jnp.float32)  # matched-against
    t_proc = cfg.c_base + (cfg.c_match * model.proc_cost * n_active_p).sum()
    t_proc = jnp.where(ev_dropped, cfg.c_ebl, t_proc)
    sim_time = c.sim_time + t_proc
    l_e = sim_time - arrival

    # latency samples for fitting f (n_pm -> l_p): store (n, t_proc).
    S = c.lat_samples_n.shape[0]
    ptr = c.lat_ptr % S
    lat_n = c.lat_samples_n.at[ptr].set(n_pm)
    lat_l = c.lat_samples_l.at[ptr].set(t_proc)

    c = Carry(
        pms=pms3, ring=c.ring, ring_ptr=c.ring_ptr, sim_time=sim_time,
        key=c.key, ebl_frac=c.ebl_frac, ema_gap=c.ema_gap,
        prev_arrival=c.prev_arrival,
        complex_count=c.complex_count + n_completed,
        pms_created=c.pms_created + spawned,
        pms_shed=c.pms_shed, shed_calls=c.shed_calls,
        overflow=c.overflow + oflow, ebl_dropped=c.ebl_dropped,
        obs_counts=obs_counts, obs_rewards=obs_rewards,
        lat_samples_n=lat_n, lat_samples_l=lat_l, lat_ptr=c.lat_ptr + 1,
    )
    out = StepOut(l_e=l_e, n_pm=pms3.active.sum().astype(jnp.float32),
                  shed=did_shed, dropped=ev_dropped,
                  match_open=m_open, match_bind=m_bind)
    return c, out


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def _scan_events(cfg: EngineConfig, model: EngineModel, events: EventBatch,
                 carry: Carry, start: Array) -> tuple[Carry, StepOut]:
    """The one scan both entry points share: event indices are GLOBAL
    (``start + arange``), so scanning a stream in consecutive chunks
    replays the exact op sequence of one monolithic scan — window expiry,
    ring bookkeeping and spawn open-indices all key off the global index."""
    n = events.ev_class.shape[0]
    idx = jnp.int32(start) + jnp.arange(n, dtype=jnp.int32)
    xs = (idx, events.ev_class, events.ev_bind,
          events.ev_open, events.ev_id, events.ev_rand, events.ebl_raw,
          events.arrival)
    step = functools.partial(_step, cfg, model)
    return jax.lax.scan(step, carry, xs)


# ---------------------------------------------------------------------------
# Event-block execution (backend="pallas_block", DESIGN.md §10)
# ---------------------------------------------------------------------------

def _pad_event_blocks(events: EventBatch, n: int, w: int,
                      axis: int = 0) -> tuple[EventBatch, int]:
    """Pad the event axis to a multiple of ``w`` (masked in-kernel) and
    reshape it into (nb, w) blocks; returns (blocked events, nb)."""
    pad = ktile.tile_pad(w, n)
    nb = max(1, (n + pad) // w)
    pad = nb * w - n

    def f(x):
        if pad:
            widths = [(0, 0)] * x.ndim
            widths[axis] = (0, pad)
            x = jnp.pad(x, widths)
        return x.reshape(x.shape[:axis] + (nb, w) + x.shape[axis + 1:])

    return jax.tree.map(f, events), nb


@count_traces("cep._run_block")
def _run_block(cfg: EngineConfig, model: EngineModel, carry: Carry,
               blk: tuple, i0: Array, n_valid: Array) -> tuple[Carry, dict]:
    """One event block through the fused kernel (DESIGN.md §10).

    Default (``block_shed="fused"``): exactly ONE launch per block for
    every shedder — Algorithm-2 fires are handled inside the kernel, so
    overload is the fast path, not an escape hatch.

    Legacy oracle (``block_shed="replay"``, or any ``shed_plan="sort"``
    config): the kernel commits events until the Algorithm-1 check
    fires; the fired event is then replayed through the ordinary
    ``_step`` — which re-derives the identical overload decision from
    the committed carry and runs the host-level Algorithm-2 shed — and
    the kernel re-enters at the next event.  Fire-at-the-tail re-entry
    is safe by construction: with ``fire_idx + 1 == n_valid`` the while
    cond is immediately false, so no zero-width relaunch happens (under
    vmap the batched while keeps finished lanes on identity relaunches;
    non-fired lanes carry the ``fire_idx = W`` sentinel, whose replay
    reads are clamped and discarded by the per-lane ``fired`` select).
    """
    W = cfg.block_events
    interp = kops.default_interpret()
    ev_blk = EventBatch(*blk)

    if cfg.shedder not in (SHED_PSPICE, SHED_PMBL) or kblock.fused_shed(cfg):
        carry, rows, _, _ = kblock.block_step(
            cfg, model, carry, ev_blk, i0, 0, n_valid, interpret=interp)
        return carry, rows

    rows0 = dict(
        l_e=jnp.zeros((W,), jnp.float32), n_pm=jnp.zeros((W,), jnp.float32),
        shed=jnp.zeros((W,), bool), dropped=jnp.zeros((W,), bool),
        match_open=jnp.zeros(
            (W, cfg.num_patterns, cfg.max_pms if cfg.emit_matches else 0),
            jnp.int32),
        match_bind=jnp.zeros(
            (W, cfg.num_patterns, cfg.max_pms if cfg.emit_matches else 0),
            jnp.int32))
    jidx = jnp.arange(W, dtype=jnp.int32)

    def cond(st):
        return st[0] < n_valid

    def body(st):
        s, c, rows = st
        c2, krows, fired, fire_idx = kblock.block_step(
            cfg, model, c, ev_blk, i0, s, n_valid, interpret=interp)
        stop = jnp.where(fired, fire_idx, n_valid)
        mask = (jidx >= s) & (jidx < stop)
        rows = {k: jnp.where(mask.reshape((W,) + (1,) * (v.ndim - 1)),
                             krows[k], v) for k, v in rows.items()}

        def on_fire(args):
            c3, rows3 = args
            j = fire_idx
            ev = tuple(jax.lax.dynamic_index_in_dim(x, j, keepdims=False)
                       for x in (jidx,) + blk)
            ev = (i0 + ev[0],) + ev[1:]
            c3, row = _step(cfg, model, c3, ev)
            row_d = dict(l_e=row.l_e, n_pm=row.n_pm, shed=row.shed,
                         dropped=row.dropped, match_open=row.match_open,
                         match_bind=row.match_bind)
            rows3 = {k: v.at[j].set(row_d[k]) for k, v in rows3.items()}
            return c3, rows3

        c2, rows = jax.lax.cond(fired, on_fire, lambda a: a, (c2, rows))
        return (jnp.where(fired, fire_idx + 1, n_valid), c2, rows)

    _, carry, rows = jax.lax.while_loop(
        cond, body, (jnp.int32(0), carry, rows0))
    return carry, rows


def _scan_event_blocks(cfg: EngineConfig, model: EngineModel,
                       events: EventBatch, carry: Carry,
                       start: Array) -> tuple[Carry, StepOut]:
    """``_scan_events`` with the per-event step fused into one kernel
    launch per ``cfg.block_events`` events: the outer scan runs over
    event BLOCKS, and each block's W events execute inside
    ``kernels.block_step`` with the PM store resident.  Event indices
    stay global, so monolithic, chunked and blocked execution all replay
    the identical op sequence (bit-for-bit with backend="xla")."""
    n = events.ev_class.shape[0]
    W = cfg.block_events
    blocks, nb = _pad_event_blocks(events, n, W)
    offs = jnp.arange(nb, dtype=jnp.int32) * W

    def body(c, xs):
        blk, off = xs
        n_valid = jnp.clip(jnp.int32(n) - off, 0, W)
        return _run_block(cfg, model, c, tuple(blk), jnp.int32(start) + off,
                          n_valid)

    carry, rows = jax.lax.scan(body, carry, (blocks, offs))
    outs = StepOut(**{k: v.reshape((nb * W,) + v.shape[2:])[:n]
                      for k, v in rows.items()})
    return carry, outs


def _scan_event_blocks_lanes(cfg: EngineConfig, model: EngineModel,
                             events: EventBatch, carry: Carry,
                             start: Array) -> tuple[Carry, StepOut]:
    """Lane-batched ``_scan_event_blocks``: the fused kernel vmaps over
    the lane axis (lanes are independent operators — per-lane results
    are bitwise those of the single-lane block scan, which equals the
    per-event engine).  Fire handling composes with vmap in both shed
    modes: fused (default) needs nothing special — each lane's kernel
    resolves its own Algorithm-2 fires in the single launch; on the
    legacy replay path the batched while loop runs until every lane
    committed its block (finished lanes relaunch as identity) and the
    replayed ``_step`` commits only on lanes whose own check fired."""
    L, n = events.ev_class.shape[0], events.ev_class.shape[1]
    W = cfg.block_events
    blocks, nb = _pad_event_blocks(events, n, W, axis=1)
    blocks = jax.tree.map(lambda x: jnp.moveaxis(x, 1, 0), blocks)
    offs = jnp.arange(nb, dtype=jnp.int32) * W

    def body(c, xs):
        blk, off = xs
        n_valid = jnp.clip(jnp.int32(n) - off, 0, W)
        i0 = jnp.int32(start) + off
        run = lambda m, cc, b: _run_block(   # noqa: E731
            cfg, m, cc, tuple(b), i0, n_valid)
        return jax.vmap(run)(model, c, blk)

    carry, rows = jax.lax.scan(body, carry, (blocks, offs))
    outs = StepOut(**{
        k: jnp.moveaxis(v, 0, 1).reshape((L, nb * W) + v.shape[3:])[:, :n]
        for k, v in rows.items()})
    return carry, outs


def _scan_events_backend(cfg: EngineConfig, model: EngineModel,
                         events: EventBatch, carry: Carry,
                         start: Array) -> tuple[Carry, StepOut]:
    """Backend dispatch for every event-scan entry point (run_engine,
    run_engine_chunk, the runtime's group runners)."""
    if cfg.backend == BACKEND_PALLAS_BLOCK:
        return _scan_event_blocks(cfg, model, events, carry, start)
    return _scan_events(cfg, model, events, carry, start)


def _scan_events_lanes_backend(cfg: EngineConfig, model: EngineModel,
                               events: EventBatch, carry: Carry,
                               start: Array) -> tuple[Carry, StepOut]:
    """Lane-batched backend dispatch (runtime lanes + sharded lanes)."""
    if cfg.backend == BACKEND_PALLAS_BLOCK:
        return _scan_event_blocks_lanes(cfg, model, events, carry, start)
    return _scan_events_lanes(cfg, model, events, carry, start)


@count_traces("cep._step_lanes")
def _step_lanes(cfg: EngineConfig, model: EngineModel, carry: Carry,
                ev: tuple) -> tuple[Carry, StepOut]:
    """Lane-batched event step for the multi-tenant runtime (DESIGN.md §7).

    ``model``/``carry`` leaves have a leading (L,) lane axis; ``ev``
    leaves are lane-stacked except the shared global index ``i`` (lanes
    advance in lockstep).  Naively vmapping ``_step`` would turn the
    per-lane shed ``lax.cond`` into a select that executes the O(N log N)
    shed path on EVERY event for EVERY lane; instead the overload
    decisions are computed batched (elementwise, cheap) and the expensive
    shed runs under a SCALAR ``any(lane sheds)`` gate.  Per-lane results
    stay bitwise identical to the sequential engine: lanes that shed get
    exactly ``_shed_now``'s output, the rest keep their carry bits.
    """
    (i, ev_class, ev_bind, ev_open, ev_id, ev_rand, ebl_raw, arrival) = ev
    c, l_q, n_pm = jax.vmap(
        functools.partial(_pre_shed, cfg),
        in_axes=(0, 0, None, 0, 0))(model, carry, i, ev_open, arrival)
    L = l_q.shape[0]
    did_shed = jnp.zeros((L,), bool)
    if cfg.shedder in (SHED_PSPICE, SHED_PMBL):
        # Elementwise over the lane axis — no vmap needed.
        dec = ovl.detect_overload(model.f_model, model.g_model, l_q,
                                  n_pm.astype(jnp.int32), cfg.latency_bound,
                                  cfg.safety_buffer)
        want = dec.shed & (dec.rho > 0)

        def shed_lanes(cc: Carry) -> Carry:
            shed_c = jax.vmap(
                lambda m, c1, r: _shed_now(cfg, m, c1, i, r)[0])(
                    model, cc, dec.rho)
            sel = lambda a, b: jnp.where(                    # noqa: E731
                want.reshape((L,) + (1,) * (a.ndim - 1)), a, b)
            return jax.tree.map(sel, shed_c, cc)

        c = jax.lax.cond(jnp.any(want), shed_lanes, lambda cc: cc, c)
        did_shed = want
    return jax.vmap(
        functools.partial(_post_shed, cfg),
        in_axes=(0, 0, (None, 0, 0, 0, 0, 0, 0, 0), 0, 0, 0))(
            model, c, ev, l_q, n_pm, did_shed)


def _scan_events_lanes(cfg: EngineConfig, model: EngineModel,
                       events: EventBatch, carry: Carry,
                       start: Array) -> tuple[Carry, StepOut]:
    """Lane-batched ``_scan_events``: events are lane-stacked (L, n, ...);
    the scan runs over the event axis with ``_step_lanes`` as its body.
    Returned StepOut leaves are lane-stacked (L, n)."""
    n = events.ev_class.shape[1]
    idx = jnp.int32(start) + jnp.arange(n, dtype=jnp.int32)
    ev_t = jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), events)
    xs = (idx, ev_t.ev_class, ev_t.ev_bind, ev_t.ev_open, ev_t.ev_id,
          ev_t.ev_rand, ev_t.ebl_raw, ev_t.arrival)
    step = functools.partial(_step_lanes, cfg, model)
    carry, outs = jax.lax.scan(step, carry, xs)
    return carry, jax.tree.map(lambda x: jnp.moveaxis(x, 0, 1), outs)


@ctr.contract("cep.run_engine",
              max_while=12, max_cond=24, max_compiles=1,
              max_temp_bytes=ctr.hot_path_temp_budget,
              max_gather_bytes=ctr.hot_path_gather_budget)
@functools.partial(jax.jit, static_argnames=("cfg",))
def run_engine(cfg: EngineConfig, model: EngineModel, events: EventBatch,
               carry: Carry) -> tuple[Carry, StepOut]:
    """Run the operator over a whole event stream (one lax.scan)."""
    return _scan_events_backend(cfg, model, events, carry, jnp.int32(0))


def wrap_event_index(start) -> Array:
    """An unbounded Python event index as a wrap-safe int32 scalar.

    The engine's window arithmetic is int32 differences (``i - open_idx``,
    ``i - ring``), which stay correct across two's-complement wraparound
    as long as windows are << 2^31 — but ``jnp.int32(start)`` raises
    OverflowError once a streamed index reaches 2^31.  Mapping the index
    into int32 modular space keeps the runtime's unbounded-stream claim
    honest past 2.1B events.
    """
    wrapped = int(start) & 0xFFFFFFFF
    return jnp.asarray(np.uint32(wrapped).astype(np.int32))


@ctr.contract("cep.run_engine_chunk",
              donate=("carry", "events"),
              max_while=12, max_cond=24, max_compiles=2,
              max_temp_bytes=ctr.hot_path_temp_budget,
              max_gather_bytes=ctr.hot_path_gather_budget)
@functools.partial(jax.jit, static_argnames=("cfg",),
                   donate_argnames=("carry", "events"))
def run_engine_chunk(cfg: EngineConfig, model: EngineModel,
                     events: EventBatch, carry: Carry,
                     start: Array) -> tuple[Carry, StepOut]:
    """One micro-batch of the chunked runtime (repro.runtime, DESIGN.md §7).

    Identical semantics to ``run_engine`` restricted to events
    ``[start, start + chunk)``; the carry is DONATED so the steady-state
    loop reuses its buffers (constant memory over an unbounded stream),
    and so are the chunk's event buffers — each chunk slice is consumed
    exactly once, and donating it lets XLA write the per-event StepOut
    columns into the arriving chunk's storage instead of fresh
    allocations.  ``start`` is a traced scalar, so every same-length
    chunk hits one compiled executable — zero retraces while streaming.
    """
    return _scan_events_backend(cfg, model, events, carry, start)


def merge_carries(stacked: Carry, axis: int = 0) -> Carry:
    """Fold an L-lane-stacked carry (every leaf has a leading lane axis)
    into one flat carry over L·P patterns — the lane-merge used by the
    runtime's telemetry and by model refresh over multi-tenant state.

    Pattern-dim state (PM store, rings, per-pattern counters, obs
    matrices) concatenates along the pattern axis; scalar counters sum;
    clocks take the slowest lane (``max``, mirroring the sharded engine's
    pmax semantics in repro.dist); the latency ring keeps per-slot global
    PM counts (sum) against the slowest lane's per-event time (max).
    """
    def _flat(x):  # (L, P, ...) -> (L·P, ...)
        x = jnp.moveaxis(x, axis, 0)
        return x.reshape((-1,) + x.shape[2:])

    pms = PMStore(*[_flat(x) for x in stacked.pms])
    if jax.tree.leaves(stacked)[0].shape[axis] == 0:
        # Zero-lane merge: the flattened pattern state is (0, ...) and
        # every folded scalar takes its reduction identity (max over no
        # lanes = the zero clock) instead of tripping the empty-axis
        # reduction error.
        zero = lambda x: jnp.zeros(                      # noqa: E731
            x.shape[:axis] + x.shape[axis + 1:], x.dtype)
        mx = sm = first = zero
    else:
        mx = lambda x: x.max(axis=axis)          # noqa: E731
        sm = lambda x: x.sum(axis=axis)          # noqa: E731
        first = lambda x: jnp.take(x, 0, axis=axis)  # noqa: E731
    return Carry(
        pms=pms, ring=_flat(stacked.ring), ring_ptr=_flat(stacked.ring_ptr),
        sim_time=mx(stacked.sim_time), key=first(stacked.key),
        ebl_frac=mx(stacked.ebl_frac), ema_gap=mx(stacked.ema_gap),
        prev_arrival=mx(stacked.prev_arrival),
        complex_count=_flat(stacked.complex_count),
        pms_created=_flat(stacked.pms_created),
        pms_shed=sm(stacked.pms_shed), shed_calls=sm(stacked.shed_calls),
        overflow=sm(stacked.overflow), ebl_dropped=sm(stacked.ebl_dropped),
        obs_counts=_flat(stacked.obs_counts),
        obs_rewards=_flat(stacked.obs_rewards),
        lat_samples_n=sm(stacked.lat_samples_n),
        lat_samples_l=mx(stacked.lat_samples_l),
        lat_ptr=mx(stacked.lat_ptr),
    )


# ---------------------------------------------------------------------------
# Durable-state manifest (repro.runtime.persist)
# ---------------------------------------------------------------------------

def pytree_manifest(tree) -> list[dict]:
    """Leaf schema of a pytree in ``jax.tree_util`` flatten order:
    ``[{"path", "dtype", "shape"}, ...]``.

    This is the validation half of the durable snapshot codec
    (``repro.runtime.persist``): a snapshot records the manifest it was
    written with, and a restore only proceeds when it matches the live
    tree's manifest — a mismatch means the snapshot belongs to a
    different config (shapes) or code version (structure) and must be
    surfaced, never coerced.
    """
    out = []
    for path, leaf in jax.tree_util.tree_leaves_with_path(tree):
        arr = np.asarray(leaf)
        out.append({"path": jax.tree_util.keystr(path),
                    "dtype": arr.dtype.str, "shape": list(arr.shape)})
    return out


def carry_manifest(cfg: EngineConfig, seed: int = 0,
                   lat_capacity: int = 4096) -> list[dict]:
    """The manifest any durable snapshot of this config's carry must
    match (``init_carry`` shapes are a pure function of the config)."""
    return pytree_manifest(init_carry(cfg, seed=seed,
                                      lat_capacity=lat_capacity))


# ---------------------------------------------------------------------------
# Results summary
# ---------------------------------------------------------------------------

def match_sets(outs: StepOut, start: int = 0) -> list[set[tuple]]:
    """Decode emitted matches into per-pattern sets of match identities.

    A match is the tuple ``(open_idx, bind, end_idx)`` — the completing
    PM's window-open event index, its binding value, and the global index
    of the completing event.  One PM exists per such identity (spawn
    dedupes on (open_idx, bind)), so the match multiset IS a set; this is
    the equality ``repro.eval`` uses for differential and metamorphic
    testing (DESIGN.md §9).  Requires ``cfg.emit_matches``; ``start`` is
    the global index of the first event (chunked runs pass their chunk
    start and union the per-chunk sets).
    """
    m_open = np.asarray(outs.match_open)         # (n, P, N)
    m_bind = np.asarray(outs.match_bind)
    if m_open.ndim != 3 or m_open.shape[-1] == 0:
        raise ValueError("run had cfg.emit_matches off — no match identity "
                         "was emitted (match fields are zero-width)")
    n, P, _ = m_open.shape
    out: list[set[tuple]] = [set() for _ in range(P)]
    ev, p, slot = np.nonzero(m_open >= 0)
    for e, q, s in zip(ev.tolist(), p.tolist(), slot.tolist()):
        out[q].add((int(m_open[e, q, s]), int(m_bind[e, q, s]),
                    start + e))
    return out


@dataclasses.dataclass
class RunResult:
    complex_count: np.ndarray   # (P,)
    pms_created: np.ndarray     # (P,)
    pms_shed: float
    shed_calls: float
    overflow: float
    ebl_dropped: float
    l_e: np.ndarray             # (n,)
    n_pm: np.ndarray            # (n,)
    carry: Carry
    # Per-pattern match-identity sets (cfg.emit_matches runs; else None).
    matches: list | None = None

    @property
    def match_probability(self) -> np.ndarray:
        return self.complex_count / np.maximum(self.pms_created, 1.0)

    def false_negatives(self, ground_truth: "RunResult",
                        weights: np.ndarray | None = None) -> float:
        """Weighted FN fraction vs a no-shed run on the same stream (§II-B)."""
        gt = np.maximum(ground_truth.complex_count, 1e-9)
        fn = np.maximum(gt - self.complex_count, 0.0)
        w = np.ones_like(gt) if weights is None else np.asarray(weights)
        return float((w * fn).sum() / (w * gt).sum())


def summarize(carry: Carry, outs: StepOut) -> RunResult:
    emitted = np.asarray(outs.match_open).ndim == 3 and \
        outs.match_open.shape[-1] > 0
    return RunResult(
        complex_count=np.asarray(carry.complex_count),
        pms_created=np.asarray(carry.pms_created),
        pms_shed=float(carry.pms_shed),
        shed_calls=float(carry.shed_calls),
        overflow=float(carry.overflow),
        ebl_dropped=float(carry.ebl_dropped),
        l_e=np.asarray(outs.l_e),
        n_pm=np.asarray(outs.n_pm),
        carry=carry,
        matches=match_sets(outs) if emitted else None,
    )
