"""Experiment runner: the full pSPICE lifecycle (paper §IV experimental
methodology).

  1. WARM-UP at a sustainable rate with statistic gathering on — the model
     builder's observation phase (§III-C).
  2. MODEL BUILD: transition matrices, reward matrices, MRP value iteration,
     utility tables; latency regressions f (from gathered samples) and g.
  3. MAX-THROUGHPUT measurement from the calibrated cost model at the warm
     steady-state PM population ("maximum operator throughput" in §IV-A).
  4. OVERLOAD RUN at rate = multiplier × max throughput with the chosen
     shedder, vs. a no-shed GROUND-TRUTH run on the identical stream.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.core import markov, overload as ovl, utility as util
from repro.data import streams

Array = jnp.ndarray


@dataclasses.dataclass
class BuiltModel:
    """Everything the model builder produces."""
    T: list          # per-pattern transition matrices
    R: list          # per-pattern reward matrices
    tables: list     # per-pattern UtilityTable
    ut_stacked: Array
    ut_bins: Array
    f_model: ovl.LatencyModel
    g_model: ovl.LatencyModel
    max_rate: float  # measured max operator throughput (events/s)
    steady_n_pm: float


def default_config(cp: pat.CompiledPatterns, **kw) -> eng.EngineConfig:
    """Engine config with the static pattern census filled in.

    ``backend`` selects the hot-path implementation (DESIGN.md §8/§10):
    the jnp reference scan, the per-event Pallas kernels, or the
    event-block megakernel (``backend="pallas_block"`` with
    ``block_events=W`` fused per launch) — all bitwise-equivalent, so
    experiments may pick purely on speed.  Unknown backends / bad block
    sizes fail at config-build time (``EngineConfig.__post_init__``),
    never as a silent xla-path fallback mid-experiment.
    """
    kind, sm = np.asarray(cp.kind), np.asarray(cp.spawn_mode)
    base = dict(
        num_patterns=cp.num_patterns,
        max_states=cp.max_states,
        max_classes=cp.trans.shape[2] - 1,
        max_pms=2048,
        max_any_ids=max(8, int(cp.final_state.max()) + 1),
        ring_size=8,
        # Static pattern census: lets the engine skip the per-event ops of
        # pattern families that cannot occur (bitwise-identical to "mixed").
        kinds=("seq" if (kind == pat.KIND_SEQ).all()
               else "any" if (kind == pat.KIND_ANY).all() else "mixed"),
        spawn_modes=("at_open" if (sm == pat.SPAWN_AT_OPEN).all()
                     else "in_windows" if (sm == pat.SPAWN_IN_WINDOWS).all()
                     else "mixed"),
    )
    base.update(kw)
    return eng.EngineConfig(**base)


def build_model(specs: Sequence[pat.PatternSpec], cfg: eng.EngineConfig,
                warm_events: streams.EventBatch, bin_size: int = 64,
                use_remaining_time: bool = True,
                seed: int = 0) -> BuiltModel:
    """Phase 1+2: warm-up run with stats on, then build everything."""
    cp = pat.compile_patterns(specs)
    # No match emission during warm-up: nothing reads the identity
    # columns here, and they would be (n_warm, P, N) of dead output.
    warm_cfg = dataclasses.replace(cfg, gather_stats=True,
                                   shedder=eng.SHED_NONE,
                                   emit_matches=False)
    model0 = eng.make_model(cp, warm_cfg)
    carry = eng.init_carry(warm_cfg, seed=seed)
    carry, outs = eng.run_engine(warm_cfg, model0, warm_events, carry)

    Ts, Rs, tables = [], [], []
    for p, spec in enumerate(specs):
        m = spec.num_states
        stats = markov.TransitionStats(
            counts=carry.obs_counts[p, :m, :m],
            reward_sum=carry.obs_rewards[p, :m, :m])
        T = markov.estimate_transition_matrix(stats)
        R = markov.estimate_reward_matrix(
            stats, default_reward=cfg.c_match * float(spec.proc_cost))
        Ts.append(T)
        Rs.append(R)
        tables.append(util.build_utility_table(
            T, R, window_size=spec.window_size, bin_size=bin_size,
            weight=spec.weight, use_remaining_time=use_remaining_time))
    ut_stacked, ut_bins = util.stack_tables(tables,
                                            max_states=cp.max_states)

    # Latency regression f from the gathered (n_pm, t_proc) samples.
    S = carry.lat_samples_n.shape[0]
    n_valid = jnp.minimum(carry.lat_ptr, S)
    valid = jnp.arange(S) < n_valid
    f_model = ovl.fit_latency_model(carry.lat_samples_n,
                                    carry.lat_samples_l, valid)
    # g (shed latency) from the simulator's true sort-cost model — in a real
    # deployment these samples come from observed shed calls; the warm run
    # never sheds, so we use the calibrated constants directly.
    g_model = ovl.LatencyModel(a=jnp.float32(cfg.c_shed_pm),
                               b=jnp.float32(cfg.c_shed_base),
                               kind=jnp.int32(ovl.LINEAR))

    # Max throughput at the warm steady state: 1 / E[t_proc].
    n_tail = max(1, warm_events.ev_class.shape[0] // 2)
    steady_n_pm = float(np.asarray(outs.n_pm)[-n_tail:].mean())
    t_proc = float(ovl.predict_latency(f_model, jnp.float32(steady_n_pm)))
    max_rate = 1.0 / max(t_proc, 1e-9)
    return BuiltModel(T=Ts, R=Rs, tables=tables, ut_stacked=ut_stacked,
                      ut_bins=ut_bins, f_model=f_model, g_model=g_model,
                      max_rate=max_rate, steady_n_pm=steady_n_pm)


def run_with_shedder(specs: Sequence[pat.PatternSpec],
                     cfg: eng.EngineConfig, built: BuiltModel,
                     raw: streams.RawStream, rate: float, shedder: str,
                     seed: int = 0,
                     pattern_parallel: bool = False) -> eng.RunResult:
    cp = pat.compile_patterns(specs)
    run_cfg = dataclasses.replace(cfg, gather_stats=False, shedder=shedder)
    events = streams.classify(specs, raw, rate=rate, seed=seed)
    model = eng.make_model(cp, run_cfg, ut_tables=built.ut_stacked,
                           ut_bins=built.ut_bins, f_model=built.f_model,
                           g_model=built.g_model,
                           ebl_raw_mean=float(
                               np.asarray(events.ebl_raw).mean()))
    carry = eng.init_carry(run_cfg, seed=seed)
    if pattern_parallel:
        # Pattern-parallel scale-out: shard the (P, N) PM store over the
        # local device mesh (repro.dist.sharding.pm_specs / shard_map).
        from repro.dist import sharding as SH
        carry, outs = SH.run_engine_sharded(run_cfg, model, events, carry)
    else:
        carry, outs = eng.run_engine(run_cfg, model, events, carry)
    return eng.summarize(carry, outs)


@dataclasses.dataclass
class ExperimentResult:
    shedder: str
    fn: float                 # weighted false-negative fraction (count-based)
    match_probability: float  # ground-truth match probability
    max_rate: float
    result: eng.RunResult
    ground_truth: eng.RunResult
    latency_bound: float = 1.0  # the configured LB the run was held to
    # Match-SET quality metrics (repro.eval.quality, DESIGN.md §9) —
    # populated when the runs emitted matches (``emit_matches``, the
    # run_experiment default).  ``fn`` above compares completion COUNTS;
    # ``fn_match`` compares identities, so a shedder that loses one match
    # while a different one completes cannot cancel the loss out.
    recall: float | None = None        # weighted |found ∩ gt| / |gt|
    fn_match: float | None = None      # 1 - recall
    per_pattern_fn: np.ndarray | None = None   # (P,)
    n_gt_matches: int = 0
    n_found_matches: int = 0

    @property
    def lb_violations(self) -> float:
        """Fraction of events whose latency exceeded the configured bound.
        An empty run (zero events) violated nothing — the unguarded
        ``mean()`` of an empty array would be NaN."""
        l_e = np.asarray(self.result.l_e)
        if l_e.size == 0:
            return 0.0
        return float((l_e > self.latency_bound).mean())

    @property
    def lb_compliance(self) -> float:
        """Fraction of events whose latency met the configured bound
        (delegates to the one §IV-B metric definition in repro.eval)."""
        from repro.eval import quality as Q
        return Q.latency_compliance(self.result.l_e, self.latency_bound)


def run_experiment(specs: Sequence[pat.PatternSpec], raw: streams.RawStream,
                   shedders: Sequence[str] = (eng.SHED_PSPICE, eng.SHED_PMBL,
                                              eng.SHED_EBL),
                   rate_multiplier: float = 1.2,
                   warm_frac: float = 0.3, latency_bound: float = 1.0,
                   bin_size: int = 64, max_pms: int = 2048,
                   use_remaining_time: bool = True,
                   seed: int = 0, pattern_parallel: bool = False,
                   emit_matches: bool = True,
                   **cfg_kw) -> dict[str, ExperimentResult]:
    """The full paper methodology on one stream; returns per-shedder results.

    With ``emit_matches`` (the default) every run emits its match
    identities and the summary carries match-SET quality metrics (recall
    / fn_match vs the no-shed ground truth) next to the legacy
    count-based ``fn``."""
    cp = pat.compile_patterns(specs)
    cfg = default_config(cp, latency_bound=latency_bound, max_pms=max_pms,
                         emit_matches=emit_matches, **cfg_kw)

    n_warm = int(raw.n * warm_frac)
    raw_warm = dataclasses.replace(
        raw, n=n_warm, type_id=raw.type_id[:n_warm], attr=raw.attr[:n_warm],
        group=raw.group[:n_warm])
    raw_run = dataclasses.replace(
        raw, n=raw.n - n_warm, type_id=raw.type_id[n_warm:],
        attr=raw.attr[n_warm:], group=raw.group[n_warm:])

    # Warm-up below capacity: use a conservative low rate.
    warm_events = streams.classify(specs, raw_warm, rate=1.0, seed=seed)
    built = build_model(specs, cfg, warm_events, bin_size=bin_size,
                        use_remaining_time=use_remaining_time, seed=seed)

    rate = built.max_rate * rate_multiplier
    gt = run_with_shedder(specs, cfg, built, raw_run, rate=rate,
                          shedder=eng.SHED_NONE, seed=seed,
                          pattern_parallel=pattern_parallel)
    weights = np.array([s.weight for s in specs])
    out = {}
    for sh in shedders:
        res = run_with_shedder(specs, cfg, built, raw_run, rate=rate,
                               shedder=sh, seed=seed,
                               pattern_parallel=pattern_parallel)
        er = ExperimentResult(
            shedder=sh,
            fn=res.false_negatives(gt, weights),
            match_probability=float(
                gt.complex_count.sum() / max(gt.pms_created.sum(), 1.0)),
            max_rate=built.max_rate,
            result=res, ground_truth=gt,
            latency_bound=latency_bound)
        if res.matches is not None and gt.matches is not None:
            # Imported here: repro.eval's public surface pulls in the
            # sweep driver, which imports this module.
            from repro.eval import quality as Q
            rep = Q.compare_match_sets(res.matches, gt.matches, weights)
            er.recall = rep.recall
            er.fn_match = rep.fn_ratio
            er.per_pattern_fn = rep.per_pattern_fn
            er.n_gt_matches = rep.n_gt
            er.n_found_matches = rep.n_found
        out[sh] = er
    return out
