"""SLO-bounded continuous-batching scheduler with pSPICE eviction.

The paper's control loop (§III) transplanted onto LLM decoding:

  CEP concept              serving concept
  ----------------------   -------------------------------------------
  partial match (PM)       in-flight decode sequence (KV slot)
  PM state  s_i            progress bucket (tokens decoded / bucket_sz)
  events left in window    decode steps left in the request's deadline
  completion probability   P(sequence reaches EOS before its deadline),
                           from a Markov chain over progress buckets whose
                           absorbing state is EOS (learned online from
                           observed EOS hazards)
  remaining proc. time     expected remaining decode-step cost (Markov
                           reward process; reward = measured step cost,
                           which grows with the active batch)
  l_p = f(n_pm)            measured batch-step latency vs active slots
  utility U = w·P/tau      same formula, same min-max scaling
  Alg.1 overload detector  queue-delay + step-latency SLO check
  Alg.2 shedder            evict lowest-utility sequences (free KV slots)

Eviction baselines mirror the paper's: random eviction (PM-BL) and
admission-only throttling (E-BL analog: refuse new requests, never evict).

The scheduler is simulation-friendly (deterministic virtual time driven by a
per-step cost model calibrated from the real decode_step wall-clock) so the
benchmark (benchmarks/serving_shed.py) is reproducible on CPU.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core import markov as MK
from repro.core import overload as OV
from repro.core import utility as UT

import jax.numpy as jnp


@dataclasses.dataclass
class Request:
    req_id: int
    arrival: float
    deadline: float           # absolute SLO deadline
    true_length: int          # tokens until EOS (hidden ground truth)
    weight: float = 1.0
    decoded: int = 0
    done: bool = False
    evicted: bool = False
    finish_time: float = -1.0


@dataclasses.dataclass(frozen=True)
class SchedulerConfig:
    max_slots: int = 64               # KV capacity (the PM store)
    bucket_size: int = 32             # tokens per progress bucket
    num_buckets: int = 16             # states incl. absorbing EOS
    step_cost_base: float = 2e-3      # s per decode step
    step_cost_per_seq: float = 2e-4   # s per active sequence per step
    slo: float = 2.0                  # seconds from arrival to completion
    policy: str = "pspice"            # pspice | random | admission
    safety_buffer: float = 0.0
    seed: int = 0


class PSpiceScheduler:
    """Virtual-time continuous batcher with utility-driven eviction."""

    def __init__(self, cfg: SchedulerConfig):
        self.cfg = cfg
        self.time = 0.0
        self.active: list[Request] = []
        self.queue: list[Request] = []
        self.finished: list[Request] = []
        m = cfg.num_buckets
        self._counts = np.zeros((m, m))
        self._rewards = np.zeros((m, m))
        self.ut: UT.UtilityTable | None = None
        self._ut_np: np.ndarray | None = None
        self.rng = np.random.default_rng(cfg.seed)
        # latency model f(n_active) — the true cost model is linear; the
        # scheduler LEARNS it from observed step samples like the paper's f.
        self._lat_samples: list[tuple[int, float]] = []
        self.f_model: OV.LatencyModel | None = None
        self.evictions = 0

    # -- model building (the paper's model builder) -----------------------
    def _observe(self, s: int, s_next: int, t: float) -> None:
        self._counts[s, s_next] += 1
        self._rewards[s, s_next] += t

    def build_model(self) -> None:
        m = self.cfg.num_buckets
        stats = MK.TransitionStats(counts=jnp.asarray(self._counts, jnp.float32),
                                   reward_sum=jnp.asarray(self._rewards, jnp.float32))
        T = MK.estimate_transition_matrix(stats)
        R = MK.estimate_reward_matrix(
            stats, default_reward=self.cfg.step_cost_per_seq)
        # "window size" = max decode steps within the SLO at nominal cost
        ws = max(2 * self.cfg.bucket_size * m, 64)
        self.ut = UT.build_utility_table(T, R, window_size=ws,
                                         bin_size=self.cfg.bucket_size)
        self._ut_np = np.asarray(self.ut.table)
        if len(self._lat_samples) >= 8:
            n = jnp.array([s[0] for s in self._lat_samples], jnp.float32)
            lt = jnp.array([s[1] for s in self._lat_samples], jnp.float32)
            self.f_model = OV.fit_latency_model(n, lt)

    # -- utility ------------------------------------------------------------
    def _bucket(self, r: Request) -> int:
        return min(r.decoded // self.cfg.bucket_size,
                   self.cfg.num_buckets - 2)

    def _utility(self, r: Request) -> float:
        if self._ut_np is None:
            return 1.0
        steps_left = max(1.0, (r.deadline - self.time)
                         / self._step_cost(len(self.active)))
        tab = self._ut_np
        pos = np.clip(steps_left / self.ut.bin_size - 1.0, 0.0,
                      tab.shape[0] - 1.0)
        j0 = int(pos)
        j1 = min(j0 + 1, tab.shape[0] - 1)
        fr = pos - j0
        s = self._bucket(r)
        return float(tab[j0, s] * (1 - fr) + tab[j1, s] * fr) * r.weight

    # -- dynamics -------------------------------------------------------------
    def _step_cost(self, n_active: int) -> float:
        return self.cfg.step_cost_base \
            + self.cfg.step_cost_per_seq * n_active

    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue and len(self.active) < self.cfg.max_slots:
            r = self.queue.pop(0)
            if self.cfg.policy == "admission" and self._overloaded():
                # E-BL analog: refuse under overload (black-box input drop)
                r.evicted = True
                self.finished.append(r)
                continue
            self.active.append(r)

    def _overloaded(self) -> bool:
        cost = self._step_cost(len(self.active))
        worst = max((self.time + cost - (r.deadline - self.cfg.slo)
                     for r in self.active), default=0.0)
        return worst + cost > self.cfg.slo

    def _maybe_evict(self) -> None:
        """Alg. 1 + Alg. 2: if the projected step latency endangers the
        tightest deadline, evict lowest-utility sequences until the
        remaining batch is sustainable."""
        if self.cfg.policy == "admission" or not self.active:
            return
        while self.active:
            cost = self._step_cost(len(self.active))
            slack = min(r.deadline - self.time for r in self.active)
            # steps needed for the most-advanced request to finish
            if cost <= slack / max(1.0, self._min_steps_left()) \
               + self.cfg.safety_buffer:
                break
            # rho = 1 per iteration (incremental trim, same fixed point as
            # the paper's f^{-1} computation for a linear f)
            if self.cfg.policy == "pspice":
                victim = min(self.active, key=self._utility)
            else:  # random (PM-BL)
                victim = self.active[self.rng.integers(len(self.active))]
            self.active.remove(victim)
            victim.evicted = True
            victim.finish_time = self.time
            self.finished.append(victim)
            self.evictions += 1

    def _min_steps_left(self) -> float:
        return float(min((r.true_length - r.decoded for r in self.active),
                         default=1))

    def run_step(self) -> None:
        """One batched decode step in virtual time."""
        self._admit()
        self._maybe_evict()
        n = len(self.active)
        if n == 0:
            self.time += self.cfg.step_cost_base
            return
        cost = self._step_cost(n)
        self._lat_samples.append((n, cost))
        self.time += cost
        still = []
        for r in self.active:
            s = self._bucket(r)
            r.decoded += 1
            if r.decoded >= r.true_length:
                r.done = True
                r.finish_time = self.time
                self.finished.append(r)
                self._observe(s, self.cfg.num_buckets - 1,
                              self.cfg.step_cost_per_seq)
            else:
                self._observe(s, self._bucket(r),
                              self.cfg.step_cost_per_seq)
                still.append(r)
        self.active = still

    # -- metrics ----------------------------------------------------------
    def metrics(self) -> dict:
        # One linear pass: classify each request once (the SLO-miss test is
        # a predicate, not a membership scan over the in-SLO list).
        n_done = n_ev = n_slo = 0
        w_total = w_miss = 0.0
        for r in self.finished:
            hit = r.done and r.finish_time <= r.deadline
            n_done += r.done
            n_ev += r.evicted
            n_slo += hit
            w_total += r.weight
            if not hit:
                w_miss += r.weight
        total = len(self.finished)
        return {
            "completed": n_done,
            "evicted": n_ev,
            "in_slo": n_slo,
            "goodput": n_slo / max(total, 1),
            "weighted_miss": w_miss / max(w_total, 1e-9),
            "evictions": self.evictions,
        }


def synth_workload(n: int, rate: float, cfg: SchedulerConfig,
                   seed: int = 0) -> list[Request]:
    """Poisson arrivals; output lengths ~ mixture (short chats + long
    generations) so completion probability varies with progress bucket."""
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, n))
    short = rng.geometric(1 / 40.0, n)
    long_ = 200 + rng.geometric(1 / 200.0, n)
    lens = np.where(rng.random(n) < 0.7, short, long_)
    return [Request(req_id=i, arrival=float(t[i]),
                    deadline=float(t[i]) + cfg.slo,
                    true_length=int(max(2, lens[i])))
            for i in range(n)]


def run_simulation(cfg: SchedulerConfig, requests: list[Request],
                   warmup_frac: float = 0.3) -> dict:
    sched = PSpiceScheduler(cfg)
    reqs = sorted(requests, key=lambda r: r.arrival)
    i = 0
    n_warm = int(len(reqs) * warmup_frac)
    while len(sched.finished) < len(reqs):
        while i < len(reqs) and reqs[i].arrival <= sched.time:
            sched.submit(reqs[i])
            i += 1
        if i == n_warm and sched.ut is None:
            sched.build_model()
        if not sched.active and not sched.queue and i < len(reqs):
            sched.time = max(sched.time, reqs[i].arrival)
            continue
        sched.run_step()
        if sched.ut is None and len(sched.finished) >= n_warm:
            sched.build_model()
    return sched.metrics()
