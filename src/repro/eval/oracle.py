"""Differential-testing oracle: a slow, obvious, trusted CEP engine.

A pure-NumPy/Python *event-at-a-time* implementation of the paper's
operator semantics (§III) — PMs live in a slot-addressed store, every
event is processed by plain Python loops, and the load shedder is the
LITERAL sort-based Algorithm 2 (stable sort by utility ascending, drop
the first ρ).  Nothing here shares code with the vectorized engine in
``repro.cep.engine``: no ``lax.scan``, no masked scatters, no histogram
select — which is the point.  ``tests/test_oracle.py`` asserts that the
fast engine (both backends, monolithic and chunked) produces EXACTLY this
oracle's match set, so every future hot-path refactor is automatically
cross-checked against an independent implementation (DESIGN.md §9).

Scope and fidelity:

  * Matching semantics (expire / advance / complete / spawn, capacity,
    distinctness, binding, ring bookkeeping) are replicated exactly —
    they are integer-valued, so "exact" is well-defined on any platform.
  * The simulated-time / overload-detector arithmetic is replicated in
    float32 with the engine's operation order, so shed decisions agree
    with the jax engine on CPU for the seeded test configurations.  Keep
    latency models LINEAR for bitwise agreement (``log2`` may differ by
    an ulp between libm and XLA).
  * The engine's PM-BL shedder draws its random ρ-subset from
    ``jax.random``; a NumPy reimplementation cannot reproduce that
    stream, so — for PM-BL only — the oracle draws its scores through
    the same ``jax.random`` calls.  The shedding *logic* stays
    independent; only the raw uniforms are shared.
  * Observation gathering (``gather_stats``) and the latency-sample ring
    are not replicated: they feed model building, not matching, and are
    covered by the engine's own unit tests.

The oracle intentionally has no knobs the engine lacks: it consumes the
same ``EngineConfig`` / ``EngineModel`` / ``EventBatch``.  The engine's
``shed_plan="threshold"`` is an O(N) *approximation* of Algorithm 2 (it
may pick a different equal-size low-utility subset); differential tests
that shed therefore pin ``shed_plan="sort"`` to compare against the
literal algorithm implemented here.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat

f32 = np.float32


@dataclasses.dataclass
class OraclePM:
    """One partial match: plain Python state, one object per live PM."""
    state: int
    open_idx: int
    bind: int
    idset: list        # length max_any_ids, -1 = empty slot


@dataclasses.dataclass
class OracleResult:
    """What the oracle tracks — the comparable surface of a run."""
    matches: list              # per pattern: set of (open_idx, bind, end_idx)
    complex_count: np.ndarray  # (P,) completions
    pms_created: np.ndarray    # (P,) spawns that got a slot
    pms_shed: float
    shed_calls: float
    overflow: float
    ebl_dropped: float
    l_e: np.ndarray            # (n,) realized event latency (f32 replica)
    n_pm: np.ndarray           # (n,) active PMs after each step
    shed: np.ndarray           # (n,) bool — shed triggered at this event
    dropped: np.ndarray        # (n,) bool — E-BL input drop


def _predict(a: f32, b: f32, kind: int, n: f32) -> f32:
    """f32 replica of ``overload.predict_latency``."""
    basis = n if kind == eng.ovl.LINEAR else f32(n * np.log2(f32(n + f32(1.0))))
    return f32(f32(a * basis) + b)


def _invert(a: f32, b: f32, kind: int, l_target: f32) -> f32:
    """f32 replica of ``overload.invert_latency`` (16 Newton steps)."""
    t = f32(max(f32(f32(l_target - b) / a), f32(0.0)))
    if kind == eng.ovl.LINEAR:
        return t
    n = f32(max(t, f32(1.0)))
    for _ in range(16):
        fn = f32(f32(n * np.log2(f32(n + f32(1.0)))) - t)
        dfn = f32(np.log2(f32(n + f32(1.0)))
                  + f32(n / f32(f32(n + f32(1.0)) * f32(np.log(2.0)))))
        n = f32(min(max(f32(n - f32(fn / max(dfn, f32(1e-9)))),
                        f32(0.0)), f32(1e12)))
    return n


def _detect_overload(model, l_q: f32, n_pm: int, latency_bound: float,
                     safety_buffer: float) -> tuple[bool, int, f32]:
    """Algorithm 1 (paper §III-E), f32 replica of ``detect_overload``."""
    fa, fb, fk = model["f_a"], model["f_b"], model["f_kind"]
    ga, gb, gk = model["g_a"], model["g_b"], model["g_kind"]
    n_f = f32(n_pm)
    l_p = _predict(fa, fb, fk, n_f)
    l_s = _predict(ga, gb, gk, n_f)
    l_e = f32(l_q + l_p)
    shed = bool(f32(f32(l_e + l_s) + f32(safety_buffer)) > f32(latency_bound))
    l_p_new = f32(max(f32(f32(f32(f32(latency_bound) - l_q) - l_s)
                          - f32(safety_buffer)), f32(0.0)))
    n_keep = int(np.floor(f32(_invert(fa, fb, fk, l_p_new) + f32(1e-4))))
    rho = max(n_pm - n_keep, 0) if shed else 0
    return shed, rho, l_e


def _utility(model, p: int, state: int, r_w: int) -> f32:
    """f32 replica of ``utility.multi_pattern_lookup`` for one PM."""
    tab = model["ut_tables"]                    # (P, B, M) f32
    B = tab.shape[1]
    bs = f32(model["ut_bins"][p])
    pos = f32(min(max(f32(f32(f32(r_w) / bs) - f32(1.0)), f32(0.0)),
                  f32(B - 1.0)))
    j0 = int(np.floor(pos))
    j1 = min(j0 + 1, B - 1)
    frac = f32(pos - f32(j0))
    u0, u1 = tab[p, j0, state], tab[p, j1, state]
    return f32(f32(u0 * f32(f32(1.0) - frac)) + f32(u1 * frac))


def _shed_literal_alg2(cfg, model, store, i: int, rho: int,
                       scores: np.ndarray | None) -> int:
    """The paper's Algorithm 2, literally: collect every active PM across
    all patterns, sort ascending by utility (stable — ties keep slot
    order), drop the first ρ.  ``scores`` (PM-BL) replaces utilities with
    the uniform draws; inactive slots are +inf and never chosen."""
    N = cfg.max_pms
    flat_u = np.full(cfg.num_patterns * N, np.inf, f32)
    for p, slots in enumerate(store):
        ws = int(model["window_size"][p])
        for s, pm in enumerate(slots):
            if pm is None:
                continue
            if scores is not None:                    # PM-BL uniform scores
                flat_u[p * N + s] = scores[p * N + s]
            else:
                r_w = ws - (i - pm.open_idx)
                flat_u[p * N + s] = _utility(model, p, pm.state, r_w)
    order = np.argsort(flat_u, kind="stable")
    dropped = 0
    for flat in order[:rho]:
        p, s = divmod(int(flat), N)
        if store[p][s] is not None:
            store[p][s] = None
            dropped += 1
    return dropped


def _model_np(model: eng.EngineModel) -> dict:
    g = lambda x: np.asarray(x)  # noqa: E731
    return dict(
        trans=g(model.trans), kind=g(model.kind),
        spawn_mode=g(model.spawn_mode), window_size=g(model.window_size),
        slide=g(model.slide), final_state=g(model.final_state),
        proc_cost=g(model.proc_cost).astype(f32),
        uses_binding=g(model.uses_binding),
        spawn_counts=g(model.spawn_counts),
        ut_tables=g(model.ut_tables).astype(f32),
        ut_bins=g(model.ut_bins),
        f_a=f32(model.f_model.a), f_b=f32(model.f_model.b),
        f_kind=int(model.f_model.kind),
        g_a=f32(model.g_model.a), g_b=f32(model.g_model.b),
        g_kind=int(model.g_model.kind),
        ebl_raw_mean=f32(model.ebl_raw_mean),
    )


def run_oracle(cfg: eng.EngineConfig, model: eng.EngineModel,
               events: eng.EventBatch, seed: int = 0,
               start: int = 0) -> OracleResult:
    """Run the reference engine over a whole stream.

    ``seed`` must match the ``init_carry`` seed of the engine run being
    diffed (it only matters for PM-BL's shared random stream); ``start``
    is the global index of the first event (0 for ``run_engine``).
    """
    m = _model_np(model)
    P, N, A, K = (cfg.num_patterns, cfg.max_pms, cfg.max_any_ids,
                  cfg.ring_size)
    ev_class = np.asarray(events.ev_class)
    ev_bind = np.asarray(events.ev_bind)
    ev_open = np.asarray(events.ev_open)
    ev_id = np.asarray(events.ev_id)
    ev_rand = np.asarray(events.ev_rand).astype(f32)
    ebl_raw = np.asarray(events.ebl_raw).astype(f32)
    arrival = np.asarray(events.arrival).astype(f32)
    n = ev_class.shape[0]

    store: list[list[OraclePM | None]] = [[None] * N for _ in range(P)]
    ring = [[-1] * K for _ in range(P)]
    ring_ptr = [0] * P

    # PM-BL shares the engine's jax.random stream (see module docstring).
    key = None
    if cfg.shedder == eng.SHED_PMBL:
        import jax
        key = jax.random.PRNGKey(seed)

    sim_time = f32(0.0)
    ebl_frac = f32(0.0)
    ema_gap = f32(1e-3)
    prev_arrival = f32(0.0)
    matches: list[set] = [set() for _ in range(P)]
    complex_count = np.zeros(P, np.int64)
    pms_created = np.zeros(P, np.int64)
    pms_shed = 0
    shed_calls = 0
    overflow = 0
    ebl_dropped = 0
    l_e_out = np.zeros(n, f32)
    n_pm_out = np.zeros(n, np.int64)
    shed_out = np.zeros(n, bool)
    drop_out = np.zeros(n, bool)

    at_open = m["spawn_mode"] == pat.SPAWN_AT_OPEN
    is_seq = m["kind"] == pat.KIND_SEQ

    for e in range(n):
        i = start + e

        # -- 1. expire closed windows + ring bookkeeping --------------------
        for p in range(P):
            ws = int(m["window_size"][p])
            for s in range(N):
                pm = store[p][s]
                if pm is not None and (i - pm.open_idx) >= ws:
                    store[p][s] = None
            if not at_open[p] and ev_open[e, p]:
                ring[p][ring_ptr[p]] = i
                ring_ptr[p] = (ring_ptr[p] + 1) % K

        # -- 2. queueing latency & overload check (Alg. 1) -------------------
        sim_time = f32(max(sim_time, arrival[e]))
        l_q = f32(sim_time - arrival[e])
        n_pm = sum(1 for slots in store for pm in slots if pm is not None)

        did_shed = False
        if cfg.shedder in (eng.SHED_PSPICE, eng.SHED_PMBL):
            shed, rho, _ = _detect_overload(m, l_q, n_pm, cfg.latency_bound,
                                            cfg.safety_buffer)
            if shed and rho > 0:
                scores = None
                if cfg.shedder == eng.SHED_PMBL:
                    import jax
                    key, sub = jax.random.split(key)
                    scores = np.asarray(
                        jax.random.uniform(sub, (P * N,))).astype(f32)
                d = _shed_literal_alg2(cfg, m, store, i, rho, scores)
                pms_shed += d
                shed_calls += 1
                sim_time = f32(sim_time + f32(f32(cfg.c_shed_base)
                                              + f32(f32(cfg.c_shed_pm)
                                                    * f32(n_pm))))
                did_shed = True

        # -- 3. E-BL input drop ---------------------------------------------
        ev_dropped = False
        gap = f32(max(f32(arrival[e] - prev_arrival), f32(1e-9)))
        ema_gap = f32(f32(f32(0.99) * ema_gap) + f32(f32(0.01) * gap))
        prev_arrival = arrival[e]
        if cfg.shedder == eng.SHED_EBL:
            shed, _, _ = _detect_overload(m, l_q, n_pm, cfg.latency_bound,
                                          cfg.safety_buffer)
            l_p_est = _predict(m["f_a"], m["f_b"], m["f_kind"], f32(n_pm))
            d_ff = f32(f32(l_p_est - ema_gap)
                       / max(f32(l_p_est - f32(cfg.c_ebl)), f32(1e-9)))
            d_bk = f32(f32(f32(cfg.ebl_backlog_gain) * l_q)
                       / f32(cfg.latency_bound))
            d_need = f32(min(max(f32(d_ff + d_bk), f32(0.0)), f32(1.0)))
            decayed = f32(ebl_frac * f32(cfg.ebl_decay))
            ebl_frac = f32(max(decayed, d_need)) if shed else decayed
            fl = f32(cfg.ebl_floor)
            one_m = f32(1.0 - cfg.ebl_floor)
            raw_eff = f32(fl + f32(one_m * ebl_raw[e]))
            mean_eff = f32(fl + f32(one_m * m["ebl_raw_mean"]))
            p_drop = f32(min(max(f32(f32(raw_eff * ebl_frac)
                                     / max(mean_eff, f32(1e-9))),
                                 f32(0.0)), f32(1.0)))
            ev_dropped = bool(ev_rand[e] < p_drop)
            if ev_dropped:
                ebl_dropped += 1
            did_shed = shed

        # per-pattern matched-against counts BEFORE advance (sim-time model)
        n_active_p = [sum(1 for pm in store[p] if pm is not None)
                      for p in range(P)]

        # -- 4. advance + completions ---------------------------------------
        for p in range(P):
            cls = 0 if ev_dropped else int(ev_class[e, p])
            b = int(ev_bind[e, p])
            eid = int(ev_id[e])
            final = int(m["final_state"][p])
            for s in range(N):
                pm = store[p][s]
                if pm is None:
                    continue
                bind_ok = (pm.bind == b) if m["uses_binding"][p] else True
                c_eff = cls if bind_ok else 0
                if is_seq[p]:
                    new_state = int(m["trans"][p, pm.state, c_eff])
                else:
                    in_set = eid in pm.idset
                    advances = (c_eff == 1 and not in_set
                                and pm.state < final)
                    new_state = pm.state + (1 if advances else 0)
                    if advances:
                        sc = 1 if m["spawn_counts"][p] else 0
                        slot = min(max(pm.state - 1 + sc, 0), A - 1)
                        pm.idset[slot] = eid
                if new_state == final and pm.state != final:
                    matches[p].add((pm.open_idx, pm.bind, i))
                    complex_count[p] += 1
                    store[p][s] = None
                else:
                    pm.state = new_state

        # -- 5. spawn --------------------------------------------------------
        for p in range(P):
            cls = 0 if ev_dropped else int(ev_class[e, p])
            opened = False if ev_dropped else bool(ev_open[e, p])
            b = int(ev_bind[e, p])
            eid = int(ev_id[e])
            ws = int(m["window_size"][p])
            # Candidates in ring-slot order (the AT_OPEN candidate is k=0).
            cand_opens: list[int] = []
            if at_open[p]:
                if opened:
                    cand_opens.append(i)
            elif cls == 1:
                for k in range(K):
                    r = ring[p][k]
                    if r < 0 or (i - r) >= ws:
                        continue
                    exists = any(pm is not None and pm.open_idx == r
                                 and pm.bind == b for pm in store[p])
                    if not exists:
                        cand_opens.append(r)
            free = [s for s in range(N) if store[p][s] is None]
            for rank, open_idx in enumerate(cand_opens):
                if rank >= len(free):
                    overflow += 1
                    continue
                idset = [-1] * A
                if m["spawn_counts"][p]:
                    idset[0] = eid
                store[p][free[rank]] = OraclePM(
                    state=1, open_idx=open_idx, bind=b, idset=idset)
                pms_created[p] += 1

        # -- 7. simulated processing time & latency --------------------------
        if ev_dropped:
            t_proc = f32(cfg.c_ebl)
        else:
            acc = f32(0.0)
            for p in range(P):
                acc = f32(acc + f32(f32(f32(cfg.c_match)
                                        * m["proc_cost"][p])
                                    * f32(n_active_p[p])))
            t_proc = f32(f32(cfg.c_base) + acc)
        sim_time = f32(sim_time + t_proc)
        l_e_out[e] = f32(sim_time - arrival[e])
        n_pm_out[e] = sum(1 for slots in store
                          for pm in slots if pm is not None)
        shed_out[e] = did_shed
        drop_out[e] = ev_dropped

    return OracleResult(
        matches=matches,
        complex_count=complex_count, pms_created=pms_created,
        pms_shed=float(pms_shed), shed_calls=float(shed_calls),
        overflow=float(overflow), ebl_dropped=float(ebl_dropped),
        l_e=l_e_out, n_pm=n_pm_out, shed=shed_out, dropped=drop_out)
