"""Quality-of-results metrics: match sets, false negatives, degradation.

The paper's headline claim is about *quality*, not throughput: under the
same latency bound, pSPICE's utility-driven PM drop loses far fewer
matches than random PM drop (PM-BL) or event-level shedding (E-BL).
This module defines the measurement (DESIGN.md §9):

  * ground truth = the match set of a no-shed run on the identical
    stream (``cfg.emit_matches`` runs expose it via
    ``engine.match_sets`` / ``RunResult.matches``);
  * a match identity is ``(open_idx, bind, end_idx)`` — window-open
    event index, binding value, completing event index — so "the same
    match" is well-defined across engines, backends and chunkings;
  * false-negative ratio = 1 − recall, recall = |found ∩ gt| / |gt|,
    weighted across patterns by the pattern weights w_q (§II-B);
  * QUALITY comparisons project identities to the *window* level,
    ``(open_idx, bind)``, as a multiset: a shedder that detects the
    complex event of a window through a slightly later constituent
    event (an input drop shifts the completing event) still detected
    it — that is the paper's complex-event count, not a loss.  The full
    3-tuple ("identity") equality is for DIFFERENTIAL testing, where
    the two runs see byte-identical inputs and must agree exactly;
  * a shedder can only LOSE window completions, never invent them
    (events seen by a shed run are a subset of the no-shed run's, and
    skip-till-next-match is monotone in its input), PROVIDED the
    ground-truth run had no PM-store overflow: any found \\ gt
    remainder ("spurious") under that proviso is an engine bug, and
    the metamorphic suite asserts it is empty.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Sequence

import numpy as np


@dataclasses.dataclass
class QualityReport:
    """Match-set comparison of one run against a ground truth."""
    recall: float                    # weighted |found ∩ gt| / |gt|
    fn_ratio: float                  # 1 - recall (weighted FN fraction)
    per_pattern_recall: np.ndarray   # (P,) — 1.0 where gt is empty
    per_pattern_fn: np.ndarray       # (P,)
    n_gt: int                        # total ground-truth matches
    n_found: int                     # total matches the run produced
    n_spurious: int                  # found \ gt — MUST be 0 for shedders

    def to_row(self) -> dict:
        d = dataclasses.asdict(self)
        d["per_pattern_recall"] = [float(x) for x in self.per_pattern_recall]
        d["per_pattern_fn"] = [float(x) for x in self.per_pattern_fn]
        return d


def project_matches(matches: Sequence[set],
                    key: str = "window") -> list[collections.Counter]:
    """Project per-pattern match-identity sets to comparison multisets.

    key="identity": the full (open_idx, bind, end_idx) tuple — exact,
    for differential testing.  key="window": (open_idx, bind) — one
    entry per detected complex event of a window/group; a multiset
    because an IN_WINDOWS window can legitimately complete more than
    once (the exists-check only blocks while a PM is live)."""
    if key == "identity":
        return [collections.Counter(m) for m in matches]
    if key == "window":
        return [collections.Counter((o, b) for (o, b, _e) in m)
                for m in matches]
    raise ValueError(f"unknown match key {key!r}")


def compare_match_sets(found: Sequence[set], gt: Sequence[set],
                       weights: np.ndarray | None = None,
                       key: str = "window") -> QualityReport:
    """Compare per-pattern match sets against a ground truth.

    Patterns with an empty ground truth contribute recall 1 (nothing to
    lose) and weight 0 to the aggregate — matching the paper's convention
    that the FN ratio is "of the matches the no-shed operator produced".
    """
    if len(found) != len(gt):
        raise ValueError(f"pattern count mismatch: {len(found)} vs {len(gt)}")
    P = len(gt)
    w = np.ones(P) if weights is None else np.asarray(weights, float)
    fc = project_matches(found, key)
    gc = project_matches(gt, key)
    per_recall = np.ones(P)
    hit = np.zeros(P)
    total = np.zeros(P)
    spurious = 0
    for p in range(P):
        total[p] = sum(gc[p].values())
        hit[p] = sum((fc[p] & gc[p]).values())     # multiset intersection
        spurious += sum((fc[p] - gc[p]).values())
        if total[p] > 0:
            per_recall[p] = hit[p] / total[p]
    denom = float((w * total).sum())
    recall = float((w * hit).sum() / denom) if denom > 0 else 1.0
    return QualityReport(
        recall=recall, fn_ratio=1.0 - recall,
        per_pattern_recall=per_recall, per_pattern_fn=1.0 - per_recall,
        n_gt=int(total.sum()),
        n_found=int(sum(sum(c.values()) for c in fc)),
        n_spurious=int(spurious))


def latency_compliance(l_e: np.ndarray, latency_bound: float,
                       tolerance: float = 0.0) -> float:
    """Fraction of events whose realized latency met the bound (§IV-B
    'the latency bound is kept'): mean(l_e <= LB·(1+tolerance))."""
    l_e = np.asarray(l_e).reshape(-1)
    if l_e.size == 0:
        return 1.0
    return float((l_e <= latency_bound * (1.0 + tolerance)).mean())


def drop_fraction(result) -> float:
    """Fraction of the run's created PMs that were shed (PM shedders) or
    of its events that were dropped (E-BL) — the x-axis of degradation
    curves.  ``result`` is an ``engine.RunResult``."""
    created = float(np.asarray(result.pms_created).sum())
    frac_pm = result.pms_shed / max(created, 1.0)
    n_events = int(np.asarray(result.l_e).size)
    frac_ev = result.ebl_dropped / max(n_events, 1)
    return float(max(frac_pm, frac_ev))


def degradation_point(res, gt_res, weights=None,
                      latency_bound: float = 1.0) -> dict:
    """One point of a degradation curve: quality + load metrics of a
    shedder run (``RunResult`` with matches) vs its ground truth."""
    if res.matches is None or gt_res.matches is None:
        raise ValueError(
            "degradation_point needs match sets on both runs — run with "
            "cfg.emit_matches=True (extract_matches) so the FN ratio can "
            "be computed against the ground truth")
    rep = compare_match_sets(res.matches, gt_res.matches, weights)
    return {
        "fn_ratio": rep.fn_ratio,
        "recall": rep.recall,
        "n_gt": rep.n_gt,
        "n_found": rep.n_found,
        "n_spurious": rep.n_spurious,
        "drop_fraction": drop_fraction(res),
        "lb_compliance": latency_compliance(res.l_e, latency_bound),
        "pms_shed": res.pms_shed,
        "ebl_dropped": res.ebl_dropped,
    }


def degradation_curve(points: Sequence[tuple[float, dict]]) -> dict:
    """Assemble (level → point) pairs into a curve dict for JSON output,
    with the levels sorted ascending."""
    pts = sorted(points, key=lambda lp: lp[0])
    return {
        "levels": [float(l) for l, _ in pts],
        "fn_ratio": [p["fn_ratio"] for _, p in pts],
        "drop_fraction": [p["drop_fraction"] for _, p in pts],
        "lb_compliance": [p["lb_compliance"] for _, p in pts],
        "points": [dict(p, level=float(l)) for l, p in pts],
    }
