"""The paper-figure quality sweep (§IV-B, Figs. 5–6 shape).

Runs the full experiment grid

    {stock, soccer, bus} × {pspice, PM-BL, E-BL} × overload levels

over the seeded scenario registry (``repro.data.streams.SCENARIOS``) and
reports, per cell, the match-set false-negative ratio against the
no-shed ground truth of the identical stream, plus latency-bound
compliance and drop fractions.  ``benchmarks/bench_quality.py`` is the
CLI; the committed ``BENCH_quality.json`` is the full-grid snapshot and
CI re-runs ``--quick`` per PR, failing when the paper's headline
ordering — pSPICE FN ≤ PM-BL FN and ≤ E-BL FN on every dataset at the
paper overload level — does not hold (DESIGN.md §9).
"""
from __future__ import annotations

import json
import pathlib
from typing import Sequence

from repro.cep import engine as eng
from repro.cep import runner
from repro.configs import pspice_paper as pp
from repro.data import streams
from repro.eval import quality as Q

# The paper's Fig. 6 x-axis is 120%..200% of max operator throughput; the
# headline comparisons (Fig. 5) run at the default 120% overload.
OVERLOAD_LEVELS: tuple[float, ...] = (1.2, 1.4, 1.6)
HEADLINE_LEVEL: float = pp.RATE_MULTIPLIER

DATASETS: tuple[str, ...] = ("stock", "soccer", "bus")
SHEDDERS: tuple[str, ...] = (eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)


def _cell(er: runner.ExperimentResult) -> dict:
    """One (dataset, level, shedder) cell of the grid."""
    return {
        "fn": er.fn_match,                     # match-set FN ratio
        "recall": er.recall,
        "fn_count": er.fn,                     # legacy count-based FN
        "n_gt": er.n_gt_matches,
        "n_found": er.n_found_matches,
        "lb_compliance": er.lb_compliance,
        "drop_fraction": Q.drop_fraction(er.result),
        "pms_shed": er.result.pms_shed,
        "shed_calls": er.result.shed_calls,
        "ebl_dropped": er.result.ebl_dropped,
        "overflow": er.result.overflow,
        "max_rate": er.max_rate,
    }


def run_dataset(name: str, levels: Sequence[float] = OVERLOAD_LEVELS,
                shedders: Sequence[str] = SHEDDERS,
                quick: bool = False, seed: int | None = None) -> dict:
    """The overload grid for one scenario: per level, one ground-truth
    run + one run per shedder on the identical stream."""
    sc = streams.get_scenario(name)
    n = sc.n_quick if quick else sc.n_default
    raw = sc.raw(n=n, seed=seed)
    specs = sc.specs()
    by_level: dict[str, dict] = {}
    for level in levels:
        res = runner.run_experiment(
            specs, raw, shedders=tuple(shedders), rate_multiplier=level,
            max_pms=sc.max_pms, bin_size=sc.bin_size,
            latency_bound=sc.latency_bound,
            seed=sc.seed if seed is None else seed, **pp.COST)
        by_level[f"{level:g}"] = {sh: _cell(er) for sh, er in res.items()}
    curves = {
        sh: Q.degradation_curve(
            [(float(lv), dict(cells[sh], fn_ratio=cells[sh]["fn"]))
             for lv, cells in by_level.items()])
        for sh in shedders
    }
    return {
        "scenario": name,
        "n_events": n,
        "seed": sc.seed if seed is None else seed,
        "patterns": [s.name for s in specs],
        "num_patterns": len(specs),
        "max_pms": sc.max_pms,
        "latency_bound": sc.latency_bound,
        "levels": by_level,
        "curves": curves,
    }


def run_quality_sweep(datasets: Sequence[str] = DATASETS,
                      levels: Sequence[float] = OVERLOAD_LEVELS,
                      shedders: Sequence[str] = SHEDDERS,
                      quick: bool = False,
                      results_dir: str | pathlib.Path | None = None) -> dict:
    """The full grid.  With ``results_dir``, each dataset's grid is also
    written to ``quality_<dataset>.json`` there (the per-figure files);
    the returned dict is the ``BENCH_quality.json`` payload."""
    per_dataset = {}
    for name in datasets:
        grid = run_dataset(name, levels=levels, shedders=shedders,
                           quick=quick)
        per_dataset[name] = grid
        if results_dir is not None:
            p = pathlib.Path(results_dir)
            p.mkdir(parents=True, exist_ok=True)
            (p / f"quality_{name}.json").write_text(
                json.dumps(grid, indent=2, sort_keys=True) + "\n")
    headline_key = f"{HEADLINE_LEVEL:g}"
    headline = {
        name: {sh: grid["levels"][headline_key][sh]["fn"]
               for sh in shedders}
        for name, grid in per_dataset.items()
        if headline_key in grid["levels"]
    }
    bench = {
        "config": {
            "datasets": list(datasets),
            "levels": [float(l) for l in levels],
            "shedders": list(shedders),
            "headline_level": HEADLINE_LEVEL,
            "quick": quick,
        },
        "headline": headline,
        "datasets": per_dataset,
    }
    bench["violations"] = check_headline(bench)
    bench["ordering_ok"] = not bench["violations"]
    return bench


def check_headline(bench: dict) -> list[str]:
    """The paper's headline ordering, as a CI gate: pSPICE's FN ratio
    must be ≤ every baseline's on every dataset at the headline overload
    level.  Returns human-readable violations (empty == pass).  A
    dataset (or the whole headline level) missing from the grid is a
    violation, never a silent pass — a gate that checked nothing must
    not report success."""
    violations = []
    headline = bench.get("headline", {})
    expected = bench.get("config", {}).get("datasets", list(headline))
    if not headline:
        violations.append("headline table is empty (is the headline "
                          "overload level in the swept levels?)")
    for name in expected:
        if name not in headline:
            violations.append(f"{name}: missing from the headline table")
    for name, cells in headline.items():
        if eng.SHED_PSPICE not in cells:
            violations.append(f"{name}: no pspice cell in headline")
            continue
        fn_p = cells[eng.SHED_PSPICE]
        for sh, fn_b in cells.items():
            if sh == eng.SHED_PSPICE:
                continue
            if fn_p is None or fn_b is None:
                violations.append(f"{name}: missing FN metric "
                                  f"(pspice={fn_p}, {sh}={fn_b})")
            elif fn_p > fn_b + 1e-9:
                violations.append(
                    f"{name}: pspice FN {fn_p:.4f} > {sh} FN {fn_b:.4f}")
    return violations
