"""repro.eval — quality-of-results evaluation subsystem (DESIGN.md §9).

Three pieces:
  * ``oracle``  — a slow, pure-NumPy/Python reference CEP engine (the
    literal sort-based Algorithm 2) used as a differential-testing oracle
    for the vectorized engine;
  * ``quality`` — match-set extraction and metrics: false-negative ratio
    / recall vs a no-shed ground truth, latency-bound compliance,
    degradation curves;
  * ``sweep``   — the paper-figure experiment grid ({stock, soccer, bus}
    × {pspice, pmbl, ebl} × overload levels) behind
    ``benchmarks/bench_quality.py`` and ``BENCH_quality.json``.
"""
from repro.eval.oracle import OraclePM, OracleResult, run_oracle
from repro.eval.quality import (QualityReport, compare_match_sets,
                                degradation_curve, degradation_point,
                                drop_fraction, latency_compliance,
                                project_matches)

__all__ = [
    "OraclePM", "OracleResult", "run_oracle",
    "QualityReport", "compare_match_sets", "degradation_curve",
    "degradation_point", "drop_fraction", "latency_compliance",
    "project_matches",
    "run_quality_sweep", "check_headline", "OVERLOAD_LEVELS",
]

_SWEEP_NAMES = ("run_quality_sweep", "check_headline", "OVERLOAD_LEVELS")


def __getattr__(name: str):
    # The sweep driver imports repro.cep.runner, which itself uses
    # repro.eval.quality — loading it lazily keeps the package cycle-free.
    if name in _SWEEP_NAMES:
        from repro.eval import sweep
        return getattr(sweep, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
