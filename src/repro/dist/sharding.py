"""Sharding rules for the production meshes (DESIGN.md §5).

One module owns every PartitionSpec in the system:

  param_specs(mesh, cfg, params, scheme)  — per-architecture parameter
      layouts: head-sharded attention (with divisibility fallback to
      replicated), FSDP ("data","model") MLP/embed sharding, MoE experts
      on the model axis, Mamba channel sharding.
  batch_axes(mesh, global_batch)          — which mesh axes the batch dim
      spreads over, flattening multi-pod meshes to ("pod", "data") and
      dropping leading axes until the batch divides.
  batch_specs(mesh, cfg, batch, scheme)   — specs for train/prefill input
      structs (tokens / labels / patches / frames).
  cache_specs(mesh, cfg, cache)           — decode KV-cache layout: batch
      over the data axes, cache *sequence* over "model" (the memory-
      critical decode layout, DESIGN.md §6).
  pm_specs(mesh, engine_cfg)              — CEP-side: partitions the
      (P, N) partial-match store of the vectorized pSPICE operator across
      the data axis (pattern-parallel).
  run_engine_sharded(...)                 — shard_map over run_engine
      using pm_specs, so multi-query workloads scale past one device.
  lane_specs / run_chunk_lanes_sharded    — the runtime's tenant lanes
      (repro.runtime, DESIGN.md §7): lane axis over "data", per-lane
      pattern axis over "model", so lanes × patterns cover a 2-D mesh.

Every rule goes through `_fit`, which drops any axis assignment that does
not divide the dimension — specs are correct by construction on any mesh.
"""
from __future__ import annotations

import dataclasses
from functools import lru_cache
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.dist import compat
from repro.models.config import ModelConfig

PyTree = Any

abstract_mesh = compat.abstract_mesh  # version-safe AbstractMesh ctor


# ---------------------------------------------------------------------------
# Spec plumbing
# ---------------------------------------------------------------------------

def _axis_size(mesh, axes) -> int:
    shape = dict(mesh.shape)
    size = 1
    for a in axes:
        size *= shape[a]
    return size


def _norm(axes):
    """Normalize an axis group to a PartitionSpec entry."""
    if not axes:
        return None
    if len(axes) == 1:
        return axes[0]
    return tuple(axes)


def _fit(mesh, shape, entries) -> P:
    """PartitionSpec from per-dim axis proposals, dropping (from the left)
    any axes absent from the mesh or not dividing the dim."""
    names = set(mesh.axis_names)
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        ax_t = tuple(a for a in ax_t if a in names)
        while ax_t and dim % _axis_size(mesh, ax_t) != 0:
            ax_t = ax_t[1:]
        out.append(_norm(ax_t))
    return P(*out)


def spec(mesh, shape, *entries) -> P:
    """Public ad-hoc spec builder with the same divisibility fallback."""
    return _fit(mesh, shape, entries)


def named_tree(mesh, tree):
    """Map a PartitionSpec pytree to NamedShardings on `mesh` (what
    jax.jit's in_shardings/out_shardings want on every jax version)."""
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree,
                        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

_BLOCKS = ("attn", "mlp", "moe", "mamba")


def _leaf_spec(mesh, cfg: ModelConfig, scheme: str, block: str | None,
               name: str, shape) -> P:
    """Sharding rule for one parameter leaf.

    Axis indices are negative so the same rule covers stacked (leading L
    axis) and unstacked (shared_attn) leaves.  scheme:
      "tp"    — tensor parallelism over "model" (+FSDP over "data" when
                cfg.fsdp), the default.
      "fsdp"  — no tensor axis; params shard over ("data", "model") as one
                flat FSDP axis group.
      "moe2d" — tp + experts sharded (E × d_ff) two-dimensionally.
    """
    nd = len(shape)
    fsdp = cfg.fsdp or scheme == "fsdp"
    dp = ("data", "model") if scheme == "fsdp" else ("data",)
    tp = None if scheme == "fsdp" else "model"
    ax: dict[int, Any] = {}
    if block == "attn":
        head_tp = tp if cfg.attn_head_tp else None
        if name in ("wq", "bq", "wq_b", "wk", "wv", "bk", "bv",
                    "wk_b", "wv_b"):
            ax[-2] = head_tp
            if fsdp and nd >= 3 and not name.startswith("b"):
                ax[-3] = dp                 # d (or lora rank) over data
        elif name == "wo":
            ax[-3] = head_tp
            if fsdp:
                ax[-1] = dp
        elif name in ("wq_a", "wkv_a"):
            if fsdp:
                ax[-2] = dp
    elif block == "mlp":
        if name in ("wi", "wg"):
            ax[-1] = tp
            if fsdp:
                ax[-2] = dp
        elif name == "wo":
            ax[-2] = tp
            if fsdp:
                ax[-1] = dp
    elif block == "moe":
        if name == "router":
            ax[-1] = tp
        elif name in ("wi", "wg"):
            ax[-3] = "model"                # experts on the model axis
            if scheme == "moe2d":
                ax[-1] = "data"             # (E × d_ff) 2-D expert shard
        elif name == "wo":
            ax[-3] = "model"
            if scheme == "moe2d":
                ax[-2] = "data"
    elif block == "mamba":
        if name in ("wz", "wx"):
            ax[-1] = tp                     # channel (d_inner) sharding
            if fsdp:
                ax[-2] = dp
        elif name == "wo":
            ax[-2] = tp
            if fsdp:
                ax[-1] = dp
        elif name == "wdt":
            ax[-1] = tp                     # SSD heads are channel groups
    else:
        if name == "embed":
            ax[-2] = tp if tp else ("data", "model")
            if fsdp and tp:
                ax[-1] = "data"
        elif name == "lm_head":
            ax[-1] = tp if tp else ("data", "model")
            if fsdp and tp:
                ax[-2] = "data"
    entries = [None] * nd
    for i, a in ax.items():
        if a is not None and -nd <= i:
            entries[i] = a
    return _fit(mesh, shape, entries)


def param_specs(mesh, cfg: ModelConfig, params: PyTree,
                scheme: str = "tp") -> PyTree:
    """PartitionSpec tree mirroring `params` (arrays or ShapeDtypeStructs).

    Per-architecture rules with divisibility fallback to replicated — e.g.
    starcoder2's 48 query heads shard 16-way while its 4 KV heads stay
    replicated, and minitron's 24 heads fall back entirely.
    """
    def walk(tree: dict, block: str | None) -> dict:
        out = {}
        for key, val in tree.items():
            if isinstance(val, dict):
                if key in _BLOCKS:
                    nb = key
                elif key == "shared" and block == "moe":
                    nb = "mlp"              # shared experts are a plain MLP
                else:
                    nb = block
                out[key] = walk(val, nb)
            else:
                out[key] = _leaf_spec(mesh, cfg, scheme, block, key,
                                      val.shape)
        return out

    return walk(params, None)


# ---------------------------------------------------------------------------
# Batch & cache specs
# ---------------------------------------------------------------------------

def batch_axes(mesh, global_batch: int, scheme: str = "tp"):
    """Mesh axes the batch dim shards over, or None.

    Multi-pod meshes flatten to ("pod", "data"); pure-FSDP adds "model".
    Leading axes drop until the batch divides (e.g. batch 16 on a 2-pod
    (2, 16, 16) mesh keeps only ("data",))."""
    wanted = ("pod", "data", "model") if scheme == "fsdp" else ("pod", "data")
    axes = tuple(a for a in wanted if a in mesh.axis_names)
    while axes and global_batch % _axis_size(mesh, axes) != 0:
        axes = axes[1:]
    return axes or None


def batch_specs(mesh, cfg: ModelConfig, batch: dict,
                scheme: str = "tp") -> dict:
    """Specs for the train/prefill input dict (leading dim = batch)."""
    out = {}
    for key, val in batch.items():
        if key == "cache":
            out[key] = cache_specs(mesh, cfg, val)
            continue
        bax = batch_axes(mesh, val.shape[0], scheme)
        out[key] = P(_norm(bax) if bax else None,
                     *([None] * (val.ndim - 1)))
    return out


# Cache entries whose axis 2 is a (max_len) sequence axis we shard over
# "model" — the decode-memory-critical layout (DESIGN.md §6).  ck/cv hold
# encoder frames at axis 2; the divisibility fallback replicates them when
# the frame count (e.g. whisper's 1500) doesn't divide.
_CACHE_SEQ = ("k", "v", "sk", "sv", "ckv", "krope", "ck", "cv")


def cache_specs(mesh, cfg: ModelConfig, cache: dict) -> dict:
    """Decode-cache layout: (L, B, S, ...) → batch over data axes, cache
    sequence over "model"; SSD state heads over "model"."""
    out = {}
    for name, leaf in cache.items():
        nd = len(leaf.shape)
        if nd == 0:
            out[name] = P()
            continue
        entries: list = [None] * nd
        if nd >= 2:
            bax = batch_axes(mesh, leaf.shape[1])
            entries[1] = _norm(bax) if bax else None
        if name in _CACHE_SEQ and nd >= 3:
            entries[2] = "model"
        if name == "state" and nd >= 3:
            entries[2] = "model"            # SSD heads = channel groups
        out[name] = _fit(mesh, leaf.shape, entries)
    return out


# ---------------------------------------------------------------------------
# Launch-entry-point bundles (single owner of the assembly rules used by
# dryrun.py, train.py and serve.py)
# ---------------------------------------------------------------------------

def train_specs(mesh, cfg: ModelConfig, params, batch,
                scheme: str = "tp", pspecs=None):
    """(pspecs, ospecs, bspecs) for the train step: AdamW opt state
    mirrors the param specs with a replicated step counter.  Pass a
    precomputed `pspecs` to skip re-walking the parameter pytree."""
    if pspecs is None:
        pspecs = param_specs(mesh, cfg, params, scheme=scheme)
    ospecs = {"m": pspecs, "v": pspecs, "step": P()}
    bspecs = batch_specs(mesh, cfg, batch, scheme=scheme)
    return pspecs, ospecs, bspecs


def decode_specs(mesh, cfg: ModelConfig, global_batch: int):
    """(token_spec, logit_spec) for decode_step: tokens over the batch
    axes, logits (B, V) with vocab over "model"."""
    bax = batch_axes(mesh, global_batch)
    tok = _fit(mesh, (global_batch,), [bax])
    logits = _fit(mesh, (global_batch, cfg.vocab_size), [bax, "model"])
    return tok, logits


# ---------------------------------------------------------------------------
# CEP engine: pattern-parallel specs over the (P, N) PM store
# ---------------------------------------------------------------------------

def pm_specs(mesh, cfg, axis: str = "data") -> dict:
    """PartitionSpec pytrees for the pSPICE operator state.

    The dense PM store is (num_patterns, max_pms); pattern-parallelism
    shards the *pattern* axis across `axis` — each device runs the full
    event stream against its own slice of the query set, which is the
    natural scale-out for heavy multi-query traffic (eSPICE/hSPICE-style
    workloads).  Falls back to fully-replicated specs when num_patterns
    doesn't divide the axis.

    Returns {"carry", "model", "events", "out", "pattern_axis"} where the
    first four mirror Carry / EngineModel / EventBatch / StepOut.

    The specs cover every engine backend, including the event-block
    megakernel (``backend="pallas_block"``, DESIGN.md §10): its driver
    pads/blocks the event axis and slices StepOut back INSIDE the
    shard-mapped computation, so the block outputs cross the shard
    boundary with the exact per-event shapes specced here, and the
    pattern-axis entries apply to the shard-local (P/shards, N) store
    the kernel keeps resident.
    """
    from repro.cep import engine as eng
    from repro.core import overload as ovl

    divisible = (axis in mesh.axis_names
                 and cfg.num_patterns % _axis_size(mesh, (axis,)) == 0)
    pax = axis if divisible else None
    pms = eng.PMStore(active=P(pax, None), state=P(pax, None),
                      open_idx=P(pax, None), bind=P(pax, None),
                      idset=P(pax, None, None))
    carry = eng.Carry(
        pms=pms, ring=P(pax, None), ring_ptr=P(pax),
        sim_time=P(), key=P(None), ebl_frac=P(), ema_gap=P(),
        prev_arrival=P(),
        complex_count=P(pax), pms_created=P(pax), pms_shed=P(),
        shed_calls=P(), overflow=P(), ebl_dropped=P(),
        obs_counts=P(pax, None, None), obs_rewards=P(pax, None, None),
        lat_samples_n=P(None), lat_samples_l=P(None), lat_ptr=P())
    lat = ovl.LatencyModel(a=P(), b=P(), kind=P())
    model = eng.EngineModel(
        trans=P(pax, None, None), kind=P(pax), spawn_mode=P(pax),
        window_size=P(pax), slide=P(pax), final_state=P(pax),
        proc_cost=P(pax), uses_binding=P(pax), spawn_counts=P(pax),
        ut_tables=P(pax, None, None), ut_bins=P(pax),
        f_model=lat, g_model=lat, ebl_raw_mean=P())
    events = eng.EventBatch(
        ev_class=P(None, pax), ev_bind=P(None, pax), ev_open=P(None, pax),
        ev_id=P(None), ev_rand=P(None), ebl_raw=P(None), arrival=P(None))
    out = eng.StepOut(l_e=P(None), n_pm=P(None), shed=P(None),
                      dropped=P(None),
                      # match identities are pattern-local (zero-width
                      # unless cfg.emit_matches): shard with the pattern.
                      match_open=P(None, pax, None),
                      match_bind=P(None, pax, None))
    return {"carry": carry, "model": model, "events": events, "out": out,
            "pattern_axis": pax}


def _merge_pattern_shards(new_c, outs, axis: str):
    """Cross-shard telemetry merge for a pattern-sharded engine run: each
    shard is its own simulated parallel operator, so clocks take the
    slowest shard (pmax), counters aggregate (psum), and the latency ring
    pairs global PM counts with the slowest shard's per-event time.  Used
    by ``run_engine_sharded`` and, vmapped over tenant lanes, by
    ``run_chunk_lanes_sharded``."""
    from repro.cep import engine as eng

    psum = lambda x: jax.lax.psum(x, axis)              # noqa: E731
    pmax = lambda x: jax.lax.pmax(x, axis)              # noqa: E731
    new_c = new_c._replace(
        sim_time=pmax(new_c.sim_time),     # parallel shards: slowest
        key=pmax(new_c.key),               # shed-dependent; any valid
        ebl_frac=pmax(new_c.ebl_frac),     # conservative drop frac
        pms_shed=psum(new_c.pms_shed),
        shed_calls=psum(new_c.shed_calls),
        overflow=psum(new_c.overflow),
        ebl_dropped=psum(new_c.ebl_dropped),
        # latency-model samples: global PM count vs the slowest
        # shard's per-event time — the (n, l) pairs the parallel
        # operator's overload detector should fit.
        lat_samples_n=psum(new_c.lat_samples_n),
        lat_samples_l=pmax(new_c.lat_samples_l))
    outs = eng.StepOut(
        l_e=pmax(outs.l_e),
        n_pm=psum(outs.n_pm),
        shed=pmax(outs.shed.astype(jnp.int32)) > 0,
        dropped=pmax(outs.dropped.astype(jnp.int32)) > 0,
        # pattern-local: the out_spec concatenates shards on the pattern axis
        match_open=outs.match_open, match_bind=outs.match_bind)
    return new_c, outs


def run_engine_sharded(cfg, model, events, carry, mesh=None,
                       axis: str = "data"):
    """Pattern-parallel shard_map over run_engine.

    Each shard scans the whole stream against num_patterns/n_shards
    patterns as its OWN simulated operator — with more than one shard the
    semantics are a genuinely parallel deployment, not a bit-replay of
    the serial engine: per-event latency is the slowest shard's clock
    (pmax of l_e / sim_time / lat samples), overload and E-BL decisions
    are shard-local, and shed/drop counters aggregate per-shard decisions
    (psum).  Pattern-state outputs (complex_count, pms_created, n_pm) are
    exact regardless of shard count when no shedding triggers.  On a
    one-device mesh the results match the plain engine exactly.  Falls
    back to the plain engine when the pattern axis can't shard.
    """
    from repro.cep import engine as eng

    if mesh is None:
        ndev = len(jax.devices())
        mesh = jax.make_mesh((ndev,), (axis,))
    specs = pm_specs(mesh, cfg, axis=axis)
    if specs["pattern_axis"] is None:
        return eng.run_engine(cfg, model, events, carry)
    n_shards = _axis_size(mesh, (axis,))
    local_cfg = dataclasses.replace(
        cfg, num_patterns=cfg.num_patterns // n_shards)

    def local_run(model, events, carry):
        new_c, outs = eng.run_engine(local_cfg, model, events, carry)
        return _merge_pattern_shards(new_c, outs, axis)

    mapped = compat.shard_map(
        local_run, mesh=mesh,
        in_specs=(specs["model"], specs["events"], specs["carry"]),
        out_specs=(specs["carry"], specs["out"]),
        check_rep=False)
    with compat.use_mesh(mesh):
        return mapped(model, events, carry)


# ---------------------------------------------------------------------------
# Runtime tenant lanes: lanes × patterns over the mesh (DESIGN.md §7)
# ---------------------------------------------------------------------------

def _prepend_axis(spec_tree, lane_ax):
    """Grow every PartitionSpec in a pytree by a leading lane entry."""
    return jax.tree.map(lambda s: P(lane_ax, *s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def lane_specs(mesh, cfg, num_lanes: int, lane_axis: str = "data",
               pattern_axis: str | None = "model") -> dict:
    """Specs for lane-stacked runtime state: pm_specs with a leading lane
    dim.

    Lanes (independent tenants) shard over ``lane_axis``; within each lane
    the (P, N) PM store may additionally shard its pattern dim over
    ``pattern_axis`` — the lanes × patterns composition that covers a 2-D
    mesh.  Either axis falls back to replicated (None) when missing from
    the mesh, equal to the other, or not dividing its dim; with both
    fallen back the caller should use the plain vmapped path.

    Returns {"carry", "model", "events", "out", "lane_axis",
    "pattern_axis"}.
    """
    lax_ok = (lane_axis in mesh.axis_names
              and num_lanes % _axis_size(mesh, (lane_axis,)) == 0)
    lane_ax = lane_axis if lax_ok else None
    pax_name = pattern_axis if pattern_axis != lane_axis else None
    inner = pm_specs(mesh, cfg, axis=pax_name or "__none__")
    return {
        "carry": _prepend_axis(inner["carry"], lane_ax),
        "model": _prepend_axis(inner["model"], lane_ax),
        "events": _prepend_axis(inner["events"], lane_ax),
        "out": _prepend_axis(inner["out"], lane_ax),
        "lane_axis": lane_ax,
        "pattern_axis": inner["pattern_axis"],
    }


@lru_cache(maxsize=8)
def _default_lane_mesh(lane_axis: str):
    ndev = len(jax.devices())
    return jax.make_mesh((ndev,), (lane_axis,))


@lru_cache(maxsize=32)
def _lanes_sharded_fn(cfg, mesh, num_lanes: int, lane_axis: str,
                      pattern_axis: str | None):
    """The shard-mapped, jitted, carry-donating lane chunk step — built
    ONCE per (cfg, mesh, lane count, axes) and cached, so the runtime's
    steady-state loop hits one compiled executable per chunk shape (no
    per-chunk retrace) and keeps the donation invariant of the non-mesh
    paths.  Returns None when neither axis can shard."""
    from repro.cep import engine as eng

    specs = lane_specs(mesh, cfg, num_lanes, lane_axis=lane_axis,
                       pattern_axis=pattern_axis)
    lane_ax, pax = specs["lane_axis"], specs["pattern_axis"]
    if lane_ax is None and pax is None:
        return None
    local_cfg = cfg if pax is None else dataclasses.replace(
        cfg, num_patterns=cfg.num_patterns // _axis_size(mesh, (pax,)))

    def local_run(model, events, carry, start):
        new_c, outs = eng._scan_events_lanes_backend(local_cfg, model,
                                                     events, carry,
                                                     start[0])
        if pax is not None:
            new_c, outs = _merge_pattern_shards(new_c, outs, pax)
        return new_c, outs

    mapped = compat.shard_map(
        local_run, mesh=mesh,
        in_specs=(specs["model"], specs["events"], specs["carry"], P(None)),
        out_specs=(specs["carry"], specs["out"]),
        check_rep=False)
    return jax.jit(mapped, donate_argnums=(2,))


def run_chunk_lanes_sharded(cfg, model, events, carry, start, mesh=None,
                            lane_axis: str = "data",
                            pattern_axis: str | None = "model"):
    """Mesh-parallel chunk step for the multi-tenant runtime.

    shard_map over ``lane_specs``: each device block runs a lane-batched
    ``_scan_events_lanes`` over its local lanes × local pattern slice.
    Lanes are independent, so the lane axis needs no collectives; a
    sharded pattern axis gets the same per-lane telemetry merge as
    ``run_engine_sharded`` (psum counters, pmax clocks), vmapped over the
    lane dim.  The carry is donated, like the non-mesh chunk steps.
    Falls back to the plain lane-batched ``run_chunk_lanes`` when
    neither axis can shard (e.g. a one-axis mesh already consumed by
    lanes still shards — a no-axis fit does not).
    """
    from repro.runtime import lanes as LN

    num_lanes = events.ev_class.shape[0]
    if mesh is None:
        mesh = _default_lane_mesh(lane_axis)
    fn = _lanes_sharded_fn(cfg, mesh, num_lanes, lane_axis, pattern_axis)
    if fn is None:
        return LN.run_chunk_lanes(cfg, model, events, carry, start)
    with compat.use_mesh(mesh):
        return fn(model, events, carry, jnp.asarray(start, jnp.int32)[None])
