"""jax version-compat shims used by the sharding subsystem.

The repo targets the jax that ships in the image (0.4.x at the time of
writing) while the call sites are written against the modern spellings.
Everything version-dependent funnels through here:

  shard_map        — ``jax.shard_map`` (0.6+) or
                     ``jax.experimental.shard_map.shard_map`` (0.4.x).
  abstract_mesh    — ``AbstractMesh(sizes, names)`` (0.5+) or
                     ``AbstractMesh(((name, size), ...))`` (0.4.x).
  use_mesh         — context manager activating a mesh: ``with mesh:``
                     (0.4.x Mesh), ``jax.sharding.use_mesh`` or
                     ``jax.set_mesh`` (newer).
  get_active_mesh  — the mesh currently in scope, whichever mechanism set
                     it (``get_abstract_mesh`` or the 0.4.x thread-local
                     physical mesh).  Returns None when no mesh is active.
"""
from __future__ import annotations

import contextlib
import inspect

import jax

try:                                      # jax >= 0.6
    from jax import shard_map as _shard_map   # type: ignore[attr-defined]
except ImportError:                       # jax 0.4.x / 0.5.x
    from jax.experimental.shard_map import shard_map as _shard_map

_SM_PARAMS = set(inspect.signature(_shard_map).parameters)


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=None, **kw):
    """shard_map with the replication-check kwarg translated across the
    0.4.x (`check_rep`) → 0.6+ (`check_vma`) rename."""
    if check_rep is not None:
        if "check_rep" in _SM_PARAMS:
            kw["check_rep"] = check_rep
        elif "check_vma" in _SM_PARAMS:
            kw["check_vma"] = check_rep
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **kw)


def abstract_mesh(axis_sizes, axis_names):
    """AbstractMesh across the 0.4.x / 0.5+ constructor change."""
    from jax.sharding import AbstractMesh
    axis_sizes = tuple(int(s) for s in axis_sizes)
    axis_names = tuple(axis_names)
    try:
        return AbstractMesh(axis_sizes, axis_names)
    except TypeError:
        return AbstractMesh(tuple(zip(axis_names, axis_sizes)))


@contextlib.contextmanager
def use_mesh(mesh):
    """Activate `mesh` for PartitionSpec resolution inside jit/wsc."""
    if hasattr(mesh, "__enter__"):        # 0.4.x Mesh is a context manager
        with mesh:
            yield mesh
    elif hasattr(jax.sharding, "use_mesh"):
        with jax.sharding.use_mesh(mesh):
            yield mesh
    else:                                 # newest API: module-level setter
        prev = get_active_mesh()
        jax.set_mesh(mesh)
        try:
            yield mesh
        finally:
            jax.set_mesh(prev)            # prev may be None: clears it


def get_active_mesh():
    """The mesh in scope (abstract or physical), or None."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        try:
            mesh = get()
            if mesh is not None and mesh.axis_names:
                return mesh
        except Exception:  # noqa: BLE001
            pass
    try:  # 0.4.x: `with mesh:` sets the thread-local physical mesh.
        from jax._src import mesh as mesh_lib
        mesh = mesh_lib.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:  # noqa: BLE001
        pass
    return None
