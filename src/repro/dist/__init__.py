"""Distribution subsystem: sharding rules + version-compat shims.

``repro.dist.sharding`` owns every PartitionSpec the launch entry points
use (params / optimizer state / batches / decode caches) plus the CEP
engine's pattern-parallel specs (``pm_specs`` / ``run_engine_sharded``).
``repro.dist.compat`` bridges jax API drift (shard_map location,
AbstractMesh constructor, mesh-context activation) so the same call sites
run on 0.4.x and 0.5+.
"""
from repro.dist import compat, sharding  # noqa: F401
