"""Latency-bounded serving driver: real decode_step + pSPICE scheduler.

Runs a smoke-config model with genuine prefill/decode compute while the
pSPICE scheduler (repro/serving/scheduler.py) makes admission/eviction
decisions from its online-learned Markov utility model.  The step cost fed
to the scheduler is the MEASURED wall-clock of the jitted decode_step, so
this is the paper's architecture end-to-end: operator (decode batch) +
overload detector + model builder + load shedder.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch internlm2-1.8b \
      --requests 64 --rate 50 --policy pspice
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.dist import sharding as SH
from repro.launch import mesh as M
from repro.models import decode as D
from repro.models import transformer as T
from repro.serving.scheduler import (PSpiceScheduler, Request,
                                     SchedulerConfig, synth_workload)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--rate", type=float, default=50.0)
    ap.add_argument("--policy", default="pspice",
                    choices=("pspice", "random", "admission"))
    ap.add_argument("--slots", type=int, default=16)
    ap.add_argument("--slo", type=float, default=1.0)
    ap.add_argument("--max-len", type=int, default=96)
    args = ap.parse_args(argv)

    cfg = registry.get_smoke_config(args.arch)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B = args.slots
    cache = D.init_cache(cfg, B, args.max_len)
    # Decode runs under the same cache/batch spec machinery the production
    # dry-run lowers with, on the local host mesh (batch over "data").
    mesh = M.make_host_mesh()
    cspecs = SH.cache_specs(mesh, cfg, cache)
    tok_spec, logit_spec = SH.decode_specs(mesh, cfg, B)
    dec = jax.jit(lambda c, t: D.decode_step(cfg, params, c, t),
                  in_shardings=SH.named_tree(mesh, (cspecs, tok_spec)),
                  out_shardings=SH.named_tree(mesh, (logit_spec, cspecs)))
    # warm the jit + measure the real step cost
    toks = jnp.zeros((B,), jnp.int32)
    _, cache_w = dec(cache, toks)
    t0 = time.time()
    for _ in range(5):
        logits, cache_w = dec(cache_w, toks)
    logits.block_until_ready()
    step_cost = (time.time() - t0) / 5
    print(f"[serve] measured decode_step cost (B={B}): {step_cost*1e3:.2f}ms")

    scfg = SchedulerConfig(max_slots=B, slo=args.slo, policy=args.policy,
                           step_cost_base=step_cost * 0.5,
                           step_cost_per_seq=step_cost * 0.5 / max(B, 1))
    sched = PSpiceScheduler(scfg)
    reqs = synth_workload(args.requests, rate=args.rate, cfg=scfg)
    i = 0
    cache_live = cache
    n_steps = 0
    while len(sched.finished) < len(reqs):
        while i < len(reqs) and reqs[i].arrival <= sched.time:
            sched.submit(reqs[i])
            i += 1
        if i >= len(reqs) // 3 and sched.ut is None:
            sched.build_model()
            print("[serve] pSPICE utility model built")
        if not sched.active and not sched.queue and i < len(reqs):
            sched.time = max(sched.time, reqs[i].arrival)
            continue
        sched.run_step()
        if sched.active and n_steps < args.max_len - 1:
            logits, cache_live = dec(cache_live, toks)  # real compute
            n_steps += 1
    m = sched.metrics()
    print(f"[serve] policy={args.policy} completed={m['completed']} "
          f"evicted={m['evicted']} in_slo={m['in_slo']} "
          f"goodput={m['goodput']:.3f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
