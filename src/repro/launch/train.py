"""Fault-tolerant training driver.

Demonstrates (at laptop scale, with the same code paths the production mesh
uses) the pieces large-scale runnability requires:
  - sharded data-parallel train_step on whatever mesh exists,
  - step-tagged atomic checkpoints + keep-last-k (training/checkpoint.py),
  - NaN/inf loss detection with automatic restore-and-skip (node-failure /
    bad-batch recovery),
  - crash-resume: rerunning the command continues from the latest step,
  - deterministic per-step data sharding (restart-safe, straggler-safe:
    a restarted host re-derives exactly its shard from the step index).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch internlm2-1.8b \
      --steps 50 --batch 8 --seq 256 --smoke --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.dist import sharding as SH
from repro.launch import mesh as M
from repro.models import transformer as T
from repro.training import checkpoint as CK
from repro.training import optimizer as O
from repro.training.train_step import make_train_step


def synthetic_batch(cfg, batch: int, seq: int, step: int, seed: int = 0):
    """Deterministic per-step batch — restart-safe data pipeline."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    s_text = seq - cfg.vlm_patches if cfg.vlm_patches else seq
    # A learnable synthetic language: repeated arithmetic token sequences.
    base = rng.integers(0, cfg.vocab_size - 1, size=(batch, 1))
    ramp = (base + np.arange(s_text + 1)[None, :] * 7) % (cfg.vocab_size - 1)
    out = {"tokens": jnp.asarray(ramp[:, :-1], jnp.int32),
           "labels": jnp.asarray(ramp[:, 1:], jnp.int32)}
    if cfg.vlm_patches:
        out["patches"] = jnp.zeros((batch, cfg.vlm_patches, cfg.d_model),
                                   jnp.float32)
    if cfg.enc_dec:
        out["frames"] = jnp.zeros((batch, cfg.enc_frames, cfg.d_model),
                                  jnp.float32)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=registry.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-nan-at", type=int, default=-1,
                    help="fault-injection test: corrupt loss at this step")
    ap.add_argument("--no-shard", action="store_true",
                    help="skip explicit in/out shardings (debug only)")
    args = ap.parse_args(argv)

    cfg = (registry.get_smoke_config(args.arch) if args.smoke
           else registry.get_config(args.arch))
    opt_cfg = O.AdamWConfig(lr=args.lr, warmup_steps=10)
    if args.no_shard:
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=True))
    else:
        # Same spec machinery as the production dry-run, on whatever mesh
        # exists locally: params per repro.dist rules, batch over "data".
        mesh = M.make_host_mesh()
        params_s = jax.eval_shape(
            functools.partial(T.init_params, cfg), jax.random.PRNGKey(0))
        # Specs only need shapes; step 0's real batch serves as the struct.
        batch0 = synthetic_batch(cfg, args.batch, args.seq, 0)
        pspecs, ospecs, bspecs = SH.train_specs(mesh, cfg, params_s,
                                                batch0)
        step_fn = jax.jit(
            make_train_step(cfg, opt_cfg, remat=True),
            in_shardings=SH.named_tree(mesh, (pspecs, ospecs, bspecs)),
            out_shardings=(SH.named_tree(mesh, pspecs),
                           SH.named_tree(mesh, ospecs), None))

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt_state = O.init_opt_state(params)
    start = 0
    if args.ckpt_dir and (s := CK.latest_step(args.ckpt_dir)) is not None:
        print(f"[train] resuming from checkpoint step {s}")
        state = CK.restore(args.ckpt_dir,
                           {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start = s

    losses = []
    for step in range(start, args.steps):
        batch = synthetic_batch(cfg, args.batch, args.seq, step)
        t0 = time.time()
        new_params, new_opt, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        if args.inject_nan_at == step:
            loss = float("nan")
        if not np.isfinite(loss):
            # Node-failure / bad-batch recovery: restore & skip the batch.
            print(f"[train] step {step}: NON-FINITE loss — restoring last "
                  "checkpoint and skipping batch")
            if args.ckpt_dir and CK.latest_step(args.ckpt_dir) is not None:
                state = CK.restore(args.ckpt_dir,
                                   {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
            continue
        params, opt_state = new_params, new_opt
        losses.append(loss)
        print(f"[train] step {step:4d} loss {loss:.4f} "
              f"gnorm {float(metrics['grad_norm']):.3f} "
              f"({time.time() - t0:.2f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            d = CK.save(args.ckpt_dir, step + 1,
                        {"params": params, "opt": opt_state})
            print(f"[train] checkpointed -> {d}")
    if len(losses) >= 10:
        print(f"[train] loss first5={np.mean(losses[:5]):.4f} "
              f"last5={np.mean(losses[-5:]):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
