"""Production mesh construction (a FUNCTION — importing this module never
touches jax device state)."""
from __future__ import annotations

import jax


def production_topology(*, multi_pod: bool = False):
    """(shape, axis_names) of the production mesh — the single source of
    truth for both the device mesh and its abstract twin."""
    if multi_pod:
        return (2, 16, 16), ("pod", "data", "model")
    return (16, 16), ("data", "model")


def make_production_mesh(*, multi_pod: bool = False):
    """v5e-256 pod: (data=16, model=16).  Multi-pod: 2 pods = 512 chips with
    a leading "pod" axis (data-parallel across the cross-pod DCN/ICI)."""
    shape, axes = production_topology(multi_pod=multi_pod)
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Whatever devices exist locally (tests / examples): 1D 'data' mesh."""
    n = len(jax.devices())
    return jax.make_mesh((n,), ("data",))


def make_abstract_production_mesh(*, multi_pod: bool = False):
    """Device-free AbstractMesh with the production topology — for spec
    construction (repro.dist.sharding) without touching jax device state."""
    from repro.dist import compat
    shape, axes = production_topology(multi_pod=multi_pod)
    return compat.abstract_mesh(shape, axes)


# TPU v5e hardware constants (per chip) for the roofline terms.
PEAK_FLOPS_BF16 = 197e12     # FLOP/s
HBM_BW = 819e9               # B/s
ICI_BW_PER_LINK = 50e9       # B/s per link (~both directions combined)
