import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import — jax locks the
# device count on first initialization.  (No `from __future__` here for the
# same reason: nothing may precede the env-var lines.)

DOC = """Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell we build ShapeDtypeStruct inputs (no allocation), jit the
train/prefill/decode step with explicit in/out shardings on the production
mesh, .lower().compile(), and record memory_analysis / cost_analysis /
collective-roofline terms.  A failure here (sharding mismatch, OOM at
compile, unsupported collective) is a bug in the system.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2-1.8b \
      --shape train_4k [--multi-pod] [--json out.jsonl]
  PYTHONPATH=src python -m repro.launch.dryrun --all [--json out.jsonl]
"""

import argparse
import functools
import json
import sys
import time

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.configs.shapes import SHAPES, ShapeSpec, applicable
from repro.dist import compat as DC
from repro.dist import sharding as SH
from repro.launch import hlo_analysis as HA
from repro.launch import mesh as M
from repro.models import decode as D
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.training import optimizer as O
from repro.training.train_step import make_train_step


def _param_structs(cfg: ModelConfig):
    """abstract params (+opt state) without allocating."""
    params = jax.eval_shape(
        functools.partial(T.init_params, cfg), jax.random.key(0))
    return params


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active params."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.seq_len * shape.global_batch
        return 6.0 * n * d
    if shape.kind == "prefill":
        d = shape.seq_len * shape.global_batch
        return 2.0 * n * d
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def build_lowered(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
                  causal_skip: bool = True, donate: bool = True,
                  scheme: str = "tp", attn_flip: bool = False,
                  remat: bool = True):
    """Construct and .lower() the jitted step for one cell on `mesh`."""
    from repro.models import settings as SET
    import contextlib
    params_s = _param_structs(cfg)
    pspecs = SH.param_specs(mesh, cfg, params_s, scheme=scheme)
    batch_s = registry.input_specs(cfg, shape)

    named = functools.partial(SH.named_tree, mesh)
    with DC.use_mesh(mesh), SET.use_scheme(scheme, attn_flip):
        if shape.kind == "train":
            opt_s = jax.eval_shape(O.init_opt_state, params_s)
            pspecs, ospecs, bspecs = SH.train_specs(mesh, cfg, params_s,
                                                    batch_s, scheme=scheme,
                                                    pspecs=pspecs)
            step = make_train_step(cfg, causal_skip=causal_skip,
                                   remat=remat)
            jitted = jax.jit(
                step,
                in_shardings=named((pspecs, ospecs, bspecs)),
                out_shardings=(named(pspecs), named(ospecs), None),
                donate_argnums=(0, 1) if donate else ())
            lowered = jitted.lower(params_s, opt_s, batch_s)
        elif shape.kind == "prefill":
            bspecs = SH.batch_specs(mesh, cfg, batch_s, scheme=scheme)
            cache_s = jax.eval_shape(
                lambda: D.init_cache(cfg, shape.global_batch, shape.seq_len))
            cspecs = SH.cache_specs(mesh, cfg, cache_s)

            def pf(params, batch):
                return D.prefill(cfg, params, batch, max_len=shape.seq_len,
                                 causal_skip=causal_skip)

            jitted = jax.jit(pf, in_shardings=named((pspecs, bspecs)),
                             out_shardings=named((cspecs, P())))
            lowered = jitted.lower(params_s, batch_s)
        else:  # decode
            cache_s = batch_s["cache"]
            cspecs = SH.cache_specs(mesh, cfg, cache_s)
            tok_spec, logit_spec = SH.decode_specs(mesh, cfg,
                                                   shape.global_batch)

            def dec(params, cache, tokens):
                return D.decode_step(cfg, params, cache, tokens)

            jitted = jax.jit(
                dec, in_shardings=named((pspecs, cspecs, tok_spec)),
                out_shardings=named((logit_spec, cspecs)),
                donate_argnums=(1,) if donate else ())
            lowered = jitted.lower(params_s, cache_s, batch_s["tokens"])
    return lowered


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               causal_skip: bool = True, donate: bool = True,
               compile_: bool = True, roofline: bool = True,
               scheme: str = "tp", attn_flip: bool = False,
               remat: bool = True) -> dict:
    cfg = registry.get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": why}
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    lowered = build_lowered(cfg, shape, mesh, causal_skip=causal_skip,
                            donate=donate, scheme=scheme,
                            attn_flip=attn_flip, remat=remat)
    t_lower = time.time() - t0
    row = {"arch": arch, "shape": shape_name,
           "mesh": "multi" if multi_pod else "single",
           "chips": chips, "status": "lowered",
           "lower_s": round(t_lower, 1)}
    if not compile_:
        return row
    compiled = lowered.compile()
    row["compile_s"] = round(time.time() - t0 - t_lower, 1)
    mem = compiled.memory_analysis()
    row["status"] = "ok"
    row["memory_analysis"] = {
        "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "peak_gb": (getattr(mem, "argument_size_in_bytes", 0)
                    + getattr(mem, "temp_size_in_bytes", 0)) / 1e9,
    }
    if roofline:
        from repro.launch import roofline as RF
        try:
            rf = RF.roofline_cell(cfg, shape, mesh, chips,
                                  causal_skip=causal_skip, scheme=scheme,
                                  attn_flip=attn_flip, remat=remat)
            row.update(**rf.row())
        except Exception as e:  # noqa: BLE001
            row["roofline_error"] = repr(e)[:300]
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=registry.ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-causal-skip", action="store_true",
                    help="baseline flash schedule (full S² masked)")
    ap.add_argument("--scheme", default="tp",
                    choices=("tp", "fsdp", "moe2d"),
                    help="parallelism scheme (§Perf hillclimbs)")
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing (train cells)")
    ap.add_argument("--flip-attn", action="store_true",
                    help="batch-over-(data×model) attention for archs whose "
                         "heads don't divide the model axis")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    cells = []
    archs = registry.ARCH_IDS if args.all or not args.arch else (args.arch,)
    shapes = tuple(SHAPES) if args.all or not args.shape else (args.shape,)
    meshes = ((False, True) if args.both_meshes or args.all
              else (args.multi_pod,))
    for arch in archs:
        for sh in shapes:
            for mp in meshes:
                cells.append((arch, sh, mp))

    out = open(args.json, "a") if args.json else None
    failures = 0
    for arch, sh, mp in cells:
        try:
            # Roofline terms are a single-pod deliverable; multi-pod rows
            # prove the "pod" axis shards (compile + memory only).
            row = lower_cell(arch, sh, multi_pod=mp,
                             causal_skip=not args.no_causal_skip,
                             roofline=not mp, scheme=args.scheme,
                             attn_flip=args.flip_attn,
                             remat=not args.no_remat)
            row["scheme"] = args.scheme
            row["remat"] = not args.no_remat
            row["attn_flip"] = args.flip_attn
            row["causal_skip"] = not args.no_causal_skip
        except Exception as e:  # noqa: BLE001
            row = {"arch": arch, "shape": sh,
                   "mesh": "multi" if mp else "single",
                   "status": "FAILED", "error": repr(e)[:500]}
            failures += 1
        print(json.dumps(row), flush=True)
        if out:
            out.write(json.dumps(row) + "\n")
            out.flush()
    if out:
        out.close()
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
