"""Roofline-term extraction with depth extrapolation.

XLA's HLO cost analysis counts each while-loop body ONCE (no trip-count
multiplication), so a rolled scan-over-layers under-reports FLOPs by ~L×.
We therefore lower each cell in ANALYSIS MODE (every scan unrolled, chunk
granularity coarsened FLOP-invariantly — models/settings.py) at two reduced
depths L1 < L2 and extrapolate linearly to the real depth:

    term(L) = term(L1) + (L - L1)/(L2 - L1) · (term(L2) - term(L1))

Layers are identical, so FLOPs/bytes/collective-bytes are affine in L; the
intercept captures embeddings, the LM head, and the loss.  For zamba2 the
depths are multiples of hybrid_attn_every so each delta contains exactly one
shared-attention application; whisper varies encoder and decoder depth
together (both 12 at target).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.configs.shapes import ShapeSpec
from repro.launch import hlo_analysis as HA
from repro.launch import mesh as M
from repro.models import settings as SET
from repro.models.config import ModelConfig


def engine_block_intensity(engine_cfg) -> dict:
    """Arithmetic-intensity estimate for the CEP per-event step: the
    unfused per-event scan vs the fused event-block kernel
    (kernels/block_step.py, DESIGN.md §10).

    XLA's HLO cost analysis counts a while-loop body once regardless of
    trip count AND cannot see VMEM residency (the fused kernel's whole
    point is that the store is loaded once per W events instead of once
    per event), so this is an analytic model, not an HLO readout:

      * the store is P·N slots; per event the operator runs ~14
        elementwise ops per slot (expire, advance lookup + selects,
        completion detect, spawn compaction, activity reductions);
      * the unfused step streams the five (P, N) store arrays (+ the
        (P, N, A) idset for ANY-capable pattern sets) from memory ~6
        times per event (advance, spawn, utility/overload bookkeeping
        read-modify-write pairs — the op inventory of DESIGN.md §8);
      * the fused kernel loads and stores the same arrays ONCE per
        W-event block, plus per-event row IO (StepOut columns and the
        classified event).

    Emitted into BENCH_engine.json by benchmarks/bench_engine.py so the
    perf trajectory records the memory-traffic claim next to the
    measured events/s.
    """
    P, N, A = (engine_cfg.num_patterns, engine_cfg.max_pms,
               engine_cfg.max_any_ids)
    W = engine_cfg.block_events
    any_capable = engine_cfg.kinds != "seq"
    store_bytes = P * N * (4 * 4 + 1)          # state/open/bind ×i32 + mask
    if any_capable:
        store_bytes += P * N * A * 4
    row_bytes = 4 * 4 + 8 * P * 4              # StepOut row + event columns
    ops_per_slot = 14.0
    flops_per_event = ops_per_slot * P * N
    unfused_passes = 6.0
    bytes_unfused = unfused_passes * store_bytes + row_bytes
    bytes_fused = 2.0 * store_bytes / W + row_bytes
    return {
        "store_bytes": store_bytes,
        "flops_per_event": flops_per_event,
        "bytes_per_event_unfused": bytes_unfused,
        "bytes_per_event_fused": bytes_fused,
        "intensity_unfused": flops_per_event / bytes_unfused,
        "intensity_fused": flops_per_event / bytes_fused,
        "traffic_ratio": bytes_unfused / bytes_fused,
        "block_events": W,
    }


def analysis_depths(cfg: ModelConfig) -> tuple[ModelConfig, ModelConfig,
                                               int, int, int]:
    """(cfg_L1, cfg_L2, L1, L2, L_target)."""
    if cfg.hybrid_attn_every:
        e = cfg.hybrid_attn_every
        l1, l2 = e, 2 * e
        c1 = dataclasses.replace(cfg, num_layers=l1)
        c2 = dataclasses.replace(cfg, num_layers=l2)
    elif cfg.enc_dec:
        l1, l2 = 2, 3
        c1 = dataclasses.replace(cfg, num_layers=l1, enc_layers=l1)
        c2 = dataclasses.replace(cfg, num_layers=l2, enc_layers=l2)
    else:
        # L=1 is pathological (GSPMD picks different strategies for the
        # degenerate depth — observed +43% FLOPs); 2→3 deltas are clean.
        l1, l2 = 2, 3
        c1 = dataclasses.replace(cfg, num_layers=l1)
        c2 = dataclasses.replace(cfg, num_layers=l2)
    return c1, c2, l1, l2, cfg.num_layers


def _measure(cfg: ModelConfig, shape: ShapeSpec, mesh, *,
             causal_skip: bool, scheme: str = "tp",
             attn_flip: bool = False,
             remat: bool = True) -> tuple[float, float,
                                          HA.CollectiveStats]:
    from repro.launch import dryrun as DR
    with SET.analysis_mode():
        lowered = DR.build_lowered(cfg, shape, mesh,
                                   causal_skip=causal_skip, donate=False,
                                   scheme=scheme, attn_flip=attn_flip,
                                   remat=remat)
        compiled = lowered.compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    coll = HA.parse_collectives(compiled.as_text())
    return flops, byts, coll


def roofline_cell(cfg: ModelConfig, shape: ShapeSpec, mesh, chips: int, *,
                  causal_skip: bool = True, scheme: str = "tp",
                  attn_flip: bool = False,
                  remat: bool = True) -> HA.Roofline:
    from repro.launch.dryrun import model_flops
    c1, c2, l1, l2, lt = analysis_depths(cfg)
    kw = dict(causal_skip=causal_skip, scheme=scheme, attn_flip=attn_flip,
              remat=remat)
    f1, b1, coll1 = _measure(c1, shape, mesh, **kw)
    f2, b2, coll2 = _measure(c2, shape, mesh, **kw)
    r = (lt - l1) / (l2 - l1)
    flops = f1 + r * (f2 - f1)
    byts = b1 + r * (b2 - b1)
    coll = coll1.plus(coll2.minus(coll1).scaled(r))

    compute_s = flops / M.PEAK_FLOPS_BF16
    memory_s = byts / M.HBM_BW
    collective_s = coll.total_bytes / M.ICI_BW_PER_LINK
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, shape)
    useful = mf / (flops * chips) if flops else 0.0
    return HA.Roofline(
        flops=flops, bytes_accessed=byts, collective_bytes=coll.total_bytes,
        chips=chips, compute_s=compute_s, memory_s=memory_s,
        collective_s=collective_s, dominant=dominant, model_flops=mf,
        useful_ratio=useful, collectives=coll, per_device_mem=0)
