"""Roofline-term extraction from a compiled (SPMD-partitioned) module.

compute term    = HLO_FLOPs / (chips × 197 TFLOP/s bf16)
memory term     = HLO_bytes / (chips × 819 GB/s)
collective term = collective_bytes / (chips × 50 GB/s/link)

cost_analysis() supplies FLOPs/bytes.  collective_bytes is parsed from
``compiled.as_text()`` post-partitioning HLO: we sum the OPERAND sizes of
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute op (operand types are inlined in HLO long text).
"""
from __future__ import annotations

import dataclasses
import re

from repro.launch import mesh as M

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z]+\d*[a-z0-9]*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*((?:\(?\s*[a-z]+\d*[a-z0-9]*\[[\d,]*\]"
    r"[^)=]*\)?)?)\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUP_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_shape_bytes(fragment: str) -> int:
    """Total bytes of every typed shape in an HLO text fragment.

    Shared with ``repro.analysis.rules`` (gather/scatter result budgets):
    pass the result-type portion of an op line (everything left of the
    op name) and get the summed byte size — tuple results sum their
    elements, unknown dtypes are skipped."""
    return sum(_shape_bytes(dt, dims)
               for dt, dims in _SHAPE_RE.findall(fragment)
               if dt in _DTYPE_BYTES)


@dataclasses.dataclass
class CollectiveStats:
    """Per-device WIRE bytes (ring-algorithm volumes) by collective kind."""
    bytes_by_kind: dict
    count_by_kind: dict

    @property
    def total_bytes(self) -> int:
        return sum(self.bytes_by_kind.values())

    def scaled(self, factor: float) -> "CollectiveStats":
        return CollectiveStats(
            {k: v * factor for k, v in self.bytes_by_kind.items()},
            dict(self.count_by_kind))

    def minus(self, other: "CollectiveStats") -> "CollectiveStats":
        return CollectiveStats(
            {k: max(0.0, self.bytes_by_kind[k] - other.bytes_by_kind[k])
             for k in self.bytes_by_kind},
            {k: max(0, self.count_by_kind[k] - other.count_by_kind[k])
             for k in self.count_by_kind})

    def plus(self, other: "CollectiveStats") -> "CollectiveStats":
        return CollectiveStats(
            {k: self.bytes_by_kind[k] + other.bytes_by_kind[k]
             for k in self.bytes_by_kind},
            {k: self.count_by_kind[k] + other.count_by_kind[k]
             for k in self.count_by_kind})


def _group_size(line: str) -> int:
    m = _GROUP_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUP_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 2


def _wire_factor(kind: str, g: int) -> float:
    """Per-device ring wire volume as a multiple of the RESULT bytes."""
    if kind == "all-reduce":
        return 2.0 * (g - 1) / g
    if kind == "all-gather":
        return (g - 1) / g
    if kind == "reduce-scatter":
        return float(g - 1)       # operand = result × g
    if kind == "all-to-all":
        return (g - 1) / g
    return 1.0                    # collective-permute


def parse_collectives(hlo_text: str) -> CollectiveStats:
    """Sum per-device wire bytes of every collective in post-SPMD HLO.

    Operand names carry no inline types in modern HLO text, so sizes come
    from the RESULT type(s) with kind-specific ring factors (result ==
    operand for all-reduce/all-to-all/permute; all-gather result is the
    gathered array; reduce-scatter result is one shard)."""
    bytes_by_kind: dict = {k: 0.0 for k in _COLLECTIVES}
    count_by_kind: dict = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        result_types, kind, startdone = m.group(1), m.group(2), m.group(3)
        if startdone == "-done":
            continue  # counted at -start
        total = 0
        for dt, dims in _SHAPE_RE.findall(result_types):
            if dt in _DTYPE_BYTES:
                total += _shape_bytes(dt, dims)
        g = _group_size(line)
        bytes_by_kind[kind] += total * _wire_factor(kind, g)
        count_by_kind[kind] += 1
    return CollectiveStats(bytes_by_kind, count_by_kind)


@dataclasses.dataclass
class Roofline:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    chips: int
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float
    useful_ratio: float
    collectives: CollectiveStats
    per_device_mem: float

    def row(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_accessed,
            "coll_bytes": self.collective_bytes, "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            "per_device_mem_gb": self.per_device_mem / 1e9,
            "coll_by_kind": self.collectives.bytes_by_kind,
        }


def analyze(compiled, chips: int, model_flops: float = 0.0,
            hlo_text: str | None = None) -> Roofline:
    """Roofline terms from one compiled executable.

    cost_analysis() on a partitioned module reports PER-PARTITION numbers;
    we normalize everything to per-chip seconds.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = parse_collectives(text)

    # cost_analysis flops are per-partition (the module is the per-device
    # program) — per-chip time is direct.
    compute_s = flops / M.PEAK_FLOPS_BF16
    memory_s = byts / M.HBM_BW
    collective_s = coll.total_bytes / M.ICI_BW_PER_LINK
    dominant = max(
        (("compute", compute_s), ("memory", memory_s),
         ("collective", collective_s)), key=lambda kv: kv[1])[0]
    try:
        mem = compiled.memory_analysis()
        # Peak live bytes: arguments + outputs + XLA temp buffers, MINUS
        # the bytes where an output aliases a donated input (donation
        # means those outputs occupy the argument's storage, not new
        # memory — counting both would double the engine's carry, which
        # is the dominant term for run_engine_chunk).
        per_dev = (getattr(mem, "argument_size_in_bytes", 0)
                   + getattr(mem, "output_size_in_bytes", 0)
                   - getattr(mem, "alias_size_in_bytes", 0)
                   + getattr(mem, "temp_size_in_bytes", 0))
    except Exception:
        per_dev = 0
    useful = model_flops / (flops * chips) if flops else 0.0
    return Roofline(flops=flops, bytes_accessed=byts,
                    collective_bytes=coll.total_bytes, chips=chips,
                    compute_s=compute_s, memory_s=memory_s,
                    collective_s=collective_s, dominant=dominant,
                    model_flops=model_flops, useful_ratio=useful,
                    collectives=coll, per_device_mem=per_dev)
