"""Performance contracts for the hot path (DESIGN.md §11).

A :class:`Contract` is the machine-checked statement of the invariants a
compiled entry point must uphold — the properties PRs 3-5 won (sort-free,
allocation-bounded, retrace-free, donated carries) expressed as data
instead of folklore.  Entry points declare their contract with the
:func:`contract` decorator::

    @contract("cep.run_engine", max_compiles=1, donate=())
    @functools.partial(jax.jit, static_argnames=("cfg",))
    def run_engine(cfg, model, events, carry): ...

The decorator is ZERO-COST at call time: it registers the (function,
contract) pair in a module registry and returns the function unchanged —
no wrapper frame on the hot path.  ``repro.analysis.rules`` evaluates the
contract against COMPILED artifacts (jaxpr + optimized HLO +
``memory_analysis()``), and ``repro.analysis.driver.check_all`` sweeps
every config cell and writes ANALYSIS.json.

This module is import-cycle-free by design: the engine / runtime import
it, so it must never import them (budget callables below are duck-typed
over ``EngineConfig``'s attributes).
"""
from __future__ import annotations

import dataclasses
from typing import Callable

# Byte budgets may depend on the config cell being checked, so a budget
# is either a plain int or a callable ``(cfg, n_events) -> int`` resolved
# at check time (the decorator site cannot know the cell's shapes).
Budget = "int | Callable | None"


@dataclasses.dataclass(frozen=True)
class Contract:
    """The hot-path invariants one entry point promises (DESIGN.md §11).

    Rule provenance — which PR established each invariant — lives with
    the rule definitions in ``rules.RULES``; the contract only selects
    and parameterizes them.
    """
    name: str
    # Banned-op rules (PR 3): the compiled artifact must contain no sort
    # (spawn allocation + Algorithm 2 are sort-free), no host callback
    # (the scan never leaves the device), and no f64 (an accidental x64
    # promotion doubles every store pass).
    no_sort: bool = True
    no_callback: bool = True
    no_f64: bool = True
    # Structural control-flow budget (jaxpr-level: scan/while and cond
    # primitive counts).  The per-event step is straight-line code — new
    # data-dependent loops are exactly how O(N log N) work sneaks back.
    max_while: int | None = None
    max_cond: int | None = None
    # Donation (PR 2): argument names whose buffers the entry point
    # promises to reuse.  Checked against the compiled module's
    # ``input_output_alias`` table — a dropped ``donate_argnames`` still
    # produces correct results while silently doubling steady-state
    # memory, which is why this must be machine-checked.
    donate: tuple = ()
    # Retrace budget (PR 4): compilations per config cell across repeated
    # calls with fresh same-shape data.  A leaked static argument (a
    # Python scalar reaching the traced side) recompiles per VALUE.
    max_compiles: int | None = None
    # Allocation budgets (PR 3/5), resolved per cell: XLA temp bytes and
    # the largest single gather result (the PR-3 regression class was a
    # (P, N, C+1) gather temp materialized every event).
    max_temp_bytes: object = None
    max_gather_bytes: object = None
    # Rule names waived for this entry point (legacy / oracle paths keep
    # their sort on purpose — see DESIGN.md §11 "waivers").
    waived: tuple = ()

    def budget(self, field: str, cfg, n_events: int) -> int | None:
        """Resolve a byte budget for one cell (callables get the cell)."""
        v = getattr(self, field)
        return v(cfg, n_events) if callable(v) else v


_REGISTRY: dict = {}


def contract(name: str, **kw) -> Callable:
    """Declare a contract on an entry point; returns the function as-is."""
    c = Contract(name=name, **kw)

    def deco(fn):
        _REGISTRY[name] = (fn, c)
        return fn

    return deco


def get_contract(name: str) -> Contract:
    return _REGISTRY[name][1]


def get_entry(name: str):
    return _REGISTRY[name][0]


def registry() -> dict:
    """name -> (entry point, Contract); a copy — callers cannot mutate."""
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Shared budget formulas (duck-typed over EngineConfig attributes)
# ---------------------------------------------------------------------------

def store_bytes(cfg) -> int:
    """Bytes of one PM store: the unit allocation budgets scale in."""
    per_slot = 4 * 4 + 1 + 4 * cfg.max_any_ids   # i32 ×4 + mask + idset
    return cfg.num_patterns * cfg.max_pms * per_slot


def hot_path_temp_budget(cfg, n_events: int) -> int:
    """XLA temp-buffer budget for one engine scan.

    Legitimate temps are a bounded number of store-shaped buffers (the
    double-buffered scan carry, the spawn scatter operand, the advance
    one-hot in the block kernel's interpret lowering) plus per-event
    StepOut columns.  The constants were calibrated on the PR-6 sweep
    (largest observed cell ~11× store + ~40 B/event) with ~2× headroom —
    tight enough that one resurrected (P, N, C+1)-per-event temp inside
    the scan body (the PR-3 regression class) blows the budget.
    """
    return 24 * store_bytes(cfg) + 128 * n_events * cfg.num_patterns \
        + (1 << 17)


def hot_path_gather_budget(cfg, n_events: int) -> int:
    """Largest single gather result allowed in the compiled module.

    The flat SEQ advance gather is (P·N,) i32; event-batch gathers are
    O(n_events).  Anything store×classes-sized means the PR-3 flat-gather
    rewrite regressed.
    """
    del n_events
    return 8 * 4 * cfg.num_patterns * cfg.max_pms + (1 << 16)
