"""Retrace guard (DESIGN.md §11): compilation counting as a contract.

PR 4 established "the whole scenario sweep is 4 compiles total" — but as
a comment.  This module turns it into a checked budget, two ways:

* :class:`CompileCounter` — snapshots each jitted entry point's
  ``_cache_size()`` and reports the DELTA, i.e. compilations that
  happened inside the ``with`` block.  Calling an entry point twice with
  fresh same-shape data must cost 1 compile; a leaked static argument (a
  Python scalar reaching the traced side) costs one compile per VALUE
  and blows any budget immediately.

* :func:`count_traces` — a decorator for NON-jitted scan bodies
  (``_step_lanes``, ``_run_block``): the wrapped Python body runs once
  per trace, so a global counter of body executions IS a trace counter.
  Unlike ``_cache_size()`` this also sees traces of functions that are
  inlined into a caller's jit (no cache of their own).

Both feed :func:`retrace_findings`, which converts measured counts into
the same Finding rows the artifact rules emit.
"""
from __future__ import annotations

import collections
import functools

from repro.analysis.rules import Finding

_TRACE_COUNTS: collections.Counter = collections.Counter()


def count_traces(name: str):
    """Count Python-body executions (= traces under jit) of ``fn``.

    Zero steady-state cost: after the first trace per config cell the
    wrapper never runs again — jit replays the cached computation.
    """
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            _TRACE_COUNTS[name] += 1
            return fn(*args, **kw)
        wrapper.__wrapped__ = fn
        wrapper._trace_counter_name = name
        return wrapper
    return deco


def trace_counts() -> dict:
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


def _cache_size(fn) -> int:
    try:
        return fn._cache_size()
    except Exception:
        return 0


class CompileCounter:
    """Measure compilations of jitted entry points across a sweep.

        with CompileCounter(run_engine, run_engine_chunk) as cc:
            ... run the {backend x shedder x chunked} sweep ...
        cc.compiles(run_engine)   # executable-cache growth inside block
    """

    def __init__(self, *fns):
        self._fns = fns
        self._base = {}

    def __enter__(self):
        self._base = {id(f): _cache_size(f) for f in self._fns}
        self._trace_base = dict(_TRACE_COUNTS)
        return self

    def __exit__(self, *exc):
        return False

    def compiles(self, fn) -> int:
        return _cache_size(fn) - self._base.get(id(fn), 0)

    def traces(self, name: str) -> int:
        return _TRACE_COUNTS.get(name, 0) - self._trace_base.get(name, 0)


def retrace_findings(measured: dict, budgets: dict, cell: str = "sweep",
                     ) -> list:
    """Findings for measured compile/trace counts vs per-entry budgets.

    ``measured``: entry-point name -> compilations observed over the
    sweep.  ``budgets``: name -> max allowed (entries missing a budget
    are reported as informational passes — measured but unbounded).
    """
    out = []
    for name, n in sorted(measured.items()):
        budget = budgets.get(name)
        if budget is None:
            out.append(Finding("retrace", True,
                               f"{name}: {n} compiles (no budget)", cell))
            continue
        out.append(Finding(
            "retrace", n <= budget,
            f"{name}: {n} compiles vs budget {budget}"
            + ("" if n <= budget else
               " (leaked static argument? shape-dependent Python "
               "branch?)"), cell))
    return out
