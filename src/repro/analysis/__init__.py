"""repro.analysis — compile-time contract checker (DESIGN.md §11).

Static analysis over COMPILED artifacts: jaxpr primitive censuses, HLO
text rules, ``memory_analysis()`` byte budgets, ``input_output_alias``
donation checks, Pallas BlockSpec geometry, and a retrace guard.  Entry
points declare their invariants with :func:`contracts.contract`;
``check_all`` sweeps every config cell and writes ANALYSIS.json.

``contracts`` / ``tracing`` are import-light (the engine imports them);
``driver`` imports the engine, so it is exposed lazily here.
"""
from repro.analysis.contracts import (      # noqa: F401
    Contract, contract, get_contract, get_entry, registry)
from repro.analysis.rules import (          # noqa: F401
    Artifact, Finding, Rule, RULES, primitive_census, run_rules,
    trace_artifact)
from repro.analysis.tracing import (        # noqa: F401
    CompileCounter, count_traces, reset_trace_counts, trace_counts)

__all__ = ["Contract", "contract", "get_contract", "get_entry",
           "registry", "Artifact", "Finding", "Rule", "RULES",
           "primitive_census", "run_rules", "trace_artifact",
           "CompileCounter", "count_traces", "reset_trace_counts",
           "trace_counts", "check_all"]


def __getattr__(name):
    import importlib
    if name in ("check_all", "driver"):
        driver = importlib.import_module("repro.analysis.driver")
        return driver if name == "driver" else driver.check_all
    if name == "pallas_rules":
        return importlib.import_module("repro.analysis.pallas_rules")
    raise AttributeError(f"module 'repro.analysis' has no attribute "
                         f"{name!r}")
