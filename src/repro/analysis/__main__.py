"""CLI: ``python -m repro.analysis [--quick] [--out ANALYSIS.json]``.

Exit code 1 on any contract violation — the CI analysis job's gate.
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--quick", action="store_true",
                    help="reduced cell grid (tier-1 test subset)")
    ap.add_argument("--out", default="ANALYSIS.json",
                    help="JSON artifact path (default ANALYSIS.json)")
    args = ap.parse_args(argv)

    from repro.analysis.driver import check_all
    result = check_all(quick=args.quick, out=args.out)
    for row in result["rows"]:
        mark = "ok  " if row["status"] == "pass" else "FAIL"
        print(f"{mark} {row['rule']:<20} {row['cell']:<40} "
              f"{row['evidence']}")
    print(f"\n{result['cells']} cells, {len(result['rows'])} findings, "
          f"{result['n_fail']} failures -> {args.out}")
    return 0 if result["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
