"""Pallas-specific checks (DESIGN.md §11): BlockSpecs vs kernels/tiling.

Walks a traced jaxpr for ``pallas_call`` equations and validates each
launch's grid/BlockSpec geometry against the repo's tiling contract
(``kernels/tiling.py``) without executing anything:

* tile-multiple — every operand's array shape is an exact multiple of
  its block shape (callers must pad with ``tiling.pad_to_tile``; a
  non-multiple means a partial edge tile the kernels don't mask for);
* grid-bounds — evaluating each BlockSpec's ``index_map`` at the grid
  corners must keep ``offset x block`` inside the array;
* vmem-budget — the per-generation resident footprint (sum of one
  block per operand/result) stays under the per-core VMEM budget;
* block-alias — the store-resident ``block_step`` launch carries its
  ``input_output_aliases`` (the in-place store update PR 5 depends on);
* kernel-census — the INNER kernel jaxpr contains no banned primitive
  (a sort inside a Pallas body would evade the HLO text check, since
  Mosaic lowers it outside XLA's op vocabulary).

These run on the same artifacts as ``rules.RULES`` — they just no-op on
cells whose jaxpr launches no Pallas kernel (backend="xla").
"""
from __future__ import annotations

import math

import jax

from repro.analysis import rules as R

# ~16 MiB of VMEM per TensorCore (see /opt/skills/guides notes); one
# kernel generation must keep every operand/result block resident.
VMEM_BUDGET_BYTES = 16 * 1024 * 1024

# The block_step megakernel aliases these store buffers in place
# (kernels/block_step.py): active, state, open_idx, bind, idset, ring,
# ring_ptr, complex_count, pms_created, lat_n, lat_l.
BLOCK_STEP_MIN_ALIASES = 11

_BANNED_INNER = ("sort", "pure_callback", "io_callback", "debug_callback")


def pallas_calls(jaxpr) -> list:
    """All pallas_call eqns in the jaxpr, including nested sub-jaxprs."""
    found = []

    def walk(jx):
        for eqn in jx.eqns:
            if eqn.primitive.name == "pallas_call":
                found.append(eqn)
            for v in eqn.params.values():
                for sub in R._sub_jaxprs(v):
                    walk(sub)
    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return found


def _kernel_name(eqn) -> str:
    info = eqn.params.get("name_and_src_info")
    return getattr(info, "name", None) or str(info or "pallas_call")


def _block_bytes(bm) -> int:
    shape = tuple(int(d) for d in bm.block_shape)
    dt = bm.array_shape_dtype.dtype
    return math.prod(shape) * dt.itemsize if shape else dt.itemsize


def _grid_corners(grid):
    """Index tuples to probe: all points for tiny grids, else corners."""
    if not grid:
        return [()]
    if math.prod(grid) <= 64:
        pts = [()]
        for g in grid:
            pts = [p + (i,) for p in pts for i in range(g)]
        return pts
    corners = [()]
    for g in grid:
        corners = [p + (i,) for p in corners
                   for i in ({0, g - 1} if g > 1 else {0})]
    return corners


def _eval_index_map(bm, idx):
    jx = bm.index_map_jaxpr
    out = jax.core.eval_jaxpr(jx.jaxpr, jx.consts, *map(int, idx))
    return tuple(int(v) for v in out)


def check_pallas_calls(art: R.Artifact, ctr) -> list:
    """The Pallas findings for one artifact (empty-jaxpr safe)."""
    if art.jaxpr is None:
        return []
    calls = pallas_calls(art.jaxpr)
    is_block_cfg = getattr(art.cfg, "backend", "") == "pallas_block"
    if not calls:
        if is_block_cfg:
            return [R.Finding(
                "pallas-block-alias", False,
                "backend=pallas_block but no block kernel launch found",
                art.name)]
        return [R.Finding("pallas", True, "no pallas_call in jaxpr",
                          art.name)]
    out = []
    saw_block_step = False
    for eqn in calls:
        name = _kernel_name(eqn)
        gm = eqn.params["grid_mapping"]
        grid = tuple(int(g) for g in gm.grid)
        bms = list(gm.block_mappings)

        # -- tile-multiple + grid-bounds per operand ---------------------
        bad_tile, bad_bounds = [], []
        for k, bm in enumerate(bms):
            ashape = tuple(int(d) for d in bm.array_shape_dtype.shape)
            bshape = tuple(int(d) for d in bm.block_shape)
            if len(ashape) != len(bshape):
                bad_tile.append(f"op{k}: rank {ashape} vs block {bshape}")
                continue
            if any(b and a % b for a, b in zip(ashape, bshape)):
                bad_tile.append(f"op{k}: array {ashape} not a multiple "
                                f"of block {bshape}")
            try:
                for idx in _grid_corners(grid):
                    off = _eval_index_map(bm, idx)
                    for o, b, a in zip(off, bshape, ashape):
                        if o * b < 0 or (o + 1) * b > a:
                            bad_bounds.append(
                                f"op{k}@grid{idx}: block [{o * b},"
                                f"{(o + 1) * b}) outside [0,{a})")
            except Exception as e:  # index_map not statically evaluable
                bad_bounds.append(f"op{k}: index_map eval failed: {e}")
        out.append(R.Finding(
            "pallas-tiling", not bad_tile,
            bad_tile[0] if bad_tile else
            f"{name}: {len(bms)} operands tile-aligned, grid {grid}",
            art.name))
        out.append(R.Finding(
            "pallas-grid-bounds", not bad_bounds,
            bad_bounds[0] if bad_bounds else
            f"{name}: index maps in-bounds at "
            f"{len(_grid_corners(grid))} grid point(s)", art.name))

        # -- VMEM: one generation = one block per operand ----------------
        vmem = sum(_block_bytes(bm) for bm in bms)
        out.append(R.Finding(
            "pallas-vmem", vmem <= VMEM_BUDGET_BYTES,
            f"{name}: resident blocks {vmem} B vs budget "
            f"{VMEM_BUDGET_BYTES} B", art.name))

        # -- inner kernel census -----------------------------------------
        inner = R.primitive_census(eqn.params["jaxpr"])
        hit = [p for p in _BANNED_INNER if inner.get(p, 0)]
        out.append(R.Finding(
            "pallas-kernel-census", not hit,
            f"{name}: banned primitive(s) {hit} inside kernel body"
            if hit else f"{name}: kernel body clean "
            f"({sum(inner.values())} eqns)", art.name))

        # -- block_step alias coverage ------------------------------------
        if "block" in name:
            saw_block_step = True
            aliases = eqn.params.get("input_output_aliases") or ()
            ok = len(aliases) >= BLOCK_STEP_MIN_ALIASES
            out.append(R.Finding(
                "pallas-block-alias", ok,
                f"{name}: {len(aliases)} input_output_aliases "
                f"(store-resident update needs >= "
                f"{BLOCK_STEP_MIN_ALIASES})", art.name))
    if is_block_cfg and not saw_block_step:
        out.append(R.Finding(
            "pallas-block-alias", False,
            "backend=pallas_block but no block kernel launch found",
            art.name))
    return out


PALLAS_RULE = R.Rule(
    "pallas", "PR 5",
    "Pallas launches match kernels/tiling.py: tile-multiple shapes, "
    "in-bounds index maps, VMEM-resident generations, aliased "
    "block_step stores, clean kernel bodies.",
    check_pallas_calls)
