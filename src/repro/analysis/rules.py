"""Rule engine over compiled artifacts (DESIGN.md §11).

Checks COMPILED representations, not runtime behavior: each entry point
is traced to a jaxpr and lowered/compiled to optimized HLO, and rules
assert invariants on both —

  * jaxpr: primitive census (sort / callback primitives, structural
    while/scan/cond counts) including every sub-jaxpr (cond branches,
    scan bodies, pjit calls, Pallas kernel bodies);
  * HLO text: banned op applications (``sort(``, callback custom-calls,
    ``f64[`` types), the module header's ``input_output_alias`` table
    (donation), per-op result bytes (gather budget — shape parsing
    shared with ``launch.hlo_analysis``);
  * ``compiled.memory_analysis()``: XLA temp-buffer bytes vs the
    contract's allocation budget.

Every rule yields a :class:`Finding` with pass/fail AND an evidence line
(the offending HLO line or the measured number vs its budget), which is
what ``driver.check_all`` writes into ANALYSIS.json.
"""
from __future__ import annotations

import collections
import dataclasses
import re

import jax

from repro.analysis import contracts as C
from repro.launch import hlo_analysis as HA

# ---------------------------------------------------------------------------
# Artifacts
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Artifact:
    """One entry point traced + compiled at one config cell."""
    name: str                 # cell label, e.g. "run_engine[pallas/pspice]"
    jaxpr: object             # ClosedJaxpr (None if tracing was skipped)
    compiled: object          # jax Compiled (None for jaxpr-only checks)
    hlo: str                  # optimized HLO long text ("" when uncompiled)
    cfg: object = None        # the cell's EngineConfig (budget resolution)
    n_events: int = 0
    # Expected minimum input_output_alias pairs (the donated pytree's
    # leaf count; 0 when the contract donates nothing).  Some donated
    # leaves are legitimately unusable (layout changes), so the driver
    # sets this to the count that MUST alias — the carry leaves.
    min_alias_pairs: int = 0

    _census: collections.Counter = None
    _memory: object = None

    @property
    def census(self) -> collections.Counter:
        if self._census is None:
            self._census = (primitive_census(self.jaxpr)
                            if self.jaxpr is not None
                            else collections.Counter())
        return self._census

    @property
    def memory(self):
        if self._memory is None and self.compiled is not None:
            try:
                self._memory = self.compiled.memory_analysis()
            except Exception:   # backend without memory_analysis support
                self._memory = None
        return self._memory


def trace_artifact(fn, *args, static_argnums=(0,), name: str = "",
                   cfg=None, n_events: int = 0, min_alias_pairs: int = 0,
                   compile: bool = True) -> Artifact:
    """Build the checkable artifact for one (entry point, cell) pair.

    ``fn`` is a jitted entry point (``fn.lower`` must exist) whose
    static arguments sit at ``static_argnums`` (the engine convention:
    the EngineConfig leads).  With ``compile=False`` only the jaxpr view
    is built — the cheap mode ``bench_engine.py`` uses to refuse
    degraded baselines without paying a second XLA compile.
    """
    jaxpr = jax.make_jaxpr(fn, static_argnums=static_argnums)(*args)
    compiled, hlo = None, ""
    if compile:
        compiled = fn.lower(*args).compile()
        hlo = compiled.as_text()
    if cfg is None and static_argnums:
        cfg = args[static_argnums[0]]
    return Artifact(name=name or getattr(fn, "__name__", "fn"),
                    jaxpr=jaxpr, compiled=compiled, hlo=hlo, cfg=cfg,
                    n_events=n_events, min_alias_pairs=min_alias_pairs)


def primitive_census(jaxpr) -> collections.Counter:
    """Count primitive applications across the jaxpr and EVERY sub-jaxpr
    (cond branches, scan/while bodies, pjit calls, pallas_call kernels)."""
    counts: collections.Counter = collections.Counter()

    def walk(jx):
        for eqn in jx.eqns:
            counts[eqn.primitive.name] += 1
            for v in eqn.params.values():
                for sub in _sub_jaxprs(v):
                    walk(sub)
    walk(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)
    return counts


def _sub_jaxprs(v):
    """Yield the plain Jaxprs nested inside one eqn param value."""
    from jax.extend.core import ClosedJaxpr, Jaxpr
    if isinstance(v, ClosedJaxpr):
        yield v.jaxpr
    elif isinstance(v, Jaxpr):
        yield v
    elif isinstance(v, (tuple, list)):
        for x in v:
            yield from _sub_jaxprs(x)


# ---------------------------------------------------------------------------
# HLO text helpers (shape parsing shared with launch.hlo_analysis)
# ---------------------------------------------------------------------------

# An HLO op application: "%name = <result types> opname(...)".  Evidence
# wants the line; budgets want the result bytes via HA.parse_shape_bytes.
def hlo_op_lines(hlo: str, op: str) -> list:
    """Lines applying HLO op ``op`` (e.g. "sort", "gather").  Matches the
    op at its application site only — names like ``%sort.1 = ...`` still
    only match through the trailing ``(``, and fused-computation NAMES
    (``%sorted_branch``) never do."""
    pat = re.compile(rf"=\s*[^=\n]*\b{re.escape(op)}\(")
    return [ln for ln in hlo.splitlines() if pat.search(ln)]


_ALIAS_PAIR_RE = re.compile(r"\{[\d,\s]*\}:\s*\(")


def hlo_alias_pairs(hlo: str) -> int:
    """Count entries of the module header's ``input_output_alias`` table."""
    head = hlo.split("\n", 1)[0]
    m = re.search(r"input_output_alias=\{(.*?)\}, \w+=", head)
    region = m.group(1) if m else head
    if "input_output_alias" not in head:
        return 0
    return len(_ALIAS_PAIR_RE.findall(region))


def _trunc(line: str, n: int = 160) -> str:
    line = line.strip()
    return line if len(line) <= n else line[: n - 3] + "..."


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Finding:
    rule: str
    ok: bool
    evidence: str
    cell: str = ""

    def row(self) -> dict:
        return {"rule": self.rule, "cell": self.cell,
                "status": "pass" if self.ok else "FAIL",
                "evidence": self.evidence}


@dataclasses.dataclass(frozen=True)
class Rule:
    """One machine-checked invariant.  ``established`` records which PR
    made the property true (the provenance DESIGN.md §11 documents)."""
    name: str
    established: str
    description: str
    check: object           # (Artifact, Contract) -> list[Finding]

    def run(self, art: Artifact, ctr: C.Contract) -> list:
        out = self.check(art, ctr)
        for f in out:
            f.cell = f.cell or art.name
        return out


def _ok(rule, art, evidence):
    return [Finding(rule, True, evidence, art.name)]


def _fail(rule, art, evidence):
    return [Finding(rule, False, evidence, art.name)]


def _check_no_sort(art: Artifact, ctr: C.Contract) -> list:
    if not ctr.no_sort:
        return _ok("no-sort", art, "not required by contract")
    n_jaxpr = art.census.get("sort", 0)
    lines = hlo_op_lines(art.hlo, "sort")
    if n_jaxpr or lines:
        ev = lines[0] if lines else f"{n_jaxpr} sort eqn(s) in jaxpr"
        return _fail("no-sort", art, _trunc(ev))
    return _ok("no-sort", art, "0 sort ops (jaxpr + HLO)")


_CALLBACK_PRIMS = ("pure_callback", "io_callback", "debug_callback",
                   "host_callback_call", "outside_call")
_CALLBACK_TARGET_RE = re.compile(
    r'custom_call_target="[^"]*(callback|host)[^"]*"', re.IGNORECASE)


def _check_no_callback(art: Artifact, ctr: C.Contract) -> list:
    if not ctr.no_callback:
        return _ok("no-callback", art, "not required by contract")
    hit = [p for p in _CALLBACK_PRIMS if art.census.get(p, 0)]
    if hit:
        return _fail("no-callback", art, f"jaxpr primitives: {hit}")
    for ln in art.hlo.splitlines():
        if _CALLBACK_TARGET_RE.search(ln):
            return _fail("no-callback", art, _trunc(ln))
    return _ok("no-callback", art, "no host callbacks")


def _check_no_f64(art: Artifact, ctr: C.Contract) -> list:
    if not ctr.no_f64:
        return _ok("no-f64", art, "not required by contract")
    for ln in art.hlo.splitlines():
        if "f64[" in ln or "c128[" in ln:
            return _fail("no-f64", art, _trunc(ln))
    return _ok("no-f64", art, "no f64/c128 types in HLO")


def _check_control_flow(art: Artifact, ctr: C.Contract) -> list:
    n_loop = art.census.get("while", 0) + art.census.get("scan", 0)
    n_cond = art.census.get("cond", 0)
    out = []
    if ctr.max_while is not None:
        ok = n_loop <= ctr.max_while
        out.append(Finding(
            "control-flow", ok,
            f"while/scan count {n_loop} vs budget {ctr.max_while}"))
    if ctr.max_cond is not None:
        ok = n_cond <= ctr.max_cond
        out.append(Finding(
            "control-flow", ok,
            f"cond count {n_cond} vs budget {ctr.max_cond}"))
    return out or _ok("control-flow", art, "no budget declared")


def _check_donation(art: Artifact, ctr: C.Contract) -> list:
    if not ctr.donate:
        return _ok("donation", art, "contract donates nothing")
    pairs = hlo_alias_pairs(art.hlo)
    need = art.min_alias_pairs
    mem = art.memory
    aliased = getattr(mem, "alias_size_in_bytes", 0) if mem else 0
    if pairs < need:
        return _fail(
            "donation", art,
            f"input_output_alias has {pairs} pair(s), contract "
            f"donate={ctr.donate} needs >= {need} (broken donation "
            f"doubles steady-state memory)")
    return _ok("donation", art,
               f"{pairs} alias pairs (>= {need}), {aliased} B aliased")


def _check_temp_bytes(art: Artifact, ctr: C.Contract) -> list:
    budget = ctr.budget("max_temp_bytes", art.cfg, art.n_events)
    if budget is None:
        return _ok("temp-bytes", art, "no budget declared")
    mem = art.memory
    if mem is None:
        return _ok("temp-bytes", art, "memory_analysis unavailable")
    t = int(mem.temp_size_in_bytes)
    return [Finding("temp-bytes", t <= budget,
                    f"XLA temp buffers {t} B vs budget {budget} B")]


def _check_gather_bytes(art: Artifact, ctr: C.Contract) -> list:
    budget = ctr.budget("max_gather_bytes", art.cfg, art.n_events)
    if budget is None:
        return _ok("gather-bytes", art, "no budget declared")
    worst, worst_line = 0, ""
    for op in ("gather", "scatter"):
        for ln in hlo_op_lines(art.hlo, op):
            b = HA.parse_shape_bytes(ln.split(f"{op}(")[0])
            if b > worst:
                worst, worst_line = b, ln
    if worst > budget:
        return _fail("gather-bytes", art,
                     f"{worst} B result > budget {budget} B: "
                     f"{_trunc(worst_line, 110)}")
    return _ok("gather-bytes", art,
               f"largest gather/scatter result {worst} B <= {budget} B")


RULES = (
    Rule("no-sort", "PR 3",
         "No sort in the compiled hot path: the spawn allocator is O(N) "
         "free-list compaction and Algorithm 2 is the histogram-"
         "refinement select.", _check_no_sort),
    Rule("no-callback", "PR 1",
         "The event scan never leaves the device: no host callbacks / "
         "outside calls in the compiled module.", _check_no_callback),
    Rule("no-f64", "PR 1",
         "All hot-path arithmetic is f32/i32; an accidental x64 "
         "promotion doubles every store pass.", _check_no_f64),
    Rule("control-flow", "PR 5",
         "Structural while/scan and cond counts stay within the "
         "declared budget — new data-dependent loops are how "
         "O(N log N) work returns.", _check_control_flow),
    Rule("donation", "PR 2",
         "Donated carries / chunk buffers actually alias in the "
         "compiled module (input_output_alias).", _check_donation),
    Rule("temp-bytes", "PR 3",
         "XLA temp-buffer bytes within the per-cell budget "
         "(allocation-free hot path).", _check_temp_bytes),
    Rule("gather-bytes", "PR 3",
         "No single gather/scatter result larger than the flat-advance "
         "budget (kills (P,N,C+1)-per-event temps).", _check_gather_bytes),
)


def run_rules(art: Artifact, ctr: C.Contract, rules=None,
              extra_rules=()) -> list:
    """Evaluate rules against one artifact.  Waived rules (legacy /
    oracle paths, DESIGN.md §11) report as passing with the waiver as
    evidence, so ANALYSIS.json shows the waiver instead of hiding it."""
    out = []
    for rule in tuple(RULES if rules is None else rules) + tuple(
            extra_rules):
        if rule.name in ctr.waived:
            out.append(Finding(rule.name, True,
                               f"waived by contract {ctr.name}", art.name))
            continue
        out.extend(rule.run(art, ctr))
    return out
