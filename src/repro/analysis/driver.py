"""check_all: sweep every config cell, evaluate every contract, emit
ANALYSIS.json (the CI artifact).

One cell = one contracted entry point compiled at one
{backend x shedder x chunking} configuration on a small q1 workload.
The cells are deliberately SMALL (n<=96 events, max_pms=48): the rules
check compiled structure, not throughput, and structure is config-
dependent but size-independent — a sort appears in the HLO for N=48
exactly as it would for N=4096.

The retrace guard is the one check that EXECUTES: each jitted entry is
called twice per cell with fresh same-shape data, and executable-cache
growth is compared against the contract's ``max_compiles`` budget
(PR 4's "the whole sweep is 4 compiles" as a machine-checked fact).
"""
from __future__ import annotations

import dataclasses
import json
import tempfile

import jax
import jax.numpy as jnp

from repro.analysis import contracts as C
from repro.analysis import pallas_rules as PR
from repro.analysis import rules as R
from repro.analysis import tracing as T
from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro.runtime import lanes as LN
from repro.runtime import persist as PS
from repro.runtime import service as RTS

BACKENDS = (eng.BACKEND_XLA, eng.BACKEND_PALLAS, eng.BACKEND_PALLAS_BLOCK)
SHEDDERS = (eng.SHED_NONE, eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)

_COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4,
             c_shed_pm=1.5e-6, c_ebl=6e-5)


def _workload(n: int = 96, max_pms: int = 48, seed: int = 0):
    """The q1 fixture every cell reuses (cfg varies per cell)."""
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=0.005,
                                gather_stats=True,
                                shedder=eng.SHED_PSPICE, **_COST)
    model = eng.make_model(cp, cfg)
    rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=100 + seed)
    ev = streams.classify(specs, raw, rate=rate, seed=seed)
    return cfg, model, ev


def _workload_fired(n: int = 96, max_pms: int = 48, seed: int = 0):
    """Spawn-heavy overloaded fixture (tight bound, p_class=0.5): the
    Algorithm-1 check fires many times per block, so tracing it keeps the
    fused in-kernel Algorithm-2 path — and the replay driver it
    retired — under contract in the regime they exist for."""
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=0.001,
                                gather_stats=True,
                                shedder=eng.SHED_PSPICE, **_COST)
    model = eng.make_model(cp, cfg)
    rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.5, seed=100 + seed)
    ev = streams.classify(specs, raw, rate=rate, seed=seed)
    return cfg, model, ev


def _cells(quick: bool):
    """(backend, shedder) grid for run_engine; quick keeps one row and
    one column so tests touch every backend and every shedder once."""
    if not quick:
        return [(b, s) for b in BACKENDS for s in SHEDDERS]
    cells = [(b, eng.SHED_PSPICE) for b in BACKENDS]
    cells += [(eng.BACKEND_XLA, s) for s in SHEDDERS
              if s != eng.SHED_PSPICE]
    return cells


def _leaves(tree) -> int:
    return len(jax.tree.leaves(tree))


def _findings_for(art, ctr):
    return R.run_rules(art, ctr) + PR.check_pallas_calls(art, ctr)


def check_all(quick: bool = False, out: str | None = None) -> dict:
    """Evaluate every registered contract across the config sweep.

    Returns {"ok", "n_fail", "cells", "rows"}; with ``out`` also writes
    the same structure as JSON (the CI artifact). ``quick=True`` runs the
    reduced grid tier-1 tests use (~6 compiles instead of ~20).
    """
    cfg0, model, ev = _workload()
    n = ev.ev_class.shape[0]
    findings = []

    # ---- run_engine over the {backend x shedder} grid -------------------
    c_run = C.get_contract("cep.run_engine")
    for backend, shedder in _cells(quick):
        cfg = dataclasses.replace(cfg0, backend=backend, shedder=shedder)
        cell = f"run_engine[{backend}/{shedder}]"
        art = R.trace_artifact(eng.run_engine, cfg, model, ev,
                               eng.init_carry(cfg), name=cell, n_events=n)
        findings += _findings_for(art, c_run)

    # ---- fired-heavy cells: the block kernel in the overload regime -----
    # The fused in-kernel Algorithm 2 (default) and the legacy replay
    # driver, traced on a workload where the shed actually fires many
    # times per block — so the census/alias rules see the overload path
    # as the hot path, not just the unfired fast path.  Quick mode keeps
    # the fused pspice cell (the tentpole's structure).
    cfg_f, model_f, ev_f = _workload_fired()
    n_f = ev_f.ev_class.shape[0]
    fired_cells = [(eng.SHED_PSPICE, "fused")]
    if not quick:
        fired_cells += [(eng.SHED_PMBL, "fused"), (eng.SHED_PSPICE,
                                                   "replay")]
    for shedder, mode in fired_cells:
        cfg = dataclasses.replace(cfg_f, backend=eng.BACKEND_PALLAS_BLOCK,
                                  shedder=shedder, block_shed=mode)
        cell = f"run_engine[fired-heavy/{mode}/{shedder}]"
        art = R.trace_artifact(eng.run_engine, cfg, model_f, ev_f,
                               eng.init_carry(cfg), name=cell,
                               n_events=n_f)
        findings += _findings_for(art, c_run)

    # ---- run_engine_chunk (donation must hold on every backend) ---------
    c_chunk = C.get_contract("cep.run_engine_chunk")
    chunk = 32
    piece = jax.tree.map(lambda x: x[:chunk], ev)
    for backend in (BACKENDS if not quick else BACKENDS[:1]):
        cfg = dataclasses.replace(cfg0, backend=backend)
        carry = eng.init_carry(cfg)
        cell = f"run_engine_chunk[{backend}/{cfg.shedder}]"
        art = R.trace_artifact(eng.run_engine_chunk, cfg, model, piece,
                               carry, jnp.int32(0), name=cell,
                               n_events=chunk,
                               min_alias_pairs=_leaves(carry))
        findings += _findings_for(art, c_chunk)

    # ---- lane-batched chunk entries -------------------------------------
    L = 2
    lmodel = LN.broadcast_model(model, L)
    lev = jax.tree.map(lambda x: jnp.stack([x[:chunk]] * L), ev)
    for name in ("runtime.run_chunk_lanes", "runtime.run_chunk_lanes"
                 "_donated"):
        fn, lctr = C.registry()[name]
        lcarry = LN.init_lane_carries(cfg0, L)
        cell = f"{name.split('.')[1]}[{cfg0.backend}/{cfg0.shedder}]"
        art = R.trace_artifact(fn, cfg0, lmodel, lev, lcarry,
                               jnp.int32(0), name=cell, n_events=chunk,
                               min_alias_pairs=_leaves(lcarry))
        findings += _findings_for(art, lctr)

    # ---- retrace guard: execute twice per cell, count compiles ----------
    findings += _retrace_sweep(cfg0, model, ev, quick)

    # ---- durable recovery: zero fresh compiles + clean restored carry ---
    findings += _persist_sweep(cfg0, model, ev)

    rows = [f.row() for f in findings]
    n_fail = sum(not f.ok for f in findings)
    result = {"ok": n_fail == 0, "n_fail": n_fail,
              "cells": len({f.cell for f in findings}), "rows": rows}
    if out:
        with open(out, "w") as fh:
            json.dump(result, fh, indent=1)
    return result


def _retrace_sweep(cfg0, model, ev, quick: bool) -> list:
    """Run each entry twice per cell with fresh same-shape data; cache
    growth above cells x max_compiles means a leaked static argument."""
    chunk = 32
    backends = BACKENDS[:1] if quick else BACKENDS
    entries = (C.get_entry("cep.run_engine"),
               C.get_entry("cep.run_engine_chunk"))
    budgets, measured = {}, {}
    with T.CompileCounter(*entries) as cc:
        for backend in backends:
            cfg = dataclasses.replace(cfg0, backend=backend)
            for _ in range(2):
                eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
            for _ in range(2):
                piece = jax.tree.map(lambda x: x[:chunk].copy(), ev)
                eng.run_engine_chunk(cfg, model, piece,
                                     eng.init_carry(cfg), jnp.int32(0))
        jax.block_until_ready(eng.run_engine(cfg0, model, ev,
                                             eng.init_carry(cfg0)))
        for name in ("cep.run_engine", "cep.run_engine_chunk"):
            fn, ctr = C.registry()[name]
            # One compile budget per cell; run_engine's extra final call
            # re-hits the first cell's cache, so no extra budget.
            budgets[name] = len(backends) * (ctr.max_compiles or 1)
            measured[name] = cc.compiles(fn)
    return T.retrace_findings(measured, budgets, cell="retrace-sweep")


def _persist_sweep(cfg0, model, ev) -> list:
    """Durable-recovery contract (DESIGN.md §13): a runtime rebuilt from
    a snapshot + WAL replay must re-enter the SAME chunk executable —
    zero fresh compiles during recovery and the post-recovery stream —
    and the restored carry must trace clean through the chunk contract
    (donation aliasing, no host callbacks, no f64)."""
    chunk = 32

    def rt_cfg(d):
        # group_chunks=1 pins the run_engine_chunk path (the entry the
        # compile counter watches); snapshot on every push.
        return RTS.RuntimeConfig(chunk_size=chunk, group_chunks=1,
                                 persist=PS.PersistConfig(
                                     dir=d, snapshot_every_chunks=1))

    with tempfile.TemporaryDirectory() as d:
        warm = RTS.StreamRuntime(cfg0, model, rt_cfg(d))
        warm.push(jax.tree.map(lambda x: x[:2 * chunk].copy(), ev))
        warm.persist.wal.close()

        entry, ctr = C.registry()["cep.run_engine_chunk"]
        with T.CompileCounter(entry) as cc:
            rec = RTS.StreamRuntime(cfg0, model, rt_cfg(d))
            rec.recover_from_disk()
            rec.push(jax.tree.map(lambda x: x[2 * chunk:3 * chunk].copy(),
                                  ev))
            measured = {"cep.run_engine_chunk[post-recovery]":
                        cc.compiles(entry)}
        findings = T.retrace_findings(
            measured, {"cep.run_engine_chunk[post-recovery]": 0},
            cell="persist-sweep")

        piece = jax.tree.map(lambda x: x[:chunk].copy(), ev)
        carry = jax.tree.map(jnp.asarray, rec.carry)
        art = R.trace_artifact(eng.run_engine_chunk, cfg0, model, piece,
                               carry, jnp.int32(0),
                               name=f"run_engine_chunk[{cfg0.backend}/"
                                    f"{cfg0.shedder}/persist-restored]",
                               n_events=chunk,
                               min_alias_pairs=_leaves(carry))
        findings += _findings_for(art, ctr)
    return findings
