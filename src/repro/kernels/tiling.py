"""Shared block-tiling helpers for the Pallas kernels.

Every kernel in this package accepts stores whose length is NOT a tile
multiple: inputs pad the tail with neutral fill values (inactive slots,
NaN utilities) that the kernel provably passes through, and outputs slice
back.  The padding arithmetic used to be repeated per kernel; this module
is the one owner (used by nfa_transition.py, shed_select.py and the
block-step megakernel's event-axis padding in cep/engine.py).
"""
from __future__ import annotations

import jax.numpy as jnp


def tile_pad(tile: int, n: int) -> int:
    """Elements of tail padding needed to reach a multiple of ``tile``."""
    return (-n) % tile


def pad_to_tile(tile: int, *pairs):
    """Pad each ``(array, fill)`` pair's axis 0 to a multiple of ``tile``.

    Returns ``(padded_0, ..., padded_k, pad)`` where ``pad`` is the tail
    length that callers slice back off their outputs (0 when the length
    already divides — arrays pass through untouched).
    """
    n = pairs[0][0].shape[0]
    pad = tile_pad(tile, n)
    if not pad:
        return tuple(x for x, _ in pairs) + (0,)
    padded = tuple(
        jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)])
        for x, fill in pairs)
    return padded + (pad,)
