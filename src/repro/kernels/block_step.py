"""Store-resident event-block megakernel (DESIGN.md §10).

One ``pl.pallas_call`` advances the engine through a BLOCK of
``W = cfg.block_events`` events: the PM store, window ring, overload
scalars and per-pattern counters stay resident (VMEM on TPU) for the
whole block while an in-kernel ``fori_loop`` replays the paper's
per-event operator — expire → Algorithm-1 overload check → E-BL drop →
advance → complete → spawn → observation gather → simulated time — and
writes one ``StepOut`` row per event into output tiles.  This is the
IO-aware tiling trick of ``kernels/flash_attention.py`` applied to the
CEP hot loop: the per-event jnp step streams the whole (P, N) store
through HBM ~6 times per event; here it is loaded once per W events.

Shedding protocol — FUSED (the default, ``cfg.block_shed="fused"``):
a ``shed ∧ ρ>0`` event is handled INSIDE the loop.  Under a
``lax.cond`` (so unfired events pay nothing) the kernel recomputes the
store-resident utility column (pSPICE: the interpolated table lookup of
``core.utility.multi_pattern_lookup``, arithmetic-identical; PM-BL: a
host-precomputed row of iid uniforms) and applies the very same O(N)
histogram-refinement select the host paths use —
``core.shedder.threshold_drop_mask`` with the shared ``bucket_edges`` —
then pays the shed cost, bumps pms_shed/shed_calls and continues to the
normal advance/spawn path of the SAME event.  PRNG discipline survives
fusion because the wrapper precomputes the whole per-fire key chain
host-side (``keys[t+1], subs[t] = split(keys[t])``): the kernel only
counts fires, the wrapper advances ``carry.key`` to ``chain[n_fires]``,
and PM-BL's uniforms are drawn from exactly the ``sub`` the host path
would have used for the same fire ordinal.  A block with F fires is
still ONE launch.

Legacy protocol (block split, ``cfg.block_shed="replay"`` — kept as the
oracle, and the forced path for ``shed_plan="sort"``): the loop stops
committing at the first ``shed ∧ ρ>0`` event and reports ``(fired,
fire_idx)``; the engine driver replays that event through the ordinary
``_step`` — which re-derives the identical decision from the committed
carry, splits the PRNG key and runs the host-level Algorithm-2 path —
and re-enters the kernel at ``fire_idx + 1``.

Either way every committed quantity goes through arithmetic
bit-identical to the xla backend's (same reduction shapes and orders;
the one-hot advance touches exactly one nonzero per row), which is what
lets tests/test_block_backend.py and the eval/oracle.py suite demand
EXACT equality.

Slot allocation matches the engine's free-list compaction without its
full-store scatter: candidate r takes the (r+1)-th lowest-index inactive
slot, found as ``argmax(cumsum(~active) == r+1)`` — one pass per
candidate instead of an N-sized scatter (and a single ``argmax(~active)``
when the census proves only AT_OPEN spawns exist).

TARGET: TPU (grid=(), every operand one VMEM-resident block).
VALIDATED: interpret=True vs the xla engine (tests/test_block_backend.py)
and the NumPy oracle (tests/test_oracle.py).  The ``gather_stats``
variant updates the (P, M, M) observation matrices with the engine's
exact scatter-add; Mosaic support for in-kernel scatter is limited, so
stats-gathering (warm-up only, never the hot path) should keep
``interpret=True`` off-CPU too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.cep import patterns as pat
from repro.core import overload as ovl
from repro.core import shedder as shd

SHED_PSPICE, SHED_PMBL, SHED_EBL = "pspice", "pmbl", "ebl"

SHED_NBINS = 128   # the engine shed paths' histogram width (kops default)


def fused_shed(cfg) -> bool:
    """True when this config runs Algorithm 2 inside the block kernel.

    The fused path implements the O(N) threshold plan only; the sort
    plan (the argsort oracle) and an explicit ``block_shed="replay"``
    pin the legacy block-split protocol instead."""
    return (cfg.shedder in (SHED_PSPICE, SHED_PMBL)
            and cfg.shed_plan == "threshold"
            and getattr(cfg, "block_shed", "fused") == "fused")


def _block_kernel(*refs, spec):
    """Kernel body: unpacks refs positionally (mirror of the wrapper's
    operand assembly), loads the resident state once, loops over the W
    events, writes the state + per-event rows back."""
    (P, N, M, A, K, S, W) = (spec["P"], spec["N"], spec["M"], spec["A"],
                             spec["K"], spec["S"], spec["W"])
    kinds, spawn_modes = spec["kinds"], spec["spawn_modes"]
    shedder, emit, stats = spec["shedder"], spec["emit"], spec["stats"]
    fused = spec["fused"]
    f32, i32 = jnp.float32, jnp.int32

    it = iter(refs)
    nxt = lambda: next(it)                                   # noqa: E731
    (tcols_ref, evc_ref, evb_ref, evo_ref, evid_ref, evr_ref, eraw_ref,
     arr_ref, iscal_ref, fscal_ref) = (nxt() for _ in range(10))
    (act_ref, st_ref, oi_ref, bd_ref, ids_ref, ring_ref, rp_ref) = (
        nxt() for _ in range(7))
    (ws_ref, fin_ref, ub_ref, kind_ref, sm_ref, sc_ref, pc_ref) = (
        nxt() for _ in range(7))
    cplx_ref, crtd_ref, latn_ref, latl_ref = (nxt() for _ in range(4))
    if fused and shedder == SHED_PSPICE:
        utt_ref, utb_ref = nxt(), nxt()
    if fused and shedder == SHED_PMBL:
        unif_ref = nxt()
    if stats:
        obsc_ref, obsr_ref = nxt(), nxt()
    # outputs
    (oact_ref, ost_ref, ooi_ref, obd_ref, oids_ref, oring_ref, orp_ref,
     ocplx_ref, ocrtd_ref, olatn_ref, olatl_ref) = (nxt() for _ in range(11))
    ofscal_ref, oiscal_ref = nxt(), nxt()
    ole_ref, onpm_ref, oshed_ref, odrop_ref = (nxt() for _ in range(4))
    if emit:
        omo_ref, omb_ref = nxt(), nxt()
    if stats:
        oobsc_ref, oobsr_ref = nxt(), nxt()

    iscal = iscal_ref[...]
    s, n_valid, i0, lat_ptr0 = iscal[0], iscal[1], iscal[2], iscal[3]
    fscal = fscal_ref[...]
    f_model = ovl.LatencyModel(a=fscal[6], b=fscal[7], kind=iscal[4])
    g_model = ovl.LatencyModel(a=fscal[8], b=fscal[9], kind=iscal[5])
    ebl_raw_mean = fscal[10]

    wsz = ws_ref[...][:, None]                # (P, 1) window sizes
    final = fin_ref[...][:, None]             # (P, 1)
    usesb = ub_ref[...] > 0                   # (P,)
    kindv, smode = kind_ref[...], sm_ref[...]
    scount, proc = sc_ref[...], pc_ref[...]
    at_open_m = smode == pat.SPAWN_AT_OPEN
    is_seq = (kindv == pat.KIND_SEQ)[:, None]
    pidx = jax.lax.broadcasted_iota(i32, (P, 1), 0)[:, 0]   # (P,)

    # Fused-shed residents: the pSPICE utility table / the host-drawn
    # PM-BL uniforms, loaded once per block like the rest of the state.
    if fused and shedder == SHED_PSPICE:
        ut_tab = utt_ref[...]                 # (P, B, M) f32
        ut_bs = utb_ref[...]                  # (P,)      f32 bin sizes
    if fused and shedder == SHED_PMBL:
        unif = unif_ref[...]                  # (W, P*N)  f32

    def _cmp_hist(u, lo, hi):
        """Comparison-based bucket counter over the SHARED ``bucket_edges``
        (the same math as ``kernels.shed_select.utility_histogram_pallas``,
        inlined: no nested pallas_call).  Only used when compiling for the
        MXU — interpret mode keeps ``threshold_drop_mask``'s own jnp
        scatter-add histogram, the literal host-path function.  NaN
        (masked-out) entries fail both comparisons and count nowhere;
        masked-in entries lie in [lo, hi] so both bucketings agree."""
        edges = shd.bucket_edges(lo, hi, SHED_NBINS)
        inb = (u[:, None] >= edges[None, :-1]) & (u[:, None] < edges[None, 1:])
        return jnp.sum(inb, axis=0, dtype=i32)

    def row_i32(ref, j):
        return pl.load(ref, (pl.dslice(j, 1), slice(None)))[0]

    def body(st):
        j, carry = st
        (active, state, open_idx, bind, idset, ring, ring_ptr, n_act,
         sim, ema, prev, eblf, cplx, crtd, ovf, ebld, pshed, scalls,
         lat_n, lat_l, lat_ptr, obs_c, obs_r, nfire, fire_idx) = carry
        i = i0 + j
        ec = row_i32(evc_ref, j)                            # (P,)
        eb = row_i32(evb_ref, j)
        eo = row_i32(evo_ref, j) > 0
        eid = pl.load(evid_ref, (pl.dslice(j, 1),))[0]
        er = pl.load(evr_ref, (pl.dslice(j, 1),))[0]
        eraw = pl.load(eraw_ref, (pl.dslice(j, 1),))[0]
        arr = pl.load(arr_ref, (pl.dslice(j, 1),))[0]
        pred = (j >= s) & (j < n_valid)
        if not fused:
            pred = pred & (nfire == 0)        # replay: stop at first fire

        # -- 1-2. tentative pre-shed: expiry, queueing, Algorithm 1 -------
        expired_t = active & ((i - open_idx) >= wsz)
        n_exp = jnp.sum(expired_t, axis=1, dtype=i32)
        n_act1 = n_act - n_exp
        sim1 = jnp.maximum(sim, arr)
        l_q = sim1 - arr
        n_pm_i = n_act1.sum()
        n_pm_f = n_pm_i.astype(f32)

        fire_j = jnp.bool_(False)
        if shedder in (SHED_PSPICE, SHED_PMBL):
            dec = ovl.detect_overload(f_model, g_model, l_q, n_pm_i,
                                      spec["latency_bound"],
                                      spec["safety_buffer"], lazy=True)
            fire_j = pred & dec.shed & (dec.rho > 0)
        # Fused: the fire event is handled in-kernel and commits like any
        # other.  Replay: the fire event is NOT committed — the driver
        # replays it through the host ``_step``.
        commit = pred if fused else pred & ~fire_j
        nfire2 = nfire + fire_j.astype(i32)
        fire_idx2 = jnp.where(fire_j, j, fire_idx)

        # -- committed pre-shed state ------------------------------------
        active1 = active & ~(expired_t & commit)
        n_act1 = jnp.where(commit, n_act1, n_act)
        if spawn_modes != "at_open":
            opens = eo & (smode == pat.SPAWN_IN_WINDOWS) & commit
            ring = jnp.where(
                opens[:, None] &
                (jax.lax.broadcasted_iota(i32, (P, K), 1)
                 == ring_ptr[:, None]), i, ring)
            ring_ptr = jnp.where(opens, (ring_ptr + 1) % K, ring_ptr)
        sim1 = jnp.where(commit, sim1, sim)

        # -- 2b. in-kernel Algorithm 2 (fused shed path) ------------------
        if fused:
            def run_shed(_):
                # Mirrors engine._shed_now on the committed (post-expiry)
                # store: utility column → threshold_drop_mask.  Everything
                # here reads loop-carried VALUES (no refs), so the cond
                # stays a plain jaxpr branch.
                if shedder == SHED_PSPICE:
                    # core.utility.multi_pattern_lookup, (P, N)-shaped:
                    # identical arithmetic, so active slots match the xla
                    # path bit for bit (inactive slots are masked anyway).
                    B = spec["B"]
                    r_w = wsz - (i - open_idx)               # (P, N) i32
                    pos = jnp.clip(r_w.astype(f32) / ut_bs[:, None] - 1.0,
                                   0.0, B - 1.0)
                    b0 = jnp.floor(pos).astype(i32)
                    b1 = jnp.minimum(b0 + 1, B - 1)
                    frac = pos - b0.astype(f32)
                    if spec["mxu"]:
                        # One-hot state/bin extraction (exactly one nonzero
                        # per reduction ⇒ exact), like the advance lookup.
                        oh_s = (state[:, :, None] == jax.lax.broadcasted_iota(
                            i32, (P, N, M), 2)).astype(f32)
                        per_bin = (ut_tab[:, None, :, :] *
                                   oh_s[:, :, None, :]).sum(-1)  # (P, N, B)
                        biota = jax.lax.broadcasted_iota(i32, (P, N, B), 2)
                        u0 = (per_bin *
                              (b0[..., None] == biota).astype(f32)).sum(-1)
                        u1 = (per_bin *
                              (b1[..., None] == biota).astype(f32)).sum(-1)
                    else:
                        tflat = ut_tab.reshape(-1)
                        u0 = jnp.take(tflat, ((pidx[:, None] * B + b0) * M +
                                              state).reshape(-1),
                                      mode="clip").reshape(P, N)
                        u1 = jnp.take(tflat, ((pidx[:, None] * B + b1) * M +
                                              state).reshape(-1),
                                      mode="clip").reshape(P, N)
                    u = (u0 * (1.0 - frac) + u1 * frac).reshape(-1)
                else:
                    # PM-BL: row ``nfire`` of the host-precomputed uniforms
                    # — exactly the draw the host path makes from the
                    # (nfire+1)-th key split of this block's carry key.
                    u = jax.lax.dynamic_index_in_dim(
                        unif, jnp.minimum(nfire, W - 1), 0, keepdims=False)
                hist = _cmp_hist if spec["mxu"] else None
                return shd.threshold_drop_mask(
                    active1.reshape(-1), u, dec.rho, nbins=SHED_NBINS,
                    hist_fn=hist).reshape(P, N)

            active1 = jax.lax.cond(fire_j, run_shed,
                                   lambda _: active1, 0)
            n_act1 = jnp.where(fire_j,
                               jnp.sum(active1, axis=1, dtype=i32), n_act1)
            pshed = pshed + jnp.where(
                fire_j,
                (n_pm_i - jnp.sum(active1, dtype=i32)).astype(f32), 0.0)
            scalls = scalls + jnp.where(fire_j, 1.0, 0.0)
            sim1 = sim1 + jnp.where(
                fire_j,
                spec["c_shed_base"] + spec["c_shed_pm"] * n_pm_f, 0.0)

        # -- 3. E-BL drop + inter-arrival EMA ----------------------------
        gap = jnp.maximum(arr - prev, 1e-9)
        ema1 = 0.99 * ema + 0.01 * gap
        ema = jnp.where(commit, ema1, ema)
        prev = jnp.where(commit, arr, prev)
        dropped_e = jnp.bool_(False)
        did_shed_row = fire_j
        if shedder == SHED_EBL:
            dec_e = ovl.detect_overload(f_model, g_model, l_q, n_pm_i,
                                        spec["latency_bound"],
                                        spec["safety_buffer"], lazy=True)
            l_p_est = ovl.predict_latency(f_model, n_pm_f)
            d_ff = (l_p_est - ema1) / jnp.maximum(
                l_p_est - spec["c_ebl"], 1e-9)
            d_bk = spec["ebl_backlog_gain"] * l_q / spec["latency_bound"]
            d_need = jnp.clip(d_ff + d_bk, 0.0, 1.0)
            eblf1 = jnp.where(dec_e.shed,
                              jnp.maximum(eblf * spec["ebl_decay"], d_need),
                              eblf * spec["ebl_decay"])
            raw_eff = spec["ebl_floor"] + (1.0 - spec["ebl_floor"]) * eraw
            mean_eff = (spec["ebl_floor"] +
                        (1.0 - spec["ebl_floor"]) * ebl_raw_mean)
            p_drop = jnp.clip(raw_eff * eblf1 /
                              jnp.maximum(mean_eff, 1e-9), 0.0, 1.0)
            dropped_e = er < p_drop
            eblf = jnp.where(commit, eblf1, eblf)
            ebld = ebld + jnp.where(commit & dropped_e, 1.0, 0.0)
            did_shed_row = dec_e.shed
        lc = jnp.where(dropped_e, 0, ec)                    # live class
        lo = eo & ~dropped_e

        # -- 4. advance + completions ------------------------------------
        bind_ok = jnp.where(usesb[:, None], bind == eb[:, None], True)
        if kinds != "any":
            tcol = pl.load(
                tcols_ref,
                (pl.dslice(j, 1), slice(None), slice(None)))[0]  # (P, M)
            if spec["mxu"]:
                # TPU: data-dependent lookup as a one-hot MXU matmul
                # (exactly one nonzero per row ⇒ exact integers).
                oh = (state[:, :, None] == jax.lax.broadcasted_iota(
                    i32, (P, N, M), 2)).astype(f32)
                looked = jnp.round(
                    (oh * tcol[:, None, :]).sum(axis=-1)).astype(i32)
            else:
                # Interpret mode lowers to XLA anyway — a plain gather
                # is the same exact lookup without the (P, N, M) one-hot.
                looked = jnp.take_along_axis(
                    tcol.astype(i32), state, axis=1)
            seq_next = jnp.where(bind_ok & ~dropped_e, looked, state)
        if kinds != "seq":
            in_set = (idset == eid).any(axis=-1)
            any_match = (bind_ok & (lc[:, None] == 1) & ~in_set &
                         (state < final))
            any_next = state + any_match.astype(i32)
            slot_ins = jnp.clip(state - 1 + scount[:, None], 0, A - 1)
            do_ins = (~is_seq) & active1 & any_match & commit
            oh_ins = ((slot_ins[:, :, None] ==
                       jax.lax.broadcasted_iota(i32, (P, N, A), 2)) &
                      do_ins[..., None])
            idset = jnp.where(oh_ins, eid, idset)
        if kinds == "seq":
            nxt_state = seq_next
        elif kinds == "any":
            nxt_state = any_next
        else:
            nxt_state = jnp.where(is_seq, seq_next, any_next)
        new_state = jnp.where(active1 & commit, nxt_state, state)
        completed = (active1 & (nxt_state == final) & (state != final) &
                     commit)
        ncomp = jnp.sum(completed, axis=1, dtype=i32)
        active2 = active1 & ~completed
        n_act2 = n_act1 - ncomp
        cplx = cplx + ncomp.astype(f32)
        if emit:
            pl.store(omo_ref, (pl.dslice(j, 1), slice(None), slice(None)),
                     jnp.where(completed, open_idx, -1)[None])
            pl.store(omb_ref, (pl.dslice(j, 1), slice(None), slice(None)),
                     jnp.where(completed, bind, -1)[None])

        # -- 6. observations (model-building phase only) ------------------
        if stats:
            w = (active1 & commit).astype(f32)
            t_obs = (spec["c_match"] * proc)[:, None] * w
            flat_obs = ((pidx[:, None] * M + state) * M +
                        new_state).reshape(-1)
            obs_c = obs_c.reshape(-1).at[flat_obs].add(
                w.reshape(-1)).reshape(P, M, M)
            obs_r = obs_r.reshape(-1).at[flat_obs].add(
                t_obs.reshape(-1)).reshape(P, M, M)

        # -- 5. spawn ----------------------------------------------------
        n_free = N - n_act2                                  # (P,)
        if spawn_modes == "at_open":
            # Census: every pattern spawns AT_OPEN — one candidate, and
            # the engine's rank-0 free-list pick IS the first free slot.
            cand1 = lo & commit
            can1 = cand1 & (n_free > 0)
            ovf = ovf + jnp.sum(cand1 & ~can1, dtype=i32).astype(f32)
            slot1 = jnp.argmax(~active2, axis=1).astype(i32)
            flat = jnp.where(can1, pidx * N + slot1, P * N)
            spawn_open = jnp.broadcast_to(i, (P,)).astype(i32)
            spawn_bind = eb
            spawned = can1.astype(i32)
            fresh = None
        else:
            ring_valid = ring >= 0
            in_window = (i - ring) < wsz
            exists = ((active2[:, None, :]) &
                      (open_idx[:, None, :] == ring[:, :, None]) &
                      (bind[:, None, :] == eb[:, None, None])).any(-1)
            win_spawn = (ring_valid & in_window & ~exists &
                         (lc == 1)[:, None] & (~at_open_m)[:, None])
            kiota = jax.lax.broadcasted_iota(i32, (1, K), 1)
            open_spawn = (at_open_m & lo)[:, None] & (kiota == 0)
            if spawn_modes == "in_windows":
                cand = win_spawn & commit
                cand_open = ring
            else:
                cand = (win_spawn | open_spawn) & commit
                cand_open = jnp.where(at_open_m[:, None], i, ring)
            rank = jnp.cumsum(cand, axis=1) - 1              # (P, K)
            can = cand & (rank < n_free[:, None])
            ovf = ovf + jnp.sum(cand & ~can, dtype=i32).astype(f32)
            # Candidate k takes the (rank[k]+1)-th lowest inactive slot
            # == first index where the running free count reaches
            # rank[k]+1 — same pick as the engine's masked-cumsum
            # scatter, without the N-sized scatter.
            frank = jnp.cumsum(~active2, axis=1)             # (P, N)
            hits = frank[:, None, :] == (rank[:, :, None] + 1)
            slots = jnp.argmax(hits, axis=-1).astype(i32)    # (P, K)
            flat = jnp.where(can, pidx[:, None] * N + slots,
                             P * N).reshape(-1)
            spawn_open = cand_open.reshape(-1)
            spawn_bind = jnp.broadcast_to(eb[:, None], (P, K)).reshape(-1)
            spawned = jnp.sum(can, axis=1, dtype=i32)
            if kinds != "seq":
                row0 = jnp.where(scount[:, None] > 0, eid, -1)  # (P, 1)
                fresh1 = jnp.concatenate(
                    [row0, jnp.full((P, A - 1), -1, i32)], axis=1)
                fresh = jnp.broadcast_to(
                    fresh1[:, None, :], (P, K, A)).reshape(-1, A)
            else:
                fresh = None
        if spawn_modes == "at_open" and kinds != "seq":
            fresh = jnp.where(scount[:, None] > 0,
                              jnp.full((P, 1), eid, i32), -1)
            fresh = jnp.concatenate(
                [fresh, jnp.full((P, A - 1), -1, i32)], axis=1)
        active3 = active2.reshape(-1).at[flat].set(
            True, mode="drop").reshape(P, N)
        state3 = new_state.reshape(-1).at[flat].set(
            1, mode="drop").reshape(P, N)
        open3 = open_idx.reshape(-1).at[flat].set(
            spawn_open, mode="drop").reshape(P, N)
        bind3 = bind.reshape(-1).at[flat].set(
            spawn_bind, mode="drop").reshape(P, N)
        if kinds != "seq":
            idset = idset.reshape(P * N, A).at[flat].set(
                fresh, mode="drop").reshape(P, N, A)
        crtd = crtd + spawned.astype(f32)
        n_act3 = n_act2 + spawned

        # -- 7. simulated processing time & latency ----------------------
        n_active_p = n_act1.astype(f32)
        t_proc = spec["c_base"] + (spec["c_match"] * proc *
                                   n_active_p).sum()
        t_proc = jnp.where(dropped_e, spec["c_ebl"], t_proc)
        sim2 = sim1 + t_proc
        l_e = sim2 - arr
        sim = jnp.where(commit, sim2, sim)
        ptr = lat_ptr % S
        lat_n = lat_n.at[ptr].set(jnp.where(commit, n_pm_f, lat_n[ptr]))
        lat_l = lat_l.at[ptr].set(jnp.where(commit, t_proc, lat_l[ptr]))
        lat_ptr = lat_ptr + jnp.where(commit, 1, 0).astype(i32)

        pl.store(ole_ref, (pl.dslice(j, 1),), l_e[None])
        pl.store(onpm_ref, (pl.dslice(j, 1),),
                 n_act3.sum().astype(f32)[None])
        pl.store(oshed_ref, (pl.dslice(j, 1),),
                 did_shed_row.astype(i32)[None])
        pl.store(odrop_ref, (pl.dslice(j, 1),),
                 dropped_e.astype(i32)[None])
        return j + 1, (active3, state3, open3, bind3, idset, ring,
                       ring_ptr, n_act3, sim, ema, prev, eblf, cplx,
                       crtd, ovf, ebld, pshed, scalls, lat_n, lat_l,
                       lat_ptr, obs_c, obs_r, nfire2, fire_idx2)

    active0 = act_ref[...] != 0
    obs0 = (obsc_ref[...], obsr_ref[...]) if stats else (
        jnp.zeros((), f32), jnp.zeros((), f32))
    carry0 = (active0, st_ref[...], oi_ref[...], bd_ref[...], ids_ref[...],
              ring_ref[...], rp_ref[...],
              jnp.sum(active0, axis=1, dtype=jnp.int32),
              fscal[0], fscal[2], fscal[3], fscal[1],
              cplx_ref[...], crtd_ref[...], fscal[4], fscal[5],
              fscal[11], fscal[12],
              latn_ref[...], latl_ref[...], lat_ptr0,
              obs0[0], obs0[1], jnp.int32(0), jnp.int32(W))
    # Event loop over [s, n_valid).  Fused mode runs the whole span in
    # one pass (fires are handled inline, ``nfire`` just counts them for
    # the wrapper's key-chain advance).  Replay mode early-exits at the
    # first Algorithm-1 fire — a block with F fires costs O(committed
    # events) total across its F+1 launches, not F+1 full W-iteration
    # replays; rows outside the committed range stay unwritten and the
    # driver only reads [s, stop).
    if spec["fused"]:
        loop_cond = lambda st: st[0] < n_valid               # noqa: E731
    else:
        loop_cond = lambda st: ((st[0] < n_valid) &          # noqa: E731
                                (st[1][23] == 0))
    out = jax.lax.while_loop(loop_cond, body, (s, carry0))[1]
    (active, state, open_idx, bind, idset, ring, ring_ptr, _n_act, sim,
     ema, prev, eblf, cplx, crtd, ovf, ebld, pshed, scalls, lat_n, lat_l,
     lat_ptr, obs_c, obs_r, nfire, fire_idx) = out
    oact_ref[...] = active.astype(jnp.int32)
    ost_ref[...] = state
    ooi_ref[...] = open_idx
    obd_ref[...] = bind
    oids_ref[...] = idset
    oring_ref[...] = ring
    orp_ref[...] = ring_ptr
    ocplx_ref[...] = cplx
    ocrtd_ref[...] = crtd
    olatn_ref[...] = lat_n
    olatl_ref[...] = lat_l
    ofscal_ref[...] = jnp.stack([sim, eblf, ema, prev, ovf, ebld,
                                 pshed, scalls])
    oiscal_ref[...] = jnp.stack([nfire, fire_idx, lat_ptr])
    if stats:
        oobsc_ref[...] = obs_c
        oobsr_ref[...] = obs_r


def block_step(cfg, model, carry, blk, i0, s, n_valid, *,
               interpret: bool = True):
    """Run the fused block step: ``W = cfg.block_events`` events against
    the resident carry, starting at in-block offset ``s`` (events before
    ``s`` were committed by a previous entry — the block-split protocol),
    masking events at ``>= n_valid`` (ragged tail blocks).

    ``cfg`` / ``model`` / ``carry`` / ``blk`` are the engine's
    ``EngineConfig`` / ``EngineModel`` / ``Carry`` / block-shaped
    ``EventBatch`` (duck-typed; this module never imports the engine).
    Returns ``(carry', rows, fired, fire_idx)`` where ``rows`` is a dict
    of per-event StepOut columns.

    Under the FUSED shed plan (``fused_shed(cfg)``) Algorithm-2 fires
    are handled in-kernel: ``fired`` is always False, rows are valid on
    all of ``[s, n_valid)``, and ``carry'`` — including ``key`` (advanced
    down the precomputed split chain once per fire), ``pms_shed`` and
    ``shed_calls`` — has every valid event committed.  Under the legacy
    replay plan rows are valid on ``[s, stop)`` with ``stop = fire_idx
    if fired else n_valid`` and the fired event is left to the driver.
    Either way every committed event is bit-identical to the xla step.
    """
    P, N, M = cfg.num_patterns, cfg.max_pms, cfg.max_states
    A, K, W = cfg.max_any_ids, cfg.ring_size, cfg.block_events
    S = carry.lat_samples_n.shape[0]
    i32, f32 = jnp.int32, jnp.float32
    fused = fused_shed(cfg)
    spec = dict(P=P, N=N, M=M, A=A, K=K, S=S, W=W, mxu=not interpret,
                B=model.ut_tables.shape[1], fused=fused,
                kinds=cfg.kinds, spawn_modes=cfg.spawn_modes,
                shedder=cfg.shedder, emit=cfg.emit_matches,
                stats=cfg.gather_stats,
                c_base=cfg.c_base, c_match=cfg.c_match, c_ebl=cfg.c_ebl,
                c_shed_base=cfg.c_shed_base, c_shed_pm=cfg.c_shed_pm,
                latency_bound=cfg.latency_bound,
                safety_buffer=cfg.safety_buffer,
                ebl_backlog_gain=cfg.ebl_backlog_gain,
                ebl_decay=cfg.ebl_decay, ebl_floor=cfg.ebl_floor)

    # Per-event SEQ transition columns, gathered OUTSIDE the kernel
    # (tiny: (W, P, M)); class 0 self-loops cover bind-fail / E-BL drop.
    tt = jnp.transpose(model.trans, (0, 2, 1))               # (P, C+1, M)
    tcols = tt[jnp.arange(P, dtype=i32)[None, :],
               blk.ev_class].astype(f32)                     # (W, P, M)
    pms = carry.pms
    iscal = jnp.stack([jnp.asarray(s, i32), jnp.asarray(n_valid, i32),
                       jnp.asarray(i0, i32), carry.lat_ptr,
                       model.f_model.kind, model.g_model.kind])
    fscal = jnp.stack([carry.sim_time, carry.ebl_frac, carry.ema_gap,
                       carry.prev_arrival, carry.overflow,
                       carry.ebl_dropped, model.f_model.a, model.f_model.b,
                       model.g_model.a, model.g_model.b,
                       model.ebl_raw_mean, carry.pms_shed,
                       carry.shed_calls])

    # PRNG discipline under fusion: the host path splits the carry key
    # once per fire (``key, sub = split(key)``; only PM-BL consumes
    # ``sub``).  Precompute the whole chain for the worst case of W fires
    # — the kernel merely COUNTS fires and the wrapper advances the carry
    # key to ``chain[n_fires]``, so F in-kernel fires leave exactly the
    # key F host fires would have.  Unused tail splits are pure compute.
    if fused:
        def _split(k, _):
            nk, sub = jax.random.split(k)
            return nk, (nk, sub)
        _, (chain_keys, chain_subs) = jax.lax.scan(
            _split, carry.key, None, length=W)
        key_chain = jnp.concatenate([carry.key[None], chain_keys], axis=0)
    # Named operand assembly: the kernel unpacks refs positionally in
    # this exact order (the ``nxt()`` sequence in ``_block_kernel``);
    # the in-place alias map is derived BY NAME below, so adding an
    # operand cannot silently shift an alias pair.
    inputs = [("tcols", tcols), ("ev_class", blk.ev_class),
              ("ev_bind", blk.ev_bind),
              ("ev_open", blk.ev_open.astype(i32)),
              ("ev_id", blk.ev_id), ("ev_rand", blk.ev_rand),
              ("ebl_raw", blk.ebl_raw), ("arrival", blk.arrival),
              ("iscal", iscal), ("fscal", fscal),
              ("active", pms.active.astype(i32)), ("state", pms.state),
              ("open_idx", pms.open_idx), ("bind", pms.bind),
              ("idset", pms.idset), ("ring", carry.ring),
              ("ring_ptr", carry.ring_ptr),
              ("window_size", model.window_size),
              ("final_state", model.final_state),
              ("uses_binding", model.uses_binding.astype(i32)),
              ("kind", model.kind), ("spawn_mode", model.spawn_mode),
              ("spawn_counts", model.spawn_counts.astype(i32)),
              ("proc_cost", model.proc_cost),
              ("complex_count", carry.complex_count),
              ("pms_created", carry.pms_created),
              ("lat_n", carry.lat_samples_n),
              ("lat_l", carry.lat_samples_l)]
    if fused and cfg.shedder == SHED_PSPICE:
        inputs += [("ut_tables", model.ut_tables.astype(f32)),
                   ("ut_bins_f", model.ut_bins.astype(f32))]
    if fused and cfg.shedder == SHED_PMBL:
        # One iid-uniform score row per potential fire, drawn from the
        # chain's subs — bitwise the ``random_drop`` draw the host path
        # makes for the same fire ordinal.
        unif = jax.vmap(
            lambda kk: jax.random.uniform(kk, (P * N,)))(chain_subs)
        inputs += [("shed_uniforms", unif)]
    if cfg.gather_stats:
        inputs += [("obs_counts", carry.obs_counts),
                   ("obs_rewards", carry.obs_rewards)]

    sds = jax.ShapeDtypeStruct
    outputs = [("active", sds((P, N), i32)), ("state", sds((P, N), i32)),
               ("open_idx", sds((P, N), i32)), ("bind", sds((P, N), i32)),
               ("idset", sds((P, N, A), i32)), ("ring", sds((P, K), i32)),
               ("ring_ptr", sds((P,), i32)),
               ("complex_count", sds((P,), f32)),
               ("pms_created", sds((P,), f32)),
               ("lat_n", sds((S,), f32)), ("lat_l", sds((S,), f32)),
               ("fscal_out", sds((8,), f32)),
               ("iscal_out", sds((3,), i32)),
               ("l_e", sds((W,), f32)), ("n_pm", sds((W,), f32)),
               ("shed", sds((W,), i32)), ("dropped", sds((W,), i32))]
    if cfg.emit_matches:
        outputs += [("m_open", sds((W, P, N), i32)),
                    ("m_bind", sds((W, P, N), i32))]
    if cfg.gather_stats:
        outputs += [("obs_counts", sds((P, M, M), f32)),
                    ("obs_rewards", sds((P, M, M), f32))]
    in_idx = {name: k for k, (name, _) in enumerate(inputs)}
    out_idx = {name: k for k, (name, _) in enumerate(outputs)}
    aliases = {in_idx[name]: out_idx[name] for name in out_idx
               if name in in_idx}

    out = pl.pallas_call(
        functools.partial(_block_kernel, spec=spec),
        out_shape=[shape for _, shape in outputs],
        input_output_aliases=aliases,
        interpret=interpret,
    )(*[arr for _, arr in inputs])

    (active, state, open_idx, bind, idset, ring, ring_ptr, cplx, crtd,
     lat_n, lat_l, fscal_o, iscal_o, l_e, n_pm, shed, dropped) = out[:17]
    k = 17
    if cfg.emit_matches:
        m_open, m_bind = out[k], out[k + 1]
        k += 2
    else:
        m_open = jnp.zeros((W, P, 0), i32)
        m_bind = jnp.zeros((W, P, 0), i32)
    obs_c, obs_r = ((out[k], out[k + 1]) if cfg.gather_stats
                    else (carry.obs_counts, carry.obs_rewards))

    carry2 = carry._replace(
        pms=pms._replace(active=active != 0, state=state,
                         open_idx=open_idx, bind=bind, idset=idset),
        ring=ring, ring_ptr=ring_ptr,
        sim_time=fscal_o[0], ebl_frac=fscal_o[1], ema_gap=fscal_o[2],
        prev_arrival=fscal_o[3], overflow=fscal_o[4],
        ebl_dropped=fscal_o[5],
        pms_shed=fscal_o[6], shed_calls=fscal_o[7],
        complex_count=cplx, pms_created=crtd,
        obs_counts=obs_c, obs_rewards=obs_r,
        lat_samples_n=lat_n, lat_samples_l=lat_l, lat_ptr=iscal_o[2])
    rows = dict(l_e=l_e, n_pm=n_pm, shed=shed != 0, dropped=dropped != 0,
                match_open=m_open, match_bind=m_bind)
    if fused:
        # iscal_o[0] counts in-kernel fires: advance the key down the
        # precomputed chain and report "nothing left to replay".
        carry2 = carry2._replace(key=jax.lax.dynamic_index_in_dim(
            key_chain, iscal_o[0], axis=0, keepdims=False))
        return carry2, rows, jnp.bool_(False), iscal_o[1]
    return carry2, rows, iscal_o[0] != 0, iscal_o[1]
