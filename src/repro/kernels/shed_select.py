"""Pallas TPU kernels for the load shedder (paper Algorithm 2).

Two kernels replace the sort in Alg. 2 with a histogram-threshold plan
(O(N) instead of O(N log N), and VMEM-tiled):

  1. ``utility_lookup``: fused UT-table lookup with linear interpolation —
     again expressed as one-hot matmuls against the (bins × states) utility
     table resident in VMEM (O(1) per PM, the property the paper highlights).
  2. ``utility_histogram``: per-tile bucket counts accumulated across the
     grid — the driver (ops.shed_lowest_pallas) runs a cumsum over the tiny
     histogram to pick the drop threshold τ such that ~ρ PMs fall below it,
     then a final compare produces the drop mask (exact-ρ tie-break happens
     on the ≤1-bucket remainder).

These are the STANDALONE kernels the per-event ``backend="pallas"``
path dispatches through ``ops.shed_lowest_threshold``.  The block
megakernel (kernels/block_step.py) does not call them: its fused fire
path runs the SAME driver (``shedder.threshold_drop_mask``) and the
same ``bucket_edges`` inside the block kernel, with the lookup/
histogram re-expressed over the store-resident columns — one shared
bucketing expression is what keeps every backend's drop mask bitwise
identical.

TARGET: TPU.  VALIDATED: interpret=True vs core.shedder oracle (tests/).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.shedder import bucket_edges
from repro.kernels.tiling import pad_to_tile


def _lookup_kernel(state_ref, rw_ref, active_ref, table_ref, bs_ref,
                   out_ref, *, num_bins: int, m: int, inf_val: float):
    state = state_ref[...]
    rw = rw_ref[...].astype(jnp.float32)
    active = active_ref[...] > 0
    table = table_ref[...]                    # (num_bins, M)
    bin_size = bs_ref[0]                      # traced f32 scalar

    pos = jnp.clip(rw / bin_size - 1.0, 0.0, num_bins - 1.0)
    j0 = jnp.floor(pos).astype(jnp.int32)
    j1 = jnp.minimum(j0 + 1, num_bins - 1)
    frac = pos - j0.astype(jnp.float32)

    tile = state.shape[0]
    oh_state = (state[:, None] ==
                jax.lax.broadcasted_iota(jnp.int32, (tile, m), 1)
                ).astype(jnp.float32)         # (tile, M)
    per_bin = oh_state @ table.T              # (tile, num_bins)
    oh0 = (j0[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (tile, num_bins), 1)
           ).astype(jnp.float32)
    oh1 = (j1[:, None] ==
           jax.lax.broadcasted_iota(jnp.int32, (tile, num_bins), 1)
           ).astype(jnp.float32)
    u0 = (per_bin * oh0).sum(axis=1)
    u1 = (per_bin * oh1).sum(axis=1)
    u = u0 * (1.0 - frac) + u1 * frac
    out_ref[...] = jnp.where(active, u, inf_val)


def utility_lookup_dyn_pallas(state, r_w, active, table, bin_size, *,
                              tile: int = 256, interpret: bool = True,
                              inf_val: float = 3.4e38):
    """``utility_lookup_pallas`` with a TRACED bin size (f32 scalar array)
    — the engine's multi-pattern dispatch passes ``model.ut_bins[p]``, a
    device value, so the bin size rides into the kernel as a (1,) scalar
    input instead of a static Python int.
    """
    N = state.shape[0]
    num_bins, m = table.shape
    tile = min(tile, N)
    state, r_w, active, pad = pad_to_tile(
        tile, (state, 0), (r_w, 1), (active, 0))
    bs = jnp.asarray(bin_size, jnp.float32).reshape(1)
    out = pl.pallas_call(
        functools.partial(_lookup_kernel, num_bins=num_bins, m=m,
                          inf_val=inf_val),
        grid=((N + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((num_bins, m), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((N + pad,), jnp.float32),
        interpret=interpret,
    )(state, r_w, active.astype(jnp.int32), table, bs)
    return out[:N] if pad else out


@functools.partial(jax.jit, static_argnames=("bin_size", "tile",
                                             "interpret"))
def utility_lookup_pallas(state, r_w, active, table, *, bin_size: int,
                          tile: int = 256, interpret: bool = True,
                          inf_val: float = 3.4e38):
    """Fused O(1)-per-PM utility lookup. table: (num_bins, M) f32.

    N need not be a tile multiple: inputs are padded with inactive slots
    (which lower to inf_val in the kernel) and the output is sliced back.
    """
    return utility_lookup_dyn_pallas(state, r_w, active, table,
                                     jnp.float32(bin_size), tile=tile,
                                     interpret=interpret, inf_val=inf_val)


def _hist_kernel(u_ref, edges_ref, hist_ref, *, nbins: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)

    u = u_ref[...]                            # (tile,)
    edges = edges_ref[...]                    # (nbins+1,)
    lo = edges[:-1]
    hi = edges[1:]
    counts = ((u[:, None] >= lo[None, :]) &
              (u[:, None] < hi[None, :])).astype(jnp.int32).sum(axis=0)
    hist_ref[...] += counts


@functools.partial(jax.jit, static_argnames=("nbins", "tile", "interpret"))
def utility_histogram_pallas(u, lo, hi, *, nbins: int = 64, tile: int = 256,
                             interpret: bool = True):
    """Bucket counts of u within [lo, hi) — the threshold-plan input.

    N need not be a tile multiple: the tail pads with NaN, which fails
    both bucket comparisons and is therefore never counted.
    """
    N = u.shape[0]
    tile = min(tile, N)
    u, pad = pad_to_tile(tile, (u, jnp.nan))
    # Shared edge expression (core.shedder.bucket_edges): boundary values
    # bucket identically on the jnp and Pallas histogram paths.
    edges = bucket_edges(lo, hi, nbins)
    return pl.pallas_call(
        functools.partial(_hist_kernel, nbins=nbins),
        grid=((N + pad) // tile,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                  pl.BlockSpec((nbins + 1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((nbins,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((nbins,), jnp.int32),
        interpret=interpret,
    )(u, edges)
