"""Pallas TPU flash attention (online softmax, VMEM-resident accumulators).

The pure-jnp flash in repro/models/layers.py spills its (cq × ck) f32 score
blocks to HBM — the roofline baselines show that traffic DOMINATING the
memory term of the prefill/train cells.  This kernel keeps scores, the
running max/denominator, and the output accumulator in VMEM scratch across
the kv-block loop; HBM sees only Q/K/V reads and one O write.

Grid: (B·H, nq, nk) — the kv axis is the innermost (sequential) dimension so
the scratch carries across j.  Causal blocks above the diagonal are skipped
via pl.when (no MXU work issued).

TARGET: TPU (MXU-aligned cq/ck multiples of 128, f32 scratch).
VALIDATED: interpret=True on CPU against ref.attention_ref (tests/).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, cq: int, ck: int, nk: int):
    i = pl.program_id(1)
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    def _block():
        q = q_ref[0].astype(jnp.float32)              # (cq, d)
        k = k_ref[0, 0].astype(jnp.float32)           # (ck, d)
        v = v_ref[0, 0].astype(jnp.float32)           # (ck, dv)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale   # (cq, ck)
        if causal:
            qpos = i * cq + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 0)
            kpos = j * ck + jax.lax.broadcasted_iota(jnp.int32, (cq, ck), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]                           # (cq, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * corr + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    if causal:
        pl.when((i + 1) * cq - 1 >= j * ck)(_block)
    else:
        _block()

    @pl.when(j == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "cq", "ck",
                                             "interpret"))
def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, cq: int = 128, ck: int = 128,
                           interpret: bool = True) -> jax.Array:
    """q: (B, Sq, H, D); k/v: (B, Sk, KVH, D).  GQA via KVH | H.

    interpret=True executes the kernel body in Python on CPU (the validation
    mode in this container); on TPU pass interpret=False.
    """
    B, Sq, H, D = q.shape
    _, Sk, KVH, Dv = v.shape
    G = H // KVH
    scale = 1.0 / np.sqrt(D)
    cq = min(cq, Sq)
    ck = min(ck, Sk)
    assert Sq % cq == 0 and Sk % ck == 0
    nq, nk = Sq // cq, Sk // ck

    # Layout: (B·H, S, D) with KV heads group-expanded via the index map
    # (no materialized repeat).
    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kh = k.transpose(0, 2, 1, 3)                      # (B, KVH, Sk, D)
    vh = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               cq=cq, ck=ck, nk=nk)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, cq, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, 1, ck, D),
                         lambda b, i, j, G=G, H=H: (b // H, (b % H) // G,
                                                    j, 0)),
            pl.BlockSpec((1, 1, ck, Dv),
                         lambda b, i, j, G=G, H=H: (b // H, (b % H) // G,
                                                    j, 0)),
        ],
        out_specs=pl.BlockSpec((1, cq, Dv), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((cq, 1), jnp.float32),
            pltpu.VMEM((cq, 1), jnp.float32),
            pltpu.VMEM((cq, Dv), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, Dv).transpose(0, 2, 1, 3)
