"""Pallas TPU kernel for the CEP operator's hot loop: advancing every
active partial match against one incoming event (paper §III / engine step 4).

TPU adaptation: the per-PM table lookup ``next = trans[state, class]`` is a
data-dependent gather — hostile to the VPU.  We rewrite it as a ONE-HOT
MATMUL: ``next = onehot(state, M) @ trans_col`` where ``trans_col[s] =
trans[s, class]`` is the (tiny, ≤32-entry) column for the incoming event's
class, resident in VMEM.  The one-hot matrix hits the MXU; the whole PM tile
advances in one pass, fused with the binding check and completion detection.

Grid: PM tiles of ``tile`` slots; trans_col/bind/final ride along in VMEM.

TARGET: TPU.  VALIDATED: interpret=True vs ref.nfa_advance_ref (tests/).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.tiling import pad_to_tile


def _nfa_kernel(state_ref, bind_ref, active_ref, tcol_ref, scal_ref,
                newstate_ref, completed_ref, *, m: int):
    state = state_ref[...]                    # (tile,) int32
    bind = bind_ref[...]
    active = active_ref[...]                  # (tile,) int32 (0/1)
    tcol = tcol_ref[...].astype(jnp.float32)  # (M,) next-state per state
    ev_bind = scal_ref[0]
    final = scal_ref[1]
    use_binding = scal_ref[2]

    onehot = (state[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (state.shape[0], m), 1)
              ).astype(jnp.float32)           # (tile, M)
    nxt = jnp.round(onehot @ tcol).astype(jnp.int32)
    bind_ok = jnp.where(use_binding > 0, bind == ev_bind, True)
    live = active > 0
    nxt = jnp.where(live & bind_ok, nxt, state)
    completed = live & (nxt == final) & (state != final)
    newstate_ref[...] = nxt
    completed_ref[...] = completed.astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("tile", "interpret"))
def nfa_advance_pallas(state: jax.Array, bind: jax.Array, active: jax.Array,
                       trans_col: jax.Array, ev_bind, final, use_binding,
                       *, tile: int = 256, interpret: bool = True):
    """Advance all PMs against one event.

    state/bind: (N,) int32; active: (N,) bool; trans_col: (M,) int32 —
    trans[:, class] for the event's class.  Returns (new_state (N,),
    completed (N,) bool).

    N need not be a tile multiple: inputs pad with INACTIVE slots (state 0,
    bind -1, active 0 — the kernel passes them through untouched and never
    flags completion) and the outputs slice back, matching the treatment
    the shed kernels give non-tile-multiple stores."""
    N = state.shape[0]
    m = trans_col.shape[0]
    tile = min(tile, N)
    state, bind, active, pad = pad_to_tile(
        tile, (state, 0), (bind, -1), (active, 0))
    scal = jnp.array([ev_bind, final, use_binding], jnp.int32)
    new_state, completed = pl.pallas_call(
        functools.partial(_nfa_kernel, m=m),
        grid=((N + pad) // tile,),
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((3,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((tile,), lambda i: (i,)),
                   pl.BlockSpec((tile,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((N + pad,), jnp.int32),
                   jax.ShapeDtypeStruct((N + pad,), jnp.int32)],
        interpret=interpret,
    )(state, bind, active.astype(jnp.int32), trans_col, scal)
    if pad:
        new_state, completed = new_state[:N], completed[:N]
    return new_state, completed.astype(bool)
