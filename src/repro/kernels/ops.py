"""Jit'd public wrappers around the Pallas kernels + the engine's
backend-dispatch surface (``EngineConfig.backend`` — DESIGN.md §8).

The engine never touches a kernel directly: it calls
``advance_seq_multi`` / ``pm_utilities_multi`` / ``shed_lowest_threshold``
below, which run the Pallas kernels (compiled on TPU, ``interpret=True``
everywhere else via :func:`default_interpret`) and are bitwise-equivalent
to the jnp reference path — the one-hot matmuls touch exactly one nonzero
per row, and the histogram-threshold driver shares ``bucket_edges`` with
the jnp histogram, so xla-vs-pallas engine runs compare equal
(tests/test_backend.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import shedder as shd
from repro.kernels.block_step import block_step  # noqa: F401
from repro.kernels.flash_attention import flash_attention_pallas  # noqa: F401
from repro.kernels.nfa_transition import nfa_advance_pallas  # noqa: F401
from repro.kernels.shed_select import (utility_histogram_pallas,
                                       utility_lookup_dyn_pallas,
                                       utility_lookup_pallas)
from repro.kernels.tiling import pad_to_tile, tile_pad  # noqa: F401


def default_interpret() -> bool:
    """Pallas kernels compile only on TPU; anywhere else run interpreted."""
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("bin_size", "nbins",
                                             "interpret"))
def shed_lowest_pallas(active: jax.Array, state: jax.Array, r_w: jax.Array,
                       table: jax.Array, rho: jax.Array, *, bin_size: int,
                       nbins: int = 64, interpret: bool = True) -> jax.Array:
    """Algorithm 2 via kernels: utility lookup → histogram-refinement
    threshold plan (``core.shedder.threshold_drop_mask`` with the Pallas
    histogram as its bucket counter).  O(N) end to end — the former
    exact-ρ argsort inside the boundary bucket is gone; remaining ties
    break by slot index after the refinement levels collapse the bucket.

    Returns the new active mask with the ρ lowest-utility PMs cleared.
    """
    u = utility_lookup_pallas(state, r_w, active, table, bin_size=bin_size,
                              interpret=interpret)
    hist = functools.partial(utility_histogram_pallas, nbins=nbins,
                             interpret=interpret)
    return shd.threshold_drop_mask(active, u, rho, nbins=nbins, hist_fn=hist)


def advance_seq_multi(state: jax.Array, bind: jax.Array, active: jax.Array,
                      trans: jax.Array, ev_class: jax.Array,
                      ev_bind: jax.Array, final_state: jax.Array,
                      uses_binding: jax.Array, *,
                      interpret: bool = True) -> jax.Array:
    """SEQ advance for the whole (P, N) PM store via ``nfa_advance_pallas``,
    one kernel launch per pattern (P is small and static).

    ``trans_col = trans[p, :, class_p]`` is gathered outside the kernel
    (tiny: (M,) per pattern); binding check + advance + the one-hot MXU
    matmul run inside.  Returns new_state (P, N) int32 — completions are
    detected by the engine from (old, new) states, same as the jnp path.
    """
    P = state.shape[0]
    out = []
    for p in range(P):
        tcol = jnp.take(trans[p], ev_class[p], axis=1)      # (M,)
        ns, _ = nfa_advance_pallas(state[p], bind[p], active[p], tcol,
                                   ev_bind[p], final_state[p],
                                   uses_binding[p].astype(jnp.int32),
                                   interpret=interpret)
        out.append(ns)
    return jnp.stack(out)


def pm_utilities_multi(state: jax.Array, r_w: jax.Array, active: jax.Array,
                       tables: jax.Array, bin_sizes: jax.Array, *,
                       interpret: bool = True) -> jax.Array:
    """Fused utility lookup for the whole (P, N) store: one
    ``utility_lookup_dyn_pallas`` launch per pattern against its own
    (B, M) table and traced bin size.  Inactive slots get the kernel's
    finite +inf sentinel; the threshold driver masks them anyway.
    """
    P = state.shape[0]
    return jnp.stack([
        utility_lookup_dyn_pallas(state[p], r_w[p], active[p], tables[p],
                                  bin_sizes[p], interpret=interpret)
        for p in range(P)])


def shed_lowest_threshold(active: jax.Array, utilities: jax.Array,
                          rho: jax.Array, *, nbins: int = 128,
                          interpret: bool = True) -> jax.Array:
    """Histogram-threshold drop mask over flat (N,) utilities with the
    Pallas histogram kernel as the bucket counter (engine pallas path)."""
    hist = functools.partial(utility_histogram_pallas, nbins=nbins,
                             interpret=interpret)
    return shd.threshold_drop_mask(active, utilities, rho, nbins=nbins,
                                   hist_fn=hist)
