"""Jit'd public wrappers around the Pallas kernels."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_pallas  # noqa: F401
from repro.kernels.nfa_transition import nfa_advance_pallas  # noqa: F401
from repro.kernels.shed_select import (utility_histogram_pallas,
                                       utility_lookup_pallas)


@functools.partial(jax.jit, static_argnames=("bin_size", "nbins",
                                             "interpret"))
def shed_lowest_pallas(active: jax.Array, state: jax.Array, r_w: jax.Array,
                       table: jax.Array, rho: jax.Array, *, bin_size: int,
                       nbins: int = 64, interpret: bool = True) -> jax.Array:
    """Algorithm 2 via kernels: utility lookup → histogram → threshold →
    drop mask (exact ρ via rank-adjust inside the boundary bucket).

    Returns the new active mask with the ρ lowest-utility PMs cleared.
    """
    u = utility_lookup_pallas(state, r_w, active, table, bin_size=bin_size,
                              interpret=interpret)
    # Threshold plan over active utilities only.
    act = active
    big = jnp.float32(3.4e38)
    u_act = jnp.where(act, u, big)
    lo = jnp.min(jnp.where(act, u, big))
    hi = jnp.max(jnp.where(act, u, -big))
    hi = jnp.where(hi > lo, hi, lo + 1.0)
    hist = utility_histogram_pallas(u_act, lo, hi, nbins=nbins,
                                    interpret=interpret)
    cum = jnp.cumsum(hist)
    # First bucket where cumulative count reaches rho.
    kbucket = jnp.searchsorted(cum, rho, side="left")
    kbucket = jnp.clip(kbucket, 0, nbins - 1)
    edge = lo + (hi - lo) * kbucket.astype(jnp.float32) / nbins
    below = act & (u_act < edge)
    n_below = below.sum()
    # Exact-ρ remainder inside the boundary bucket: rank by utility order.
    # (The last bucket is right-closed — its top edge is the active max.)
    upper = jnp.where(kbucket == nbins - 1, jnp.inf,
                      lo + (hi - lo) * (kbucket + 1).astype(jnp.float32)
                      / nbins)
    in_bucket = act & ~below & (u_act < upper)
    need = jnp.maximum(rho - n_below, 0)
    order = jnp.argsort(jnp.where(in_bucket, u_act, big))
    ranks = jnp.zeros_like(order).at[order].set(jnp.arange(order.shape[0]))
    bucket_drop = in_bucket & (ranks < need)
    return act & ~(below | bucket_drop)
