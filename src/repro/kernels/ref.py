"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import shedder as _shedder
from repro.core import utility as _utility
from repro.models.layers import attention_ref  # noqa: F401  (flash oracle)


def nfa_advance_ref(state, bind, active, trans_col, ev_bind, final,
                    use_binding):
    """Oracle for nfa_advance_pallas: plain gather semantics."""
    nxt = trans_col[state]
    bind_ok = jnp.where(use_binding > 0, bind == ev_bind, True)
    live = active
    nxt = jnp.where(live & bind_ok, nxt, state)
    completed = live & (nxt == final) & (state != final)
    return nxt, completed


def utility_lookup_ref(state, r_w, active, table, bin_size):
    """Oracle for utility_lookup_pallas (core.utility.lookup_utility with
    +inf on inactive slots)."""
    u = _utility.lookup_utility(table, bin_size, state, r_w)
    return jnp.where(active, u, jnp.float32(3.4e38))


def histogram_ref(u, lo, hi, nbins):
    edges = lo + (hi - lo) * jnp.arange(nbins + 1, dtype=jnp.float32) / nbins
    edges = edges.at[-1].set(jnp.inf)
    return ((u[:, None] >= edges[:-1][None]) &
            (u[:, None] < edges[1:][None])).astype(jnp.int32).sum(axis=0)


def shed_lowest_ref(active, state, r_w, table, rho, bin_size):
    """Oracle for shed_lowest_pallas: the sort-based Algorithm 2."""
    u = utility_lookup_ref(state, r_w, active, table, bin_size)
    return _shedder.drop_lowest_utility(active, jnp.where(active, u,
                                                          jnp.inf), rho)
