"""Pallas TPU kernels for the system's compute hot spots.

  flash_attention.py — VMEM-resident online-softmax attention (the
      memory-term bottleneck of every ≥32k attention cell; §Perf).
  nfa_transition.py  — the CEP operator's hot loop (paper §III): per-event
      PM advance as a one-hot MXU matmul instead of a gather.
  shed_select.py     — Algorithm 2 without the sort: fused O(1) utility
      lookup + histogram-threshold selection.
  ops.py             — jit'd public wrappers.
  ref.py             — pure-jnp oracles (the tests' allclose targets).

All kernels are TPU-targeted (pl.pallas_call + BlockSpec VMEM tiling) and
validated in this container with interpret=True against the oracles across
shape/dtype sweeps (tests/test_kernels.py).
"""
