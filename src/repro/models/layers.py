"""Shared neural-net layers: norms, RoPE, flash attention (pure-jnp online
softmax — the lowering-friendly oracle; the Pallas TPU kernel lives in
repro/kernels/flash_attention.py), GQA/MLA attention, MLP, MoE.

All functions are pure; parameters are nested dicts of jnp arrays.  Layer
parameters for the backbone are STACKED along a leading layer axis and the
forward is a lax.scan — keeps the HLO O(1) in depth, which matters for the
512-device dry-run compiles.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models import settings as SET

Array = jax.Array


# ---------------------------------------------------------------------------
# Norms & embeddings
# ---------------------------------------------------------------------------

def rmsnorm(x: Array, scale: Array, eps: float = 1e-5) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * scale).astype(dt)


def init_dense(key, d_in: int, d_out: int, dtype) -> Array:
    scale = 1.0 / np.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32)
            * scale).astype(dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: Array, pos: Array, theta: float = 1e4) -> Array:
    """x: (..., S, H, D) with pos (..., S) or (S,)."""
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)                       # (D/2,)
    angles = pos[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Flash attention (pure jnp, chunked online softmax)
# ---------------------------------------------------------------------------

def _divisor_chunk(s: int, target: int) -> int:
    """Largest divisor of s that is ≤ target (e.g. whisper's 1500 frames →
    500 for a 512 target)."""
    c = min(target, s)
    while s % c:
        c -= 1
    return c


def flash_attention(q: Array, k: Array, v: Array, *, causal: bool = True,
                    q_chunk: int | None = None, kv_chunk: int | None = None,
                    causal_skip: bool = True, q_offset: Array | int = 0,
                    scale: float | None = None) -> Array:
    """Chunked online-softmax attention.

    q: (B, Sq, H, Dk); k: (B, Sk, KVH, Dk); v: (B, Sk, KVH, Dv); GQA via
    KVH | H.  causal_skip=True iterates only the lower-triangle
    (q_chunk × kv_chunk) pairs — half the FLOPs of masked-full iteration
    (this is the §Perf "triangle schedule" optimization; causal_skip=False
    is the naive baseline).  q_offset: global position of q[0] (for decode/
    chunked prefill against a cache).  Chunk sizes default from settings
    (coarsened in analysis mode — FLOP-invariant).
    """
    if q_chunk is None or kv_chunk is None:
        fq, fkv = SET.flash_chunks()
        q_chunk = q_chunk or fq
        kv_chunk = kv_chunk or fkv
    B, Sq, H, Dk = q.shape
    _, Sk, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    if scale is None:
        scale = 1.0 / np.sqrt(Dk)
    cq = _divisor_chunk(Sq, q_chunk)
    ck = _divisor_chunk(Sk, kv_chunk)
    nq, nk = Sq // cq, Sk // ck

    qr = q.reshape(B, nq, cq, H, Dk)
    kr = k.reshape(B, nk, ck, KVH, Dk)
    vr = v.reshape(B, nk, ck, KVH, Dv)

    def pair_step(carry, ij):
        acc, m, l = carry            # (B,nq,cq,H,Dv), (B,nq,cq,H), (B,nq,cq,H)
        i, j = ij
        qi = jax.lax.dynamic_index_in_dim(qr, i, 1, keepdims=False)
        kj = jax.lax.dynamic_index_in_dim(kr, j, 1, keepdims=False)
        vj = jax.lax.dynamic_index_in_dim(vr, j, 1, keepdims=False)
        # scores: (B, cq, H, ck) — group-broadcast KV heads.
        kj_h = jnp.repeat(kj, G, axis=2)               # (B, ck, H, Dk)
        vj_h = jnp.repeat(vj, G, axis=2)
        s = jnp.einsum("bqhd,bkhd->bqhk", qi, kj_h,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = q_offset + i * cq + jnp.arange(cq)
            kpos = j * ck + jnp.arange(ck)
            mask = qpos[:, None] >= kpos[None, :]       # (cq, ck)
            s = jnp.where(mask[None, :, None, :], s, -jnp.inf)
        mi = jax.lax.dynamic_index_in_dim(m, i, 1, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, 1, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, 1, keepdims=False)
        m_new = jnp.maximum(mi, s.max(axis=-1))
        # guard fully-masked rows
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.exp(jnp.where(jnp.isneginf(mi), -jnp.inf, mi) - m_safe)
        corr = jnp.where(jnp.isneginf(mi), 0.0, corr)
        l_new = li * corr + p.sum(axis=-1)
        a_new = ai * corr[..., None] + jnp.einsum(
            "bqhk,bkhd->bqhd", p.astype(vj_h.dtype), vj_h,
            preferred_element_type=jnp.float32)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, 1)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, 1)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, 1)
        return (acc, m, l), None

    if causal and causal_skip:
        # Only (i, j) pairs whose blocks intersect the causal triangle.
        q0 = int(q_offset) if isinstance(q_offset, int) else 0
        pairs = [(i, j) for i in range(nq) for j in range(nk)
                 if (q0 + (i + 1) * cq - 1) >= j * ck]
    else:
        pairs = [(i, j) for i in range(nq) for j in range(nk)]
    ij = jnp.array(pairs, jnp.int32)

    acc0 = jnp.zeros((B, nq, cq, H, Dv), jnp.float32)
    m0 = jnp.full((B, nq, cq, H), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, nq, cq, H), jnp.float32)
    (acc, m, l), _ = SET.scan(pair_step, (acc0, m0, l0),
                                  (ij[:, 0], ij[:, 1]))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, H, Dv).astype(q.dtype)


def attention_ref(q, k, v, *, causal=True, q_offset=0, scale=None):
    """Naive reference attention (oracle for tests)."""
    B, Sq, H, Dk = q.shape
    _, Sk, KVH, _ = k.shape
    G = H // KVH
    if scale is None:
        scale = 1.0 / np.sqrt(Dk)
    kh = jnp.repeat(k, G, axis=2)
    vh = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   kh.astype(jnp.float32)) * scale
    if causal:
        qpos = q_offset + jnp.arange(Sq)
        mask = qpos[:, None] >= jnp.arange(Sk)[None, :]
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vh.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> dict:
    d, H, KVH, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": init_dense(ks[0], d, H * hd, dtype).reshape(d, H, hd),
        "wk": init_dense(ks[1], d, KVH * hd, dtype).reshape(d, KVH, hd),
        "wv": init_dense(ks[2], d, KVH * hd, dtype).reshape(d, KVH, hd),
        "wo": init_dense(ks[3], H * hd, d, dtype).reshape(H, hd, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H, hd), dtype)
        p["bk"] = jnp.zeros((KVH, hd), dtype)
        p["bv"] = jnp.zeros((KVH, hd), dtype)
    return p


def attention_qkv(p: dict, x: Array, cfg: ModelConfig, pos: Array):
    """Project to q, k, v with RoPE applied."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = apply_rope(q, pos, cfg.rope_theta)
    k = apply_rope(k, pos, cfg.rope_theta)
    return q, k, v


def attention_block(p: dict, x: Array, cfg: ModelConfig, *,
                    causal: bool = True, causal_skip: bool = True,
                    kv_override: tuple | None = None) -> Array:
    """Full attention for train/prefill.  kv_override supplies (k, v) for
    cross-attention (whisper decoder)."""
    B, S, _ = x.shape
    pos = jnp.arange(S)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    tp = "model" if cfg.attn_head_tp else None
    # §Perf "attention batch-flip": when heads don't divide the model axis
    # (minitron 24H, whisper 12H), the baseline replicates the attention
    # compute across "model" (16× redundant).  Flipping the activations to
    # batch-over-(data×model) for the attention block removes the
    # redundancy at the cost of two re-shard all-to-alls per layer.
    flip = SET.attn_batch_flip() and not cfg.attn_head_tp
    batch_ax = ("data", "model") if flip else "data"
    q = SET.constrain(q, batch_ax, None, tp, None)
    if kv_override is None:
        k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
        if cfg.qkv_bias:
            k, v = k + p["bk"], v + p["bv"]
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        k = SET.constrain(k, batch_ax, None, tp, None)
        v = SET.constrain(v, batch_ax, None, tp, None)
    else:
        k, v = kv_override
    out = flash_attention(q, k, v, causal=causal, causal_skip=causal_skip)
    out = SET.constrain(out, batch_ax, None, tp, None)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return SET.constrain(out, "data", None, None)


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v3): low-rank compressed KV + decoupled RoPE
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> dict:
    d, H = cfg.d_model, cfg.num_heads
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    ks = jax.random.split(key, 7)
    return {
        "wq_a": init_dense(ks[0], d, rq, dtype),                # q down
        "wq_b": init_dense(ks[1], rq, H * (dn + dr), dtype
                           ).reshape(rq, H, dn + dr),           # q up
        "wkv_a": init_dense(ks[2], d, rkv + dr, dtype),         # kv down+rope
        "wk_b": init_dense(ks[3], rkv, H * dn, dtype).reshape(rkv, H, dn),
        "wv_b": init_dense(ks[4], rkv, H * dv, dtype).reshape(rkv, H, dv),
        "wo": init_dense(ks[5], H * dv, d, dtype).reshape(H, dv, d),
        "norm_kv": jnp.ones((rkv,), dtype),
        "norm_q": jnp.ones((rq,), dtype),
    }


def mla_compress(p: dict, x: Array, cfg: ModelConfig, pos: Array):
    """x → (c_kv, k_rope): the compressed cache entries."""
    kv_a = jnp.einsum("bsd,dr->bsr", x, p["wkv_a"])
    c_kv = rmsnorm(kv_a[..., :cfg.kv_lora_rank], p["norm_kv"], cfg.norm_eps)
    k_rope = kv_a[..., cfg.kv_lora_rank:]                    # (B,S,dr)
    k_rope = apply_rope(k_rope[:, :, None, :], pos,
                        cfg.rope_theta)[:, :, 0]
    return c_kv, k_rope


def mla_queries(p: dict, x: Array, cfg: ModelConfig, pos: Array):
    dn, dr = cfg.head_dim, cfg.rope_head_dim
    q_a = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wq_a"]), p["norm_q"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", q_a, p["wq_b"])          # (B,S,H,dn+dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    return q_nope, q_rope


def mla_block(p: dict, x: Array, cfg: ModelConfig, *,
              causal_skip: bool = True) -> Array:
    """MLA for train/prefill: expand compressed KV, run flash attention."""
    B, S, _ = x.shape
    pos = jnp.arange(S)
    c_kv, k_rope = mla_compress(p, x, cfg, pos)
    q_nope, q_rope = mla_queries(p, x, cfg, pos)
    k_nope = jnp.einsum("bsr,rhk->bshk", c_kv, p["wk_b"])
    v = jnp.einsum("bsr,rhk->bshk", c_kv, p["wv_b"])
    q = jnp.concatenate([q_nope, q_rope], -1)
    H = cfg.num_heads
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :],
                                  (B, S, H, cfg.rope_head_dim))], -1)
    out = flash_attention(q, k, v, causal=True, causal_skip=causal_skip,
                          scale=1.0 / np.sqrt(cfg.qk_head_dim))
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, dtype) -> dict:
    ks = jax.random.split(key, 3)
    return {"wi": init_dense(ks[0], d, ff, dtype),
            "wg": init_dense(ks[1], d, ff, dtype),
            "wo": init_dense(ks[2], ff, d, dtype)}


def mlp_block(p: dict, x: Array) -> Array:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = SET.constrain(h, "data", *([None] * (h.ndim - 2)), "model")
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# MoE (shared + routed experts, capacity-based top-k dispatch)
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 5)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": init_dense(ks[0], d, E, jnp.float32),
        "wi": (jax.random.normal(ks[1], (E, d, ff), jnp.float32)
               * scale).astype(dtype),
        "wg": (jax.random.normal(ks[2], (E, d, ff), jnp.float32)
               * scale).astype(dtype),
        "wo": (jax.random.normal(ks[3], (E, ff, d), jnp.float32)
               / np.sqrt(ff)).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, ff * cfg.num_shared_experts, dtype)
    return p


def moe_block(p: dict, x: Array, cfg: ModelConfig) -> tuple[Array, Array]:
    """Capacity-based top-k MoE.  Returns (out, aux_loss).

    Dispatch is PER ROW (batch row for train/prefill; the whole decode batch
    becomes one row): per-expert top-C token selection within the row
    realizes token top-k routing with capacity C = Sr·K·cf/E.  Row-local
    dispatch keeps routing free of cross-data-shard gathers — only the
    (row, expert) → (expert-shard) activation re-layout becomes an
    all-to-all, exactly the EP pattern we want on the "model" axis.
    """
    B, S, d = x.shape
    E, K = cfg.num_experts, cfg.moe_top_k
    xr = x.reshape(1, B, d) if S == 1 else x                 # (R, Sr, d)
    R, Sr, _ = xr.shape
    logits = jnp.einsum("rsd,de->rse", xr.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    topk_val, topk_idx = jax.lax.top_k(probs, K)             # (R, Sr, K)
    gate = jnp.zeros((R, Sr, E), jnp.float32)
    gate = gate.at[jnp.arange(R)[:, None, None],
                   jnp.arange(Sr)[None, :, None], topk_idx].set(topk_val)

    C = min(Sr, max(1, int(Sr * K * cfg.capacity_factor / E)))
    gval, gidx = jax.lax.top_k(gate.transpose(0, 2, 1), C)   # (R, E, C)
    xe = jnp.take_along_axis(xr[:, None], gidx[..., None], axis=2)
    # Pin the EP layout: rows over dp, experts over "model" — without this
    # GSPMD drops the row sharding when it re-shards for the expert einsums
    # (observed 4× FLOP inflation on the 16×16 mesh).
    xe = SET.constrain(xe, "data", "model", None, None)
    h = jax.nn.silu(jnp.einsum("recd,edf->recf", xe, p["wg"])) \
        * jnp.einsum("recd,edf->recf", xe, p["wi"])
    ye = jnp.einsum("recf,efd->recd", h, p["wo"])            # (R, E, C, d)
    ye = SET.constrain(ye, "data", "model", None, None)
    ye = ye * gval[..., None].astype(ye.dtype)
    out = jnp.zeros((R, Sr, d), ye.dtype).at[
        jnp.arange(R)[:, None, None], gidx].add(ye)
    # Load-balance aux loss (Switch-style).
    me = probs.mean(axis=(0, 1))
    ce = (gate > 0).astype(jnp.float32).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)
    if cfg.num_shared_experts:
        out = out + mlp_block(p["shared"], xr).astype(out.dtype)
    return out.reshape(B, S, d).astype(x.dtype), aux
