"""Unified model configuration for the 10 assigned architectures."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str              # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- MoE ---
    moe: bool = False
    num_experts: int = 0
    num_shared_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25

    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    rope_head_dim: int = 0    # decoupled RoPE dims per head
    v_head_dim: int = 0

    # --- SSM (mamba2 / zamba2) ---
    ssm: bool = False
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    conv_width: int = 4
    hybrid_attn_every: int = 0   # zamba2: shared attn block every k layers

    # --- encoder-decoder (whisper) ---
    enc_dec: bool = False
    enc_layers: int = 0
    enc_frames: int = 1500       # stubbed conv-frontend output length

    # --- VLM (internvl) ---
    vlm_patches: int = 0         # stubbed ViT-frontend patch count

    # --- misc ---
    qkv_bias: bool = False
    rope_theta: float = 1e4
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    dtype: str = "bfloat16"

    # --- sharding hints ---
    attn_head_tp: bool = True    # heads divisible by TP → head-sharded attn
    fsdp: bool = False           # shard params/opt-state over "data" too

    @property
    def d_inner(self) -> int:           # SSM inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def qk_head_dim(self) -> int:
        if self.use_mla:
            return self.head_dim + self.rope_head_dim
        return self.head_dim

    def param_count(self) -> int:
        """Approximate parameter count (for MODEL_FLOPS and reporting)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        emb = V * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.ssm:
            di, ds, nh = self.d_inner, self.ssm_state, self.ssm_heads
            # in_proj (z,x,B,C,dt) + out_proj + conv + A,D
            per_layer += d * (2 * di + 2 * ds + nh) + di * d
            per_layer += (di + 2 * ds) * self.conv_width + 2 * nh
        if not self.ssm or self.hybrid_attn_every:
            if self.use_mla:
                attn = (d * self.q_lora_rank
                        + self.q_lora_rank * self.num_heads * self.qk_head_dim
                        + d * (self.kv_lora_rank + self.rope_head_dim)
                        + self.kv_lora_rank * self.num_heads
                        * (self.head_dim + self.v_head_dim)
                        + self.num_heads * self.v_head_dim * d)
            else:
                attn = d * self.num_heads * self.head_dim * 2 \
                    + d * self.num_kv_heads * self.head_dim * 2
            if self.hybrid_attn_every:
                n_attn = -(-self.num_layers // self.hybrid_attn_every)
                # shared params applied at n_attn points — counted ONCE
                per_layer = per_layer  # mamba layers counted above
                extra = attn + 3 * d * ff if ff else attn
                return emb + self.num_layers * per_layer + extra
            per_layer += attn
        if self.moe:
            per_layer += d * self.num_experts * ff * 3 \
                + d * self.num_shared_experts * ff * 3 \
                + d * self.num_experts
        elif ff:
            per_layer += 3 * d * ff
        n = self.num_layers * per_layer + emb
        if self.enc_dec:
            # encoder layers (self-attn + mlp) + decoder cross-attn
            enc = self.enc_layers * (4 * d * self.num_heads * self.head_dim
                                     + 2 * d * ff)
            cross = self.num_layers * 4 * d * self.num_heads * self.head_dim
            n += enc + cross
        return int(n)

    def active_param_count(self) -> int:
        """Active params per token (MoE top-k + shared; = param_count for
        dense)."""
        if not self.moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        total = self.param_count()
        routed_all = self.num_layers * d * self.num_experts * ff * 3
        routed_active = self.num_layers * d * self.moe_top_k * ff * 3
        return int(total - routed_all + routed_active)
