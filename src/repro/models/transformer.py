"""Model zoo: unified decoder-LM covering all six assigned families.

  dense  — starcoder2/qwen1.5/internlm2/minitron (GQA, RoPE, opt. QKV bias)
  moe    — deepseek-v3 (MLA + shared/routed experts), deepseek-moe-16b
  ssm    — mamba2 (pure SSD, no attention, no MLP)
  hybrid — zamba2 (mamba2 backbone + ONE shared attention+MLP block whose
           params are reused every `hybrid_attn_every` layers)
  vlm    — internvl2 (LM backbone; ViT frontend stubbed as patch embeddings)
  audio  — whisper (encoder-decoder; conv frontend stubbed as frames)

Backbone layers are stacked (leading L axis) and applied with lax.scan so the
HLO is O(1) in depth.  Forward entry points:

  forward_train(cfg, params, batch)        -> (loss, metrics)
  prefill(cfg, params, batch, max_len)     -> (cache, last_logits)
  decode_step(cfg, params, cache, tokens)  -> (logits, cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models import settings as SET

Array = jax.Array
PyTree = Any

LOSS_CHUNK = 512


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ModelConfig, dtype) -> dict:
    """One backbone layer's params (pre-stacking)."""
    ks = jax.random.split(key, 4)
    p = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if cfg.ssm:
        p["mamba"] = S.init_mamba2(ks[0], cfg, dtype)
        return p
    if cfg.use_mla:
        p["attn"] = L.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    p["norm2"] = jnp.ones((cfg.d_model,), dtype)
    if cfg.moe:
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    elif cfg.d_ff:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def _init_shared_attn(key, cfg: ModelConfig, dtype) -> dict:
    """zamba2: the shared attention+MLP block (params reused at every
    application point)."""
    ks = jax.random.split(key, 3)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_enc_layer(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": L.init_attention(ks[0], cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "mlp": L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype),
    }


def _init_cross_layer(key, cfg: ModelConfig, dtype) -> dict:
    return {"norm": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(key, cfg, dtype)}


def init_params(cfg: ModelConfig, key: Array) -> PyTree:
    dtype = _dtype(cfg)
    keys = jax.random.split(key, 8)
    scale = 1.0 / np.sqrt(cfg.d_model)
    params: dict = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model),
                                    jnp.float32) * scale).astype(dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.init_dense(keys[1], cfg.d_model,
                                         cfg.vocab_size, dtype)
    layer_keys = jax.random.split(keys[2], cfg.num_layers)
    params["layers"] = jax.vmap(
        lambda k: _init_layer(k, cfg, dtype))(layer_keys)
    if cfg.hybrid_attn_every:
        params["shared_attn"] = _init_shared_attn(keys[3], cfg, dtype)
    if cfg.enc_dec:
        ek = jax.random.split(keys[4], cfg.enc_layers)
        params["enc_layers"] = jax.vmap(
            lambda k: _init_enc_layer(k, cfg, dtype))(ek)
        params["enc_final_norm"] = jnp.ones((cfg.d_model,), dtype)
        ck = jax.random.split(keys[5], cfg.num_layers)
        params["cross_layers"] = jax.vmap(
            lambda k: _init_cross_layer(k, cfg, dtype))(ck)
    return params


# ---------------------------------------------------------------------------
# Full-sequence forward (train / prefill compute)
# ---------------------------------------------------------------------------

def _layer_fwd(cfg: ModelConfig, lp: dict, x: Array, *,
               causal_skip: bool = True):
    """One backbone layer (no cache). Returns (x, aux_loss)."""
    aux = jnp.float32(0.0)
    if cfg.ssm:
        h, _ = S.ssd_forward(lp["mamba"], L.rmsnorm(x, lp["norm1"],
                                                    cfg.norm_eps), cfg)
        return x + h, aux
    h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
    if cfg.use_mla:
        h = L.mla_block(lp["attn"], h, cfg, causal_skip=causal_skip)
    else:
        h = L.attention_block(lp["attn"], h, cfg, causal_skip=causal_skip)
    x = x + h
    h = L.rmsnorm(x, lp["norm2"], cfg.norm_eps)
    if cfg.moe:
        h, aux = L.moe_block(lp["moe"], h, cfg)
    elif cfg.d_ff:
        h = L.mlp_block(lp["mlp"], h)
    else:
        h = jnp.zeros_like(x)
    return x + h, aux


def _shared_attn_fwd(cfg: ModelConfig, sp: dict, x: Array,
                     causal_skip: bool = True) -> Array:
    h = L.rmsnorm(x, sp["norm1"], cfg.norm_eps)
    x = x + L.attention_block(sp["attn"], h, cfg, causal_skip=causal_skip)
    h = L.rmsnorm(x, sp["norm2"], cfg.norm_eps)
    return x + L.mlp_block(sp["mlp"], h)


def backbone(cfg: ModelConfig, params: PyTree, x: Array, *,
             remat: bool = True, causal_skip: bool = True,
             enc_out: Array | None = None) -> tuple[Array, Array]:
    """Scan the stacked layers. Returns (hidden, total_aux_loss)."""

    def body(carry, inp):
        x, aux = carry
        x = SET.constrain(x, "data", None, None)
        if cfg.enc_dec:
            lp, cp, idx = inp
            # self-attn → cross-attn → MLP (whisper decoder order)
            h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
            x = x + L.attention_block(lp["attn"], h, cfg,
                                      causal_skip=causal_skip)
            h = L.rmsnorm(x, cp["norm"], cfg.norm_eps)
            kv = (jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"]),
                  jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"]))
            x = x + L.attention_block(cp["attn"], h, cfg, causal=False,
                                      kv_override=kv)
            h = L.rmsnorm(x, lp["norm2"], cfg.norm_eps)
            x = x + L.mlp_block(lp["mlp"], h)
            a = jnp.float32(0.0)
        else:
            (lp, idx), cp = inp, None
            x, a = _layer_fwd(cfg, lp, x, causal_skip=causal_skip)
        if cfg.hybrid_attn_every:
            apply_shared = (idx + 1) % cfg.hybrid_attn_every == 0
            x = jax.lax.cond(
                apply_shared,
                lambda x: _shared_attn_fwd(cfg, params["shared_attn"], x,
                                           causal_skip),
                lambda x: x, x)
        return (x, aux + a), None

    body_fn = jax.checkpoint(body) if remat else body
    idxs = jnp.arange(cfg.num_layers)
    xs = ((params["layers"], params["cross_layers"], idxs) if cfg.enc_dec
          else (params["layers"], idxs))
    (x, aux), _ = SET.scan(body_fn, (x, jnp.float32(0.0)), xs)
    return x, aux


def encoder(cfg: ModelConfig, params: PyTree, frames: Array,
            remat: bool = True) -> Array:
    """Whisper encoder over stubbed conv-frontend frames (B, F, d)."""
    pos = jnp.arange(frames.shape[1])
    x = frames + _sinusoid(pos, cfg.d_model).astype(frames.dtype)

    def body(x, lp):
        h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        x = x + L.attention_block(lp["attn"], h, cfg, causal=False)
        h = L.rmsnorm(x, lp["norm2"], cfg.norm_eps)
        return x + L.mlp_block(lp["mlp"], h), None

    body_fn = jax.checkpoint(body) if remat else body
    x, _ = SET.scan(body_fn, x, params["enc_layers"])
    return L.rmsnorm(x, params["enc_final_norm"], cfg.norm_eps)


def _sinusoid(pos: Array, d: int) -> Array:
    inv = 1.0 / (1e4 ** (jnp.arange(0, d, 2) / d))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[None]


def embed_inputs(cfg: ModelConfig, params: PyTree, batch: dict) -> Array:
    """tokens (+ stubbed modality embeddings) → (B, S, d)."""
    x = params["embed"][batch["tokens"]]
    if cfg.vlm_patches and "patches" in batch:
        x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
    return x


def lm_head_logits(cfg: ModelConfig, params: PyTree, h: Array) -> Array:
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return jnp.einsum("bsd,dv->bsv", h, w.astype(h.dtype))


def chunked_ce_loss(cfg: ModelConfig, params: PyTree, h: Array,
                    labels: Array, mask: Array | None = None):
    """Cross-entropy without materializing (B, S, V) — scan over S chunks."""
    B, Sq, d = h.shape
    ck = min(SET.loss_chunk(), Sq)
    nch = Sq // ck
    assert Sq % ck == 0
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    hm = h.reshape(B, nch, ck, d).transpose(1, 0, 2, 3)
    ym = labels.reshape(B, nch, ck).transpose(1, 0, 2)
    if mask is None:
        mask = jnp.ones_like(labels, jnp.float32)
    mm = mask.reshape(B, nch, ck).transpose(1, 0, 2)

    def body(acc, inp):
        hc, yc, mc = inp
        logits = jnp.einsum("bsd,dv->bsv", hc,
                            w.astype(hc.dtype)).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, yc[..., None], -1)[..., 0]
        loss = ((lse - ll) * mc).sum()
        return (acc[0] + loss, acc[1] + mc.sum()), None

    (tot, cnt), _ = SET.scan(body, (jnp.float32(0.), jnp.float32(0.)),
                                 (hm, ym, mm))
    return tot / jnp.maximum(cnt, 1.0)


def forward_train(cfg: ModelConfig, params: PyTree, batch: dict,
                  remat: bool = True, causal_skip: bool = True):
    """batch: tokens (B,S), labels (B,S) [+ patches/frames stubs]."""
    enc_out = None
    if cfg.enc_dec:
        enc_out = encoder(cfg, params, batch["frames"], remat=remat)
    x = embed_inputs(cfg, params, batch)
    h, aux = backbone(cfg, params, x, remat=remat, causal_skip=causal_skip,
                      enc_out=enc_out)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    if cfg.vlm_patches and "patches" in batch:
        h = h[:, batch["patches"].shape[1]:]   # loss over text positions
    loss = chunked_ce_loss(cfg, params, h, batch["labels"],
                           batch.get("loss_mask"))
    return loss + 0.01 * aux, {"ce": loss, "aux": aux}
