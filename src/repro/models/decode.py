"""Serving forward passes: prefill (cache build) and single-token decode.

Decode attention is computed densely over the (sequence-sharded) cache —
one token's scores over S cached positions; GSPMD turns the S-dim reductions
into small all-reduces when the cache's sequence axis is sharded over
"model" (the memory-critical layout for decode_32k / long_500k — see
DESIGN.md §6).

MLA decode uses weight absorption: attention runs in the compressed
kv_lora_rank space, so the cache holds only (c_kv, k_rope) per token.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models import settings as SET
from repro.models.transformer import (_dtype, _sinusoid, embed_inputs,
                                      encoder, lm_head_logits)

Array = jax.Array
PyTree = Any


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=None) -> PyTree:
    dt = dtype or _dtype(cfg)
    Ln = cfg.num_layers
    cache: dict = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.ssm:
        C = cfg.d_inner + 2 * cfg.ssm_state
        cache["conv"] = jnp.zeros((Ln, batch, cfg.conv_width - 1, C), dt)
        cache["state"] = jnp.zeros(
            (Ln, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
            jnp.float32)
        if cfg.hybrid_attn_every:
            n_app = Ln // cfg.hybrid_attn_every
            cache["sk"] = jnp.zeros(
                (n_app, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt)
            cache["sv"] = jnp.zeros_like(cache["sk"])
        return cache
    if cfg.use_mla:
        cache["ckv"] = jnp.zeros((Ln, batch, max_len, cfg.kv_lora_rank), dt)
        cache["krope"] = jnp.zeros((Ln, batch, max_len, cfg.rope_head_dim),
                                   dt)
    else:
        cache["k"] = jnp.zeros(
            (Ln, batch, max_len, cfg.num_kv_heads, cfg.head_dim), dt)
        cache["v"] = jnp.zeros_like(cache["k"])
    if cfg.enc_dec:
        cache["ck"] = jnp.zeros(
            (Ln, batch, cfg.enc_frames, cfg.num_kv_heads, cfg.head_dim), dt)
        cache["cv"] = jnp.zeros_like(cache["ck"])
    return cache


# ---------------------------------------------------------------------------
# Cached attention primitives
# ---------------------------------------------------------------------------

def _gqa_cached_attn(p: dict, x: Array, kc: Array, vc: Array, pos: Array,
                     cfg: ModelConfig, *, update: bool = True,
                     causal: bool = True):
    """x: (B, d) one token; kc/vc: (B, Smax, KVH, hd).
    Returns (out (B, d), kc, vc)."""
    B, d = x.shape
    H, KVH, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    G = H // KVH
    q = jnp.einsum("bd,dhk->bhk", x, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if update:
        k_new = jnp.einsum("bd,dhk->bhk", x, p["wk"])
        v_new = jnp.einsum("bd,dhk->bhk", x, p["wv"])
        if cfg.qkv_bias:
            k_new, v_new = k_new + p["bk"], v_new + p["bv"]
        posv = jnp.full((B, 1), pos)
        q = L.apply_rope(q[:, None], posv, cfg.rope_theta)[:, 0]
        k_new = L.apply_rope(k_new[:, None], posv, cfg.rope_theta)
        kc = jax.lax.dynamic_update_slice(kc, k_new.astype(kc.dtype),
                                          (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(vc, v_new[:, None].astype(vc.dtype),
                                          (0, pos, 0, 0))
    qg = q.reshape(B, KVH, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qg.astype(jnp.float32),
                   kc.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        valid = jnp.arange(kc.shape[1]) <= pos
        s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", w, vc.astype(jnp.float32))
    o = o.reshape(B, H, hd).astype(x.dtype)
    return jnp.einsum("bhk,hkd->bd", o, p["wo"]), kc, vc


def _mla_cached_attn(p: dict, x: Array, ckv: Array, krope: Array,
                     pos: Array, cfg: ModelConfig):
    """Absorbed MLA decode. x: (B,d); ckv: (B,Smax,rkv); krope: (B,Smax,dr)."""
    B, d = x.shape
    dn, dr, dv = cfg.head_dim, cfg.rope_head_dim, cfg.v_head_dim
    posv = jnp.full((B, 1), pos)
    ckv_new, krope_new = L.mla_compress(p, x[:, None], cfg, posv)
    ckv = jax.lax.dynamic_update_slice(ckv, ckv_new.astype(ckv.dtype),
                                       (0, pos, 0))
    krope = jax.lax.dynamic_update_slice(krope, krope_new.astype(krope.dtype),
                                         (0, pos, 0))
    q_nope, q_rope = L.mla_queries(p, x[:, None], cfg, posv)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]        # (B,H,·)
    # Absorb W_kb into the query: score in compressed space.
    q_t = jnp.einsum("bhn,rhn->bhr", q_nope.astype(jnp.float32),
                     p["wk_b"].astype(jnp.float32))
    s = jnp.einsum("bhr,bsr->bhs", q_t, ckv.astype(jnp.float32)) \
        + jnp.einsum("bhr,bsr->bhs", q_rope.astype(jnp.float32),
                     krope.astype(jnp.float32))
    s = s / np.sqrt(cfg.qk_head_dim)
    valid = jnp.arange(ckv.shape[1]) <= pos
    s = jnp.where(valid[None, None, :], s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhs,bsr->bhr", w, ckv.astype(jnp.float32))
    o = jnp.einsum("bhr,rhv->bhv", ctx, p["wv_b"].astype(jnp.float32))
    out = jnp.einsum("bhv,hvd->bd", o.astype(x.dtype), p["wo"])
    return out, ckv, krope


# ---------------------------------------------------------------------------
# Decode step (one token for the whole batch)
# ---------------------------------------------------------------------------

def decode_step(cfg: ModelConfig, params: PyTree, cache: PyTree,
                tokens: Array) -> tuple[Array, PyTree]:
    """tokens: (B,) int32 — the newest token per sequence.
    Returns (logits (B, V), updated cache)."""
    pos = cache["pos"]
    x = params["embed"][tokens]                        # (B, d)
    new_cache = dict(cache)

    if cfg.ssm:
        sk = cache.get("sk")
        sv = cache.get("sv")

        def body(carry, inp):
            x, sk, sv = carry
            lp, conv_l, state_l, idx = inp
            h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
            h, conv_l, state_l = S.ssd_decode_step(lp["mamba"], h, conv_l,
                                                   state_l, cfg)
            x = x + h
            if cfg.hybrid_attn_every:
                def shared(args):
                    x, sk, sv = args
                    slot = idx // cfg.hybrid_attn_every
                    kc = sk[slot]
                    vc = sv[slot]
                    sp = params["shared_attn"]
                    h = L.rmsnorm(x, sp["norm1"], cfg.norm_eps)
                    h, kc, vc = _gqa_cached_attn(sp["attn"], h, kc, vc, pos,
                                                 cfg)
                    x = x + h
                    h = L.rmsnorm(x, sp["norm2"], cfg.norm_eps)
                    x = x + L.mlp_block(sp["mlp"], h)
                    sk = jax.lax.dynamic_update_index_in_dim(sk, kc, slot, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(sv, vc, slot, 0)
                    return x, sk, sv

                x, sk, sv = jax.lax.cond(
                    (idx + 1) % cfg.hybrid_attn_every == 0, shared,
                    lambda a: a, (x, sk, sv))
            return (x, sk, sv), (conv_l, state_l)

        idxs = jnp.arange(cfg.num_layers)
        (x, sk, sv), (conv, state) = SET.scan(
            body, (x, sk, sv),
            (params["layers"], cache["conv"], cache["state"], idxs))
        new_cache["conv"], new_cache["state"] = conv, state
        if cfg.hybrid_attn_every:
            new_cache["sk"], new_cache["sv"] = sk, sv
    else:
        def body(x, inp):
            if cfg.enc_dec:
                lp, cp, kc, vc, ck, cv = inp
            elif cfg.use_mla:
                lp, kc, vc = inp
            else:
                lp, kc, vc = inp
            h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
            if cfg.use_mla:
                h, kc, vc = _mla_cached_attn(lp["attn"], h, kc, vc, pos, cfg)
            else:
                h, kc, vc = _gqa_cached_attn(lp["attn"], h, kc, vc, pos, cfg)
            x = x + h
            if cfg.enc_dec:
                h = L.rmsnorm(x, cp["norm"], cfg.norm_eps)
                h, _, _ = _gqa_cached_attn(cp["attn"], h, ck, cv, pos, cfg,
                                           update=False, causal=False)
                x = x + h
            h = L.rmsnorm(x, lp["norm2"], cfg.norm_eps)
            if cfg.moe:
                h, _ = L.moe_block(lp["moe"], h[:, None], cfg)
                h = h[:, 0]
            elif cfg.d_ff:
                h = L.mlp_block(lp["mlp"], h)
            else:
                h = jnp.zeros_like(x)
            return x + h, (kc, vc)

        if cfg.use_mla:
            xs = (params["layers"], cache["ckv"], cache["krope"])
        elif cfg.enc_dec:
            xs = (params["layers"], params["cross_layers"], cache["k"],
                  cache["v"], cache["ck"], cache["cv"])
        else:
            xs = (params["layers"], cache["k"], cache["v"])
        x, (kc, vc) = SET.scan(body, x, xs)
        if cfg.use_mla:
            new_cache["ckv"], new_cache["krope"] = kc, vc
        else:
            new_cache["k"], new_cache["v"] = kc, vc

    h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head_logits(cfg, params, h[:, None])[:, 0]
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# Prefill
# ---------------------------------------------------------------------------

def prefill(cfg: ModelConfig, params: PyTree, batch: dict, max_len: int,
            remat: bool = True, causal_skip: bool = True
            ) -> tuple[PyTree, Array]:
    """Run the full prompt, building the cache.  Returns (cache, logits of
    the last position)."""
    enc_out = None
    if cfg.enc_dec:
        enc_out = encoder(cfg, params, batch["frames"], remat=remat)
    x = embed_inputs(cfg, params, batch)
    B, Sq, _ = x.shape
    pos = jnp.arange(Sq)
    pad = max_len - Sq
    cache = init_cache(cfg, B, max_len)

    if cfg.ssm:
        sk, sv = cache.get("sk"), cache.get("sv")

        def body(carry, inp):
            x, sk, sv = carry
            lp, idx = inp
            h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
            y, state = S.ssd_forward(lp["mamba"], h, cfg)
            # conv state tail from the last W-1 tokens' conv inputs
            z_tail = _conv_tail(lp["mamba"], h, cfg)
            x = x + y
            if cfg.hybrid_attn_every:
                def app(args):
                    x, sk, sv = args
                    x2, k, v = _shared_fwd_kv(cfg, params["shared_attn"], x,
                                              causal_skip)
                    slot = idx // cfg.hybrid_attn_every
                    kp = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    vp = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    sk = jax.lax.dynamic_update_index_in_dim(
                        sk, kp.astype(sk.dtype), slot, 0)
                    sv = jax.lax.dynamic_update_index_in_dim(
                        sv, vp.astype(sv.dtype), slot, 0)
                    return x2, sk, sv

                x, sk, sv = jax.lax.cond(
                    (idx + 1) % cfg.hybrid_attn_every == 0, app,
                    lambda a: a, (x, sk, sv))
            return (x, sk, sv), (z_tail, state)

        idxs = jnp.arange(cfg.num_layers)
        (x, sk, sv), (conv, state) = SET.scan(
            body, (x, sk, sv), (params["layers"], idxs))
        cache["conv"] = conv.astype(cache["conv"].dtype)
        cache["state"] = state
        if cfg.hybrid_attn_every:
            cache["sk"], cache["sv"] = sk, sv
    else:
        def body(x, inp):
            if cfg.enc_dec:
                lp, cp = inp
            else:
                (lp,) = inp
            h = L.rmsnorm(x, lp["norm1"], cfg.norm_eps)
            if cfg.use_mla:
                ckv, krope = L.mla_compress(lp["attn"], h, cfg, pos)
                h2 = L.mla_block(lp["attn"], h, cfg, causal_skip=causal_skip)
                kv_out = (ckv, krope)
            else:
                q, k, v = L.attention_qkv(lp["attn"], h, cfg, pos)
                o = L.flash_attention(q, k, v, causal=True,
                                      causal_skip=causal_skip)
                h2 = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
                kv_out = (k, v)
            x = x + h2
            if cfg.enc_dec:
                hn = L.rmsnorm(x, cp["norm"], cfg.norm_eps)
                ck = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wk"])
                cv = jnp.einsum("bsd,dhk->bshk", enc_out, cp["attn"]["wv"])
                x = x + L.attention_block(cp["attn"], hn, cfg, causal=False,
                                          kv_override=(ck, cv))
            h = L.rmsnorm(x, lp["norm2"], cfg.norm_eps)
            if cfg.moe:
                h, _ = L.moe_block(lp["moe"], h, cfg)
            elif cfg.d_ff:
                h = L.mlp_block(lp["mlp"], h)
            else:
                h = jnp.zeros_like(x)
            extras = kv_out + ((ck, cv) if cfg.enc_dec else ())
            return x + h, extras

        xs = ((params["layers"], params["cross_layers"]) if cfg.enc_dec
              else (params["layers"],))
        x, extras = SET.scan(body, x, xs)
        if cfg.use_mla:
            ckv, krope = extras[0], extras[1]
            cache["ckv"] = jnp.pad(
                ckv, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(
                    cache["ckv"].dtype)
            cache["krope"] = jnp.pad(
                krope, ((0, 0), (0, 0), (0, pad), (0, 0))).astype(
                    cache["krope"].dtype)
        else:
            k, v = extras[0], extras[1]
            cache["k"] = jnp.pad(
                k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(
                    cache["k"].dtype)
            cache["v"] = jnp.pad(
                v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0))).astype(
                    cache["v"].dtype)
        if cfg.enc_dec:
            cache["ck"] = extras[2].astype(cache["ck"].dtype)
            cache["cv"] = extras[3].astype(cache["cv"].dtype)

    h = L.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = lm_head_logits(cfg, params, h[:, -1:, :])[:, 0]
    cache["pos"] = jnp.int32(Sq)
    return cache, logits


def _shared_fwd_kv(cfg: ModelConfig, sp: dict, x: Array, causal_skip: bool):
    """Shared attention block forward that also returns its K/V (for the
    hybrid prefill cache)."""
    h = L.rmsnorm(x, sp["norm1"], cfg.norm_eps)
    pos = jnp.arange(x.shape[1])
    q, k, v = L.attention_qkv(sp["attn"], h, cfg, pos)
    o = L.flash_attention(q, k, v, causal=True, causal_skip=causal_skip)
    x = x + jnp.einsum("bshk,hkd->bsd", o, sp["attn"]["wo"])
    h = L.rmsnorm(x, sp["norm2"], cfg.norm_eps)
    x = x + L.mlp_block(sp["mlp"], h)
    return x, k, v


def _conv_tail(mp: dict, h: Array, cfg: ModelConfig) -> Array:
    """Last (conv_width-1) pre-conv channel inputs — the decode conv state."""
    xin = h @ mp["wx"]
    Bm = h @ mp["wB"]
    Cm = h @ mp["wC"]
    xBC = jnp.concatenate([xin, Bm, Cm], axis=-1)
    return xBC[:, -(cfg.conv_width - 1):]
