"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Chunked SSD: within-chunk quadratic (attention-like, masked by the decay
kernel) + inter-chunk linear recurrence carried by a lax.scan.  ngroups=1
(B/C shared across heads).  Decode is the O(1)-per-token recurrent update —
the reason mamba2/zamba2 are the archs assigned the ``long_500k`` shape.

Sharding: d_inner / heads shard over "model"; B/C (d_state) replicated;
out_proj row-parallel (psum by GSPMD).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import init_dense, rmsnorm
from repro.models import settings as SET

Array = jax.Array


def init_mamba2(key, cfg: ModelConfig, dtype) -> dict:
    d, di, ds, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    W = cfg.conv_width
    ks = jax.random.split(key, 7)
    return {
        "wz": init_dense(ks[0], d, di, dtype),
        "wx": init_dense(ks[1], d, di, dtype),
        "wB": init_dense(ks[2], d, ds, dtype),
        "wC": init_dense(ks[3], d, ds, dtype),
        "wdt": init_dense(ks[4], d, nh, dtype),
        "conv_w": (jax.random.normal(ks[5], (W, di + 2 * ds), jnp.float32)
                   / np.sqrt(W)).astype(dtype),
        "A_log": jnp.zeros((nh,), jnp.float32),   # A = -exp(A_log) in (-1, 0)
        "D": jnp.ones((nh,), jnp.float32),
        "dt_bias": jnp.zeros((nh,), jnp.float32),
        "norm": jnp.ones((di,), dtype),
        "wo": init_dense(ks[6], di, d, dtype),
    }


def _causal_conv(x: Array, w: Array) -> Array:
    """Depthwise causal conv, x: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W))
    return out


def _segsum(a: Array) -> Array:
    """Lower-triangular pairwise decay: out[..., i, j] = sum_{k=j+1..i} a_k
    for i >= j, -inf otherwise.  a: (..., L)."""
    L = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]       # (..., i, j)
    mask = jnp.tril(jnp.ones((L, L), bool), 0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_forward(p: dict, x: Array, cfg: ModelConfig,
                init_state: Array | None = None):
    """Mamba2 block forward. x: (B,S,d) → (y: (B,S,d), final_state).

    final_state: (B, nh, hd, ds) — the recurrent state after the last token
    (used to seed decode after prefill).
    """
    B, S, d = x.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    Q = min(cfg.ssm_chunk, S)
    S_orig = S
    if S % Q:  # pad to a chunk multiple; outputs for real tokens are exact
        # (causal), but final_state picks up extra decay — callers that use
        # final_state (prefill) always pass chunk-aligned S.
        pad = Q - S % Q
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        S = S + pad
    nc = S // Q

    z = x @ p["wz"]                                    # (B,S,di)
    xin = x @ p["wx"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32)
                         + p["dt_bias"])               # (B,S,nh)
    xBC = jnp.concatenate([xin, Bm, Cm], axis=-1)
    xBC = jax.nn.silu(_causal_conv(xBC, p["conv_w"]))
    xin, Bm, Cm = xBC[..., :di], xBC[..., di:di + ds], xBC[..., di + ds:]

    A = -jnp.exp(p["A_log"])                           # (nh,)
    a = dt * A                                         # (B,S,nh) log-decay
    xh = xin.reshape(B, S, nh, hd).astype(jnp.float32)
    xdt = xh * dt[..., None]                           # fold dt into x
    Bm = Bm.astype(jnp.float32)
    Cm = Cm.astype(jnp.float32)

    # chunked views
    ac = a.reshape(B, nc, Q, nh)
    xc = xdt.reshape(B, nc, Q, nh, hd)
    Bc = Bm.reshape(B, nc, Q, ds)
    Cc = Cm.reshape(B, nc, Q, ds)

    # Within-chunk (diagonal blocks): Y[l] = sum_{m<=l} C[l]·B[m] L[l,m] x[m]
    Lmat = jnp.exp(_segsum(ac.transpose(0, 1, 3, 2)))  # (B,nc,nh,Q,Q)
    scores = jnp.einsum("bcln,bcmn->bclm", Cc, Bc)     # (B,nc,Q,Q)
    Wt = scores[:, :, None] * Lmat.transpose(0, 1, 2, 3, 4)  # (B,nc,nh,Q,Q)
    y_diag = jnp.einsum("bchlm,bcmhp->bclhp", Wt, xc)

    # Chunk-level state contributions.
    cum = jnp.cumsum(ac, axis=2)                       # (B,nc,Q,nh)
    total = cum[:, :, -1]                              # (B,nc,nh)
    # state injected by chunk c: sum_m B[m] x[m] exp(total - cum[m])
    decay_in = jnp.exp(total[:, :, None] - cum)        # (B,nc,Q,nh)
    S_in = jnp.einsum("bcmn,bcmh,bcmhp->bchpn", Bc, decay_in, xc)

    def chunk_scan(state, inp):
        tot, s_in, c_chunk, cum_chunk = inp
        # y_inter[l] = C[l] · state · exp(cum[l])
        y_int = jnp.einsum("bln,bhpn,blh->blhp", c_chunk, state,
                           jnp.exp(cum_chunk))
        state = state * jnp.exp(tot)[..., None, None] + s_in
        return state, y_int

    state0 = (jnp.zeros((B, nh, hd, ds), jnp.float32)
              if init_state is None else init_state.astype(jnp.float32))
    xs = (total.transpose(1, 0, 2),                    # (nc,B,nh)
          S_in.transpose(1, 0, 2, 3, 4),               # (nc,B,nh,hd,ds)
          Cc.transpose(1, 0, 2, 3),                    # (nc,B,Q,ds)
          cum.transpose(1, 0, 2, 3))                   # (nc,B,Q,nh)
    final_state, y_inter = SET.scan(chunk_scan, state0, xs)
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)         # (B,nc,Q,nh,hd)

    y = (y_diag + y_inter).reshape(B, S, nh, hd)
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B, S, di)[:, :S_orig].astype(x.dtype)
    y = y * jax.nn.silu(z[:, :S_orig])
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"], final_state


def ssd_decode_step(p: dict, x: Array, conv_state: Array, ssm_state: Array,
                    cfg: ModelConfig):
    """One-token decode. x: (B,d); conv_state: (B,W-1,di+2ds);
    ssm_state: (B,nh,hd,ds).  Returns (y: (B,d), conv_state, ssm_state)."""
    B, d = x.shape
    di, ds, nh, hd = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, \
        cfg.ssm_head_dim
    z = x @ p["wz"]
    xin = x @ p["wx"]
    Bm = x @ p["wB"]
    Cm = x @ p["wC"]
    dt = jax.nn.softplus((x @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    xBC = jnp.concatenate([xin, Bm, Cm], axis=-1)      # (B, di+2ds)
    hist = jnp.concatenate([conv_state, xBC[:, None]], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", hist, p["conv_w"])
    conv_state = hist[:, 1:]
    xBC = jax.nn.silu(conv_out)
    xin, Bm, Cm = xBC[:, :di], xBC[:, di:di + ds], xBC[:, di + ds:]

    A = -jnp.exp(p["A_log"])
    dA = jnp.exp(dt * A)                               # (B,nh)
    xh = xin.reshape(B, nh, hd).astype(jnp.float32)
    ssm_state = (ssm_state * dA[..., None, None]
                 + jnp.einsum("bn,bhp,bh->bhpn", Bm.astype(jnp.float32),
                              xh, dt))
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), ssm_state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"], conv_state, ssm_state


def ssd_reference(p: dict, x: Array, cfg: ModelConfig) -> Array:
    """Oracle: token-by-token recurrence (slow, exact). For tests."""
    B, S, d = x.shape
    W = cfg.conv_width
    conv_state = jnp.zeros((B, W - 1, cfg.d_inner + 2 * cfg.ssm_state),
                           x.dtype)
    ssm_state = jnp.zeros((B, cfg.ssm_heads, cfg.ssm_head_dim,
                           cfg.ssm_state), jnp.float32)

    def step(carry, xt):
        conv_state, ssm_state = carry
        y, conv_state, ssm_state = ssd_decode_step(p, xt, conv_state,
                                                   ssm_state, cfg)
        return (conv_state, ssm_state), y

    _, ys = jax.lax.scan(step, (conv_state, ssm_state),
                         x.transpose(1, 0, 2))
    return ys.transpose(1, 0, 2)
