"""Trace-time model settings.

``analysis_mode`` reconfigures every structural scan for roofline analysis:
XLA's HLO cost analysis counts a while-loop body ONCE (it does not multiply
by trip count), so the roofline lowering unrolls all scans (layers, flash
pairs, SSD chunks, loss chunks) at two reduced depths and extrapolates
linearly — see launch/roofline.py.  The deploy lowering keeps rolled scans
(small HLO, honest memory_analysis).
"""
from __future__ import annotations

import contextlib
import contextvars

import jax

_UNROLL = contextvars.ContextVar("repro_unroll_scans", default=False)
_FLASH_Q = contextvars.ContextVar("repro_flash_q", default=512)
_FLASH_KV = contextvars.ContextVar("repro_flash_kv", default=1024)
_LOSS_CHUNK = contextvars.ContextVar("repro_loss_chunk", default=512)
# Parallelism scheme: "tp" (TP over "model" + optional FSDP over "data"),
# "fsdp" (pure FSDP: batch over ALL axes, params sharded over data×model,
# no tensor parallelism), "moe2d" (TP + experts sharded (E × d_ff) 2-D).
_SCHEME = contextvars.ContextVar("repro_scheme", default="tp")
# Flip attention activations to batch-over-(data×model) when heads don't
# divide the model axis (minitron/whisper §Perf optimization).
_ATTN_BATCH_FLIP = contextvars.ContextVar("repro_attn_flip", default=False)


def scheme() -> str:
    return _SCHEME.get()


def attn_batch_flip() -> bool:
    return _ATTN_BATCH_FLIP.get()


@contextlib.contextmanager
def use_scheme(name: str = "tp", attn_flip: bool = False):
    t1 = _SCHEME.set(name)
    t2 = _ATTN_BATCH_FLIP.set(attn_flip)
    try:
        yield
    finally:
        _SCHEME.reset(t1)
        _ATTN_BATCH_FLIP.reset(t2)


def unroll_scans() -> bool:
    return _UNROLL.get()


def flash_chunks() -> tuple[int, int]:
    return _FLASH_Q.get(), _FLASH_KV.get()


def loss_chunk() -> int:
    return _LOSS_CHUNK.get()


def scan(f, init, xs, length=None):
    """lax.scan honoring analysis-mode unrolling."""
    return jax.lax.scan(f, init, xs, length=length,
                        unroll=True if _UNROLL.get() else 1)


def constrain(x, *axes):
    """with_sharding_constraint by axis names, one entry per dim (None =
    replicated).  Silently no-ops outside a mesh context and drops axes that
    don't divide the dim — safe in unit tests and for odd batch sizes.

    Axis entries may be tuples (e.g. ("pod", "data")); "data" is auto-
    upgraded to ("pod", "data") when a pod axis exists in the mesh.
    """
    try:
        from repro.dist import compat
        mesh = compat.get_active_mesh()
        if mesh is None or not mesh.axis_names:
            return x
    except Exception:
        return x
    names = set(mesh.axis_names)
    sch = scheme()
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax is None:
            spec.append(None)
            continue
        ax_t = (ax,) if isinstance(ax, str) else tuple(ax)
        if sch == "fsdp":
            # pure-FSDP: no tensor axis; batch spreads over every axis.
            if ax_t == ("model",):
                spec.append(None)
                continue
            if "data" in ax_t and "model" not in ax_t:
                ax_t = ax_t + ("model",)
        if "data" in ax_t and "pod" in names and "pod" not in ax_t:
            ax_t = ("pod",) + ax_t
        ax_t = tuple(a for a in ax_t if a in names)
        size = 1
        for a in ax_t:
            size *= mesh.shape[a]
        while ax_t and dim % size != 0:
            ax_t = ax_t[1:]
            size = 1
            for a in ax_t:
                size *= mesh.shape[a]
        spec.append(ax_t if ax_t else None)
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.PartitionSpec(*spec))


@contextlib.contextmanager
def analysis_mode(flash_q: int = 4096, flash_kv: int = 4096,
                  loss_chunk_: int = 4096):
    """Unroll every scan; coarsen chunk granularity (FLOP-invariant) so the
    unrolled HLO stays small."""
    t1 = _UNROLL.set(True)
    t2 = _FLASH_Q.set(flash_q)
    t3 = _FLASH_KV.set(flash_kv)
    t4 = _LOSS_CHUNK.set(loss_chunk_)
    try:
        yield
    finally:
        _UNROLL.reset(t1)
        _FLASH_Q.reset(t2)
        _FLASH_KV.reset(t3)
        _LOSS_CHUNK.reset(t4)
