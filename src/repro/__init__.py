"""repro — pSPICE (partial-match shedding for CEP) reproduction, grown
into a sharded jax/Pallas streaming system.

Subpackages are imported on demand (``import repro.cep.engine`` etc.);
this module only re-exports the evaluation API so quality measurement is
one import away:

    from repro import eval as ev
    report = ev.compare_match_sets(found, ground_truth)
"""
import importlib

__all__ = ["analysis", "cep", "core", "data", "dist", "eval", "kernels",
           "launch", "runtime"]


def __getattr__(name: str):
    if name in __all__:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
