"""Engine hot-path throughput benchmark (DESIGN.md §8/§10).

Measures the event-block megakernel (``backend="pallas_block"``,
kernels/block_step.py — the PM store resident across ``block_events``
fused events) against the per-event xla scan and against the PRE-PR-3
configuration (stable-argsort allocator, sort-based Algorithm 2, no
census) on identical streams.  Written to BENCH_engine.json (committed
at the repo root as the perf trajectory; CI re-runs --quick per PR and
gates on regression):

  single_lane   (headline)  events/sec on the paper config (Q1,
      ws=3000, MAX_PMS=128 — configs/pspice_paper.py) under 120%
      overload with the pSPICE shedder: block kernel vs per-event xla
      vs pre-PR legacy.
  single_lane_large   the same at the engine-default 2048-slot store —
      the memory-traffic-bound regime the block kernel targets.
      Target: ≥2x over the per-event path.
  lanes   L=8 tenant lanes through one lane-batched scan (the vmapped
      block kernel runs W=128: per-lane stores are small, so bigger
      blocks amortize the per-block machinery).
  block_sweep   single-lane large-store events/s per W ∈ {8, 32, 128}
      — the block-size tuning artifact CI uploads per PR.
  overload_sweep   single-lane large-store events/s at overload
      1.0/1.2/1.4/1.6× — the tentpole's flat-throughput story (Alg-2
      fires resolve in-kernel).  retention_1p4 (= ev/s at 1.4× ÷
      unloaded) is gated ≥0.70 per PR, plus a machine-normalized
      absolute floor at 1.4×.
  chunk_sweep   single-lane chunked runtime (auto-grouped chunk groups,
      donated carry+events, fused device-side telemetry) vs the
      monolithic scan.  Target: chunk=256 overhead ≤5%.
  roofline   analytic arithmetic-intensity estimate for the fused vs
      unfused step (launch/roofline.py engine_block_intensity).

Regression gate (--check BASELINE.json): events/sec must not regress
more than 20% (35% on the noisier large-store cell) against the
checked-in baseline on the single-lane cells, and the chunk=256
overhead must stay within the 5% budget plus a 5-point quick-mode
noise allowance.  CI boxes differ from the box that wrote the
baseline, so throughput comparisons are machine-normalized by the
legacy engine's throughput measured in the SAME run:
    pass  ⇔  new_now ≥ 0.8 · new_base · (legacy_now / legacy_base)
(the legacy path never changes, so it is the machine-speed probe).

Usage:  PYTHONPATH=src python benchmarks/bench_engine.py
            [--quick] [--check BENCH_engine.json] [--out PATH]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.configs import pspice_paper as pp
from repro.data import streams
from repro.launch import roofline
from repro import runtime as RT

REPEATS = 3  # best-of-N walls (2-core CI boxes are noisy)
LANES_W = 128  # block size for the lane cell (small stores: amortize)


def _legacy(cfg: eng.EngineConfig) -> eng.EngineConfig:
    """The pre-PR-3 engine: per-event argsort spawn allocator, sort-based
    Algorithm 2, no pattern-census specialization, per-event scan."""
    return dataclasses.replace(cfg, backend=eng.BACKEND_XLA,
                               spawn_alloc="argsort", shed_plan="sort",
                               kinds="mixed", spawn_modes="mixed")


def _blocked(cfg: eng.EngineConfig, w: int | None = None):
    return dataclasses.replace(
        cfg, backend=eng.BACKEND_PALLAS_BLOCK,
        block_events=w if w is not None else cfg.block_events)


def _paper_workload(n: int, max_pms: int, seed: int = 7,
                    rate_mult: float = pp.RATE_MULTIPLIER):
    specs = [pat.make_q1(window_size=3000, num_symbols=10)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms,
                                latency_bound=pp.LATENCY_BOUND,
                                shedder=eng.SHED_PSPICE, **pp.COST)
    model = eng.make_model(cp, cfg)
    # rate_mult × what the cost model sustains at a half-full store
    # (the default is the paper's ~120% overload).
    rate = rate_mult / (cfg.c_base + cfg.c_match * 0.5 * max_pms)
    raw = streams.gen_stock(n, num_symbols=500, pattern_symbols=10,
                            hot_fraction=0.9, p_class=0.03, seed=seed)
    ev = streams.classify(specs, raw, rate=rate, seed=0)
    return cfg, model, ev


_OVERLOAD_LB = 0.05  # bound tight enough that queue growth crosses it
                     # within a bench-sized cell (pp.LATENCY_BOUND=1.0
                     # needs the paper's ~60k-event stream to fire)


def _overload_workload(n: int, max_pms: int, seed: int = 7):
    """Calibrated true-overload workload for the overload sweep.

    ``_paper_workload``'s hand-derived rate assumes half-full-store
    service cost, but the store settles at ~10 live PMs on bench-sized
    streams, so actual service is ~100× faster than that estimate and
    the queue never builds — Algorithm 2 never fires.  This instead
    follows ``runner.run_experiment``: a warm unloaded run fits the
    latency model, ``max_rate = 1/f(steady_n_pm)`` is what the engine
    sustains, and arrivals at ``max_rate × ratio`` are a TRUE overload
    ratio.  Returns ``(cfg, model, classify)`` where ``classify(mult)``
    yields the stream arriving at ``mult ×`` the sustainable rate.
    """
    specs = [pat.make_q1(window_size=3000, num_symbols=10)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms,
                                latency_bound=_OVERLOAD_LB,
                                shedder=eng.SHED_PSPICE, **pp.COST)
    raw_warm = streams.gen_stock(2000, num_symbols=500, pattern_symbols=10,
                                 hot_fraction=0.9, p_class=0.2,
                                 seed=seed + 1)
    warm = streams.classify(specs, raw_warm, rate=1.0, seed=seed)
    built = runner.build_model(specs, cfg, warm)
    model = eng.make_model(cp, cfg, ut_tables=built.ut_stacked,
                           ut_bins=built.ut_bins, f_model=built.f_model,
                           g_model=built.g_model)
    raw = streams.gen_stock(n, num_symbols=500, pattern_symbols=10,
                            hot_fraction=0.9, p_class=0.2, seed=seed)

    def classify(mult: float):
        return streams.classify(specs, raw, rate=built.max_rate * mult,
                                seed=0)

    return cfg, model, classify


def _refuse_degraded() -> None:
    """Refuse to record baselines from a silently-degraded build.

    BENCH_engine.json is the regression gate's ground truth, so before
    any timing runs, the benchmarked (non-legacy) configurations are
    traced and their jaxprs run through the hot-path contract rules —
    a build whose spawn allocator regressed to argsort, whose shed plan
    sorts, or whose block kernel lost its store aliases must never
    refresh the baseline.  Jaxpr-only artifacts (compile=False) keep
    this to a few hundred ms; the legacy cell is the gate's machine-
    speed probe and is deliberately NOT checked (its sort is the point).
    """
    from repro import analysis as A
    from repro.analysis import pallas_rules as APR

    cfg, model, ev = _paper_workload(64, pp.MAX_PMS)
    ctr = A.get_contract("cep.run_engine")
    jaxpr_rules = [r for r in A.RULES
                   if r.name in ("no-sort", "no-callback", "control-flow")]
    bad = []
    for label, cell in (("xla", cfg), ("pallas_block", _blocked(cfg))):
        art = A.trace_artifact(eng.run_engine, cell, model, ev,
                               eng.init_carry(cell), name=f"bench[{label}]",
                               n_events=64, compile=False)
        fs = A.run_rules(art, ctr, rules=jaxpr_rules)
        fs += APR.check_pallas_calls(art, ctr)
        bad += [f for f in fs if not f.ok]
    if bad:
        for f in bad:
            print(f"CONTRACT VIOLATION {f.cell}: {f.rule}: {f.evidence}",
                  file=sys.stderr)
        print("refusing to record baselines from a degraded build "
              "(see repro.analysis / DESIGN.md §11)", file=sys.stderr)
        sys.exit(2)


def _time_engine(cfg, model, ev, n, reps) -> float:
    def run():
        t0 = time.perf_counter()
        c, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        jax.block_until_ready(c.sim_time)
        return time.perf_counter() - t0
    run()                                # compile
    return n / min(run() for _ in range(reps))


def bench_single_lane(n: int, max_pms: int, reps: int) -> dict:
    cfg, model, ev = _paper_workload(n, max_pms)
    new = _time_engine(_blocked(cfg), model, ev, n, reps)
    xla = _time_engine(cfg, model, ev, n, reps)
    legacy = _time_engine(_legacy(cfg), model, ev, n, reps)
    return {
        "n_events": n, "max_pms": max_pms,
        "block_events": cfg.block_events,
        "events_per_s_new": new, "events_per_s_xla": xla,
        "events_per_s_legacy": legacy,
        "speedup_vs_xla": new / xla,
        "speedup_vs_pre_pr": new / legacy,
    }


def bench_block_sweep(n: int, max_pms: int, reps: int,
                      ws=(8, 32, 128)) -> list[dict]:
    """Single-lane large-store events/s per block size W."""
    cfg, model, ev = _paper_workload(n, max_pms)
    return [{"block_events": w, "max_pms": max_pms,
             "events_per_s": _time_engine(_blocked(cfg, w), model, ev, n,
                                          reps)}
            for w in ws]


def bench_overload_sweep(n: int, max_pms: int, reps: int,
                         ratios=(1.0, 1.2, 1.4, 1.6)) -> dict:
    """The tentpole's flat-throughput story: fused pallas_block ev/s as a
    function of overload ratio.  Algorithm-2 fires are handled inside the
    kernel, so ev/s must stay ~flat across the sweep instead of decaying
    toward per-event throughput at 1.4× (the PR-5 bail/replay behavior).
    The 1.4× cell also times the legacy per-event engine as the gate's
    machine-speed probe; retention_1p4 (ev/s at 1.4× ÷ unloaded) is the
    machine-independent headline the CI gate floors at 0.70.  The
    workload is the calibrated one (``_overload_workload``): the ratio
    axis is relative to the fitted sustainable rate, so ratios > 1.0
    actually fire the shed (shed_calls is recorded per row as proof)."""
    cfg, model, classify = _overload_workload(n, max_pms)
    rows = []
    for mult in ratios:
        ev = classify(mult)
        cfg_b = _blocked(cfg)
        carry, _ = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
        row = {"overload": mult, "max_pms": max_pms, "n_events": n,
               "shed_calls": float(carry.shed_calls),
               "events_per_s_new": _time_engine(cfg_b, model, ev, n, reps)}
        if mult == 1.4:
            row["events_per_s_legacy"] = _time_engine(_legacy(cfg), model,
                                                      ev, n, reps)
        rows.append(row)
    by = {r["overload"]: r["events_per_s_new"] for r in rows}
    return {"rows": rows,
            "retention_1p4": by[1.4] / by[1.0] if 1.0 in by and 1.4 in by
            else None}


def bench_lanes(num_lanes: int, n_per_lane: int, max_pms: int,
                reps: int) -> dict:
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=1.0,
                                shedder=eng.SHED_PSPICE, **pp.COST)
    model = eng.make_model(cp, cfg)
    rate = 1.2 / (cfg.c_base + cfg.c_match * 0.5 * max_pms)
    evs = []
    for lane in range(num_lanes):
        raw = streams.gen_stock(n_per_lane, num_symbols=50,
                                pattern_symbols=4, p_class=0.05,
                                seed=100 + lane)
        evs.append(streams.classify(specs, raw,
                                    rate=rate * (1 + 0.1 * lane),
                                    seed=lane))
    evL = RT.stack(evs)
    mL = RT.broadcast_model(model, num_lanes)
    total = num_lanes * n_per_lane

    def run(c):
        carry = RT.init_lane_carries(c, num_lanes)
        t0 = time.perf_counter()
        out, _ = RT.run_chunk_lanes(c, mL, evL, carry, jnp.int32(0))
        jax.block_until_ready(out.sim_time)
        return time.perf_counter() - t0

    def best(c):
        run(c)
        return total / min(run(c) for _ in range(reps))

    new = best(_blocked(cfg, LANES_W))
    xla = best(cfg)
    legacy = best(_legacy(cfg))
    return {
        "num_lanes": num_lanes, "events_per_lane": n_per_lane,
        "max_pms": max_pms, "total_events": total,
        "block_events": LANES_W,
        "events_per_s_new": new, "events_per_s_xla": xla,
        "events_per_s_legacy": legacy,
        "speedup_vs_xla": new / xla,
        "speedup_vs_pre_pr": new / legacy,
    }


def bench_chunk_sweep(n: int, chunk_sizes, max_pms: int,
                      reps: int) -> list[dict]:
    """Chunked-runtime overhead vs the monolithic scan, on the block
    backend (the default auto-grouping policy sizes chunk groups —
    runtime.chunker.suggested_group_chunks)."""
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=1.0,
                                shedder=eng.SHED_PSPICE, **pp.COST)
    cfg = _blocked(cfg)
    model = eng.make_model(cp, cfg)
    rate = 1.2 / (cfg.c_base + cfg.c_match * 0.5 * max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=100)
    ev = streams.classify(specs, raw, rate=rate, seed=0)

    def run_mono():
        t0 = time.perf_counter()
        c, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        jax.block_until_ready(c.sim_time)
        return time.perf_counter() - t0

    run_mono()
    wall_mono = min(run_mono() for _ in range(reps))
    rows = [{"chunk_size": 0, "variant": "monolithic",
             "events_per_s": n / wall_mono, "wall_s": wall_mono}]
    for cs in chunk_sizes:
        def run():
            srt = RT.StreamRuntime(cfg, model,
                                   rt=RT.RuntimeConfig(chunk_size=cs))
            t0 = time.perf_counter()
            srt.push(ev, flush=True)
            return time.perf_counter() - t0
        run()
        wall = min(run() for _ in range(reps))
        rows.append({"chunk_size": cs, "variant": "chunked",
                     "events_per_s": n / wall, "wall_s": wall,
                     "overhead_vs_monolithic_pct":
                         100.0 * (wall / wall_mono - 1.0)})
    return rows


def _gate_cell(out: dict, base: dict, cell: str, norm: float,
               factor: float = 0.8) -> bool:
    b, c = base[cell], out[cell]
    floor = factor * b["events_per_s_new"] * norm
    ok = c["events_per_s_new"] >= floor
    print(f"# gate[{cell}]: new={c['events_per_s_new']:.0f} ev/s, "
          f"baseline={b['events_per_s_new']:.0f}, machine-norm={norm:.2f}, "
          f"floor={floor:.0f} → {'PASS' if ok else 'FAIL'}",
          file=sys.stderr)
    return ok


def _gate_overload(out: dict, base: dict) -> bool:
    """The overload gate, two halves: (1) intra-run retention — ev/s at
    1.4× overload must hold ≥70% of the unloaded rate (machine-free: a
    ratio of walls from the SAME run, so it catches the fused shed path
    reverting to bail/replay no matter the box); (2) when the baseline
    has an overload_sweep, the machine-normalized ev/s floor at 1.4×
    (the 2048-slot store's 0.65 factor — same variance class as
    single_lane_large)."""
    sw = out.get("overload_sweep")
    if not sw or sw.get("retention_1p4") is None:
        return True
    ret = sw["retention_1p4"]
    ok = ret >= 0.70
    print(f"# gate[overload@1.4x]: retention={ret:.2f} (floor 0.70) → "
          f"{'PASS' if ok else 'FAIL'}", file=sys.stderr)
    bsw = base.get("overload_sweep")
    if bsw:
        now = {r["overload"]: r for r in sw["rows"]}
        was = {r["overload"]: r for r in bsw["rows"]}
        if 1.4 in now and 1.4 in was and "events_per_s_legacy" in was[1.4]:
            norm = (now[1.4]["events_per_s_legacy"] /
                    was[1.4]["events_per_s_legacy"])
            floor = 0.65 * was[1.4]["events_per_s_new"] * norm
            ok14 = now[1.4]["events_per_s_new"] >= floor
            print(f"# gate[overload@1.4x abs]: "
                  f"new={now[1.4]['events_per_s_new']:.0f} ev/s, "
                  f"baseline={was[1.4]['events_per_s_new']:.0f}, "
                  f"machine-norm={norm:.2f}, floor={floor:.0f} → "
                  f"{'PASS' if ok14 else 'FAIL'}", file=sys.stderr)
            ok &= ok14
    return ok


def check_regression(out: dict, baseline_path: str) -> bool:
    """Machine-normalized ±20% events/sec gate vs the checked-in baseline
    on BOTH single-lane cells (paper config and the 2048-slot store this
    PR's kernel targets), the 1.4×-overload cell (retention + absolute),
    plus the chunk=256 overhead ceiling.  Returns True when passing."""
    with open(baseline_path) as f:
        base = json.load(f)
    norm = (out["single_lane"]["events_per_s_legacy"] /
            base["single_lane"]["events_per_s_legacy"])
    ok = _gate_cell(out, base, "single_lane", norm)
    if "single_lane_large" in base:
        norm_l = (out["single_lane_large"]["events_per_s_legacy"] /
                  base["single_lane_large"]["events_per_s_legacy"])
        # The 2048-slot block cell has higher run-to-run variance than
        # the legacy probe tracks (quick-mode spread of 0.68-1.03x the
        # baseline observed on a loaded 2-core box); a 35% floor still
        # catches the regression class the cell exists for (the ~4x
        # fused-kernel win reverting toward the ~5k ev/s per-event
        # path, which lands at ~0.23x).
        ok &= _gate_cell(out, base, "single_lane_large", norm_l,
                         factor=0.65)
    cell256 = [r for r in out["chunk_sweep"] if r["chunk_size"] == 256]
    if cell256:
        # Budget is ≤5% (DESIGN.md §8; the committed full-run sweep sits
        # at ~0%); the CI ceiling adds a 5-point allowance for quick-mode
        # noise on shared 2-core boxes.
        ov = cell256[0]["overhead_vs_monolithic_pct"]
        ok256 = ov <= 10.0
        print(f"# gate[chunk=256]: overhead={ov:.1f}% (budget 5% + 5 "
              f"noise allowance) → {'PASS' if ok256 else 'FAIL'}",
              file=sys.stderr)
        ok &= ok256
    ok &= _gate_overload(out, base)
    if not ok:
        print("# events/s regressed past a cell's floor (20% paper cell "
              "/ 35% large cell / 0.70 overload retention) or chunk "
              "overhead blew the ceiling, vs checked-in baseline",
              file=sys.stderr)
    return ok


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--check", default=None, metavar="BASELINE",
                    help="fail if events/s regresses >20% vs this JSON")
    ap.add_argument("--out", default="BENCH_engine.json")
    args = ap.parse_args(argv)

    # Quick mode shrinks ONLY the event counts/repeats — identical
    # configurations, so per-event rates stay comparable with the
    # committed full-run baseline (the --check gate relies on this).
    if args.quick:
        # n_large stays big enough that fixed per-run costs don't eat
        # into the 20% gate margin at the slow 2048-slot per-event rate,
        # and the chunk sweep keeps the full-run stream length: at 8k
        # events its walls are ~50 ms and the overhead gate becomes
        # noise (±20% observed) — the full 32k costs CI ~1 s.
        n, n_large, reps = 8000, 8000, 2
        L, n_lane = 4, 4096
        sweep_n, sweep = 32768, (256, 1024)
    else:
        n, n_large, reps = 30000, 15000, REPEATS
        L, n_lane = 8, 8192
        sweep_n, sweep = 32768, (256, 1024, 4096)

    _refuse_degraded()
    out = {"quick": bool(args.quick), "num_devices": len(jax.devices()),
           "backend": jax.default_backend()}
    print("name,events_per_s_new,derived")
    t0 = time.time()
    head = bench_single_lane(n, pp.MAX_PMS, reps)
    out["single_lane"] = head
    print(f"single_lane:max_pms={pp.MAX_PMS},"
          f"{head['events_per_s_new']:.0f},"
          f"speedup_vs_xla={head['speedup_vs_xla']:.2f}x,"
          f"vs_pre_pr={head['speedup_vs_pre_pr']:.2f}x")
    large = bench_single_lane(n_large, 2048, reps)
    out["single_lane_large"] = large
    print(f"single_lane:max_pms=2048,{large['events_per_s_new']:.0f},"
          f"speedup_vs_xla={large['speedup_vs_xla']:.2f}x,"
          f"vs_pre_pr={large['speedup_vs_pre_pr']:.2f}x")
    out["block_sweep"] = bench_block_sweep(n_large, 2048, reps)
    for r in out["block_sweep"]:
        print(f"block_sweep:W={r['block_events']},"
              f"{r['events_per_s']:.0f},")
    out["overload_sweep"] = bench_overload_sweep(n_large, 2048, reps)
    for r in out["overload_sweep"]["rows"]:
        print(f"overload_sweep:x{r['overload']},"
              f"{r['events_per_s_new']:.0f},"
              f"shed_calls={r['shed_calls']:.0f}")
    print(f"overload_sweep:retention_1p4,"
          f"{out['overload_sweep']['retention_1p4']:.3f},")
    lanes = bench_lanes(L, n_lane, 64, reps)
    out["lanes"] = lanes
    print(f"lanes:L={L},{lanes['events_per_s_new']:.0f},"
          f"speedup_vs_xla={lanes['speedup_vs_xla']:.2f}x,"
          f"vs_pre_pr={lanes['speedup_vs_pre_pr']:.2f}x")
    # Sweep overheads are ratios of ~0.2 s walls: always take best-of-3,
    # quick mode included — min-of-2 leaves ±5-point overhead noise.
    out["chunk_sweep"] = bench_chunk_sweep(sweep_n, sweep, 64,
                                           max(reps, REPEATS))
    for r in out["chunk_sweep"]:
        tag = r["variant"] if r["chunk_size"] == 0 \
            else f"chunk={r['chunk_size']}"
        extra = "" if r["chunk_size"] == 0 else \
            f"overhead={r['overhead_vs_monolithic_pct']:.1f}%"
        print(f"chunk_sweep:{tag},{r['events_per_s']:.0f},{extra}")
    # Memory-traffic story of the fused step (analytic, DESIGN.md §10).
    cfg_large = _blocked(_paper_workload(64, 2048)[0])
    out["roofline"] = roofline.engine_block_intensity(cfg_large)
    print(f"roofline:intensity,"
          f"{out['roofline']['intensity_fused']:.2f},"
          f"unfused={out['roofline']['intensity_unfused']:.2f},"
          f"traffic_ratio={out['roofline']['traffic_ratio']:.1f}x")
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out}", file=sys.stderr)

    if large["speedup_vs_xla"] < 2.0:
        print("# WARNING: large-store block speedup below the 2x target",
              file=sys.stderr)
    if args.check and not check_regression(out, args.check):
        sys.exit(1)


if __name__ == "__main__":
    main()
