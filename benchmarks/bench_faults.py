"""Chaos benchmark: the fault matrix the resilience layer must survive.

Runs the streaming runtime (ingest front-end + degradation ladder + carry
guard, DESIGN.md §12) under every fault kind ``repro.runtime.faults``
defines — alone, all at once, and off — and writes BENCH_robustness.json.
Every cell is SEEDED and deterministic, so CI gates on exact outcomes:

  * zero unhandled exceptions in any cell;
  * zero NaN/Inf escaping into the final carry or deployed model;
  * a clean guard sweep after the run (violations were caught + restored);
  * every ladder/guard decision mirrored in telemetry (the event log
    agrees with the ladder's and guard's own counters);
  * bounded FN degradation: each fault cell keeps at least
    ``1 - FN_BOUND`` of the clean cell's complex-event completions;
  * ``process_kill``: the one fault the in-process matrix cannot apply —
    losing the process itself.  The chaos harness (repro.runtime.
    supervisor) SIGKILLs a persist-enabled subprocess at a seeded
    mid-chunk point, relaunches it, and the recovered run must end
    bitwise-identical (carry sha, match sets, counters) to an
    uninterrupted one.  The full kill-site × backend × shedder grid
    lives in benchmarks/bench_recovery.py; this cell keeps the fault
    matrix COMPLETE over ``faults.FAULT_KINDS``.
  * ``disabled_bitwise_<backend>``: with injection and resilience off,
    the chunked runtime stays bitwise-identical to one monolithic
    ``run_engine`` scan on all three backends.

Usage:  PYTHONPATH=src python benchmarks/bench_faults.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
import traceback

import jax
import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro import runtime as RT
from repro.runtime import supervisor as SV

# In-process faults: everything except process_kill, which needs the
# subprocess harness below.
INPROC_FAULTS = RT.STREAM_FAULTS + RT.STATE_FAULTS

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)
# Max fraction of the clean cell's delivered completions a fault cell
# may lose.  This is a LIVENESS bound, not a quality target (the paper's
# FN claims are measured by repro.eval): quarantine refuses whole pushes
# and the worst cell (stall: repeated 256-event pile-ups) legitimately
# sheds most of the stream — the gate asserts the runtime keeps
# delivering matches under every fault instead of wedging at zero.
FN_BOUND = 0.98


def build_workload(n: int, backend: str = eng.BACKEND_XLA,
                   max_pms: int = 48):
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=0.005,
                                gather_stats=True, shedder=eng.SHED_PSPICE,
                                backend=backend, **COST)
    model = eng.make_model(cp, cfg)
    # At ~sustainable rate: the CLEAN cell stays mostly under the ladder
    # bound, so escalation in fault cells is attributable to the faults.
    rate = 1.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=101)
    ev = streams.classify(specs, raw, rate=rate, seed=7)
    return specs, cfg, model, ev


def resilience_rt(chunk: int) -> RT.RuntimeConfig:
    return RT.RuntimeConfig(
        chunk_size=chunk,
        refresh=RT.RefreshConfig(every_chunks=4, min_observations=64.0),
        ingest=RT.IngestConfig(max_queue_events=1 << 15,
                               high_watermark=1 << 13,
                               low_watermark=1 << 11, seed=5),
        # deescalate_streak also paces quarantine recovery (one rung per
        # streak of refused pushes) — keep it short so a stalled stream
        # is readmitted within the run instead of starving the cell.
        ladder=RT.LadderConfig(escalate_streak=2, deescalate_streak=2,
                               latency_bound=0.01),
        guard=RT.GuardConfig(check_every_chunks=1,
                             checkpoint_every_chunks=4))


def _floats_finite(tree) -> bool:
    for leaf in jax.tree.leaves(tree):
        a = np.asarray(leaf)
        if np.issubdtype(a.dtype, np.floating) and not np.isfinite(a).all():
            return False
    return True


def run_cell(name: str, kinds: tuple[str, ...], specs, cfg, model, ev,
             chunk: int, push: int, p_fault: float = 0.35,
             seed: int = 3) -> dict:
    row: dict = {"cell": name, "kinds": list(kinds)}
    try:
        inj = RT.FaultInjector(RT.FaultConfig(
            kinds=kinds, seed=seed, p_fault=p_fault)) if kinds else None
        srt = RT.StreamRuntime(cfg, model, resilience_rt(chunk),
                               specs=specs)
        n = RT.num_events(ev)
        t0 = time.perf_counter()
        for s in range(0, n, push):
            batch = RT.slice_events(ev, s, min(s + push, n))
            if inj is not None:
                # State faults strike between pushes, stream faults
                # rewrite the batch before it is offered.
                srt.carry = inj.corrupt_carry(srt.carry)
                srt.model = inj.corrupt_model(srt.model)
                batch = inj.corrupt_events(batch)
            srt.push(batch)
        srt.flush()
        srt.guard_now()                      # end-of-run sweep (+restore)
        row["wall_s"] = time.perf_counter() - t0

        agg = srt.telemetry.aggregate()
        row.update(
            events_processed=srt.events_processed,
            completions=float(np.asarray(srt.carry.complex_count).sum()),
            completions_observed=agg.get("completions", 0.0),
            faults_applied=len(inj.log) if inj else 0,
            admission_shed=srt.ingest.total_shed,
            admission_rejected=srt.ingest.total_rejected,
            quarantine_dropped=srt.quarantine_dropped,
            max_rung=agg.get("max_rung", 0),
            ladder_transitions=len(srt.ladder.transitions),
            guard_checks=srt.guard.checks_run,
            guard_violations=srt.guard.violations,
            guard_restores=srt.guard.restores,
            refresh_skipped_nonfinite=srt.refresh_state.skipped_nonfinite,
        )
        row["ok_no_exception"] = True
        # No NaN/Inf may survive into the carry or the deployed model.
        row["ok_state_finite"] = (_floats_finite(srt.carry)
                                  and _floats_finite(srt.model))
        # After the final sweep's restore, a re-check must be clean.
        row["ok_guard_clean"] = srt.guard.check(srt.carry, srt.model) == []
        # Every runtime decision must be mirrored in telemetry.
        row["ok_mirrored"] = (
            len(srt.ladder.transitions)
            == len(srt.telemetry.events_of("ladder"))
            == agg.get("ladder_transitions", -1)
            and srt.guard.violations
            == len(srt.telemetry.events_of("guard_violation"))
            and srt.guard.restores
            == len(srt.telemetry.events_of("guard_restore")))
    except Exception:
        row["ok_no_exception"] = False
        row["traceback"] = traceback.format_exc()
    return row


def run_process_kill_cell(n: int, chunk: int, push: int,
                          seed: int = 3) -> dict:
    """The process-death cell: seeded SIGKILL mid-chunk via the chaos
    harness, restart, recovery must be bitwise vs an uninterrupted run."""
    row: dict = {"cell": "process_kill", "kinds": list(RT.PROCESS_FAULTS)}
    try:
        spec = {"backend": eng.BACKEND_XLA, "shedder": eng.SHED_PSPICE,
                "n": n, "push": push, "chunk": chunk, "max_pms": 32,
                "rate_mult": 3.0, "refresh_every": 4, "snapshot_every": 4,
                "min_observations": 64.0}
        inj = RT.FaultInjector(RT.FaultConfig(kinds=RT.PROCESS_FAULTS,
                                              seed=seed))
        ks = inj.plan_kill("chunk", lo=2, hi=8)
        row["kill_spec"] = ks.spec()
        t0 = time.perf_counter()
        ref = SV.run_service(spec, persist_dir=None)
        with tempfile.TemporaryDirectory() as d:
            res = SV.Supervisor(d).run(spec, kill=ks.spec())
        row["wall_s"] = time.perf_counter() - t0
        rep = res["report"]
        row.update(faults_applied=len(inj.log),
                   completions=rep["counters"].get("completions", 0.0),
                   replayed_records=rep["recovery"]["replayed_records"],
                   guard_restores=rep["counters"].get("guard_restores", 0),
                   max_rung=rep["counters"].get("max_rung", 0),
                   events_processed=rep["events_processed"])
        row["ok_no_exception"] = True
        row["ok_killed"] = res["killed"]
        row["ok_recovered"] = res["recovered"]
        row["ok_bitwise"] = (
            rep["carry_sha"] == ref["carry_sha"]
            and rep["matches"] == ref["matches"]
            and rep["counters"] == ref["counters"])
    except Exception:
        row["ok_no_exception"] = False
        row["traceback"] = traceback.format_exc()
    return row


def run_bitwise_cell(backend: str, n: int, chunk: int) -> dict:
    """Resilience OFF + no injection: the chunked runtime must equal one
    monolithic scan bit for bit on this backend."""
    row: dict = {"cell": f"disabled_bitwise_{backend}", "backend": backend,
                 "n": n}
    try:
        _, cfg, model, ev = build_workload(n, backend=backend)
        t0 = time.perf_counter()
        c_mono, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        srt = RT.StreamRuntime(cfg, model,
                               rt=RT.RuntimeConfig(chunk_size=chunk))
        srt.push(ev, flush=True)
        row["wall_s"] = time.perf_counter() - t0
        row["ok_no_exception"] = True
        row["ok_bitwise"] = all(
            np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(c_mono),
                            jax.tree.leaves(srt.carry)))
    except Exception:
        row["ok_no_exception"] = False
        row["traceback"] = traceback.format_exc()
    return row


def _gates(row: dict) -> list[str]:
    return [k for k, v in row.items() if k.startswith("ok_") and not v]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_robustness.json")
    args = ap.parse_args(argv)

    if args.quick:
        n, chunk, push, bw_n = 4096, 256, 512, 768
    else:
        n, chunk, push, bw_n = 8192, 256, 512, 1536

    specs, cfg, model, ev = build_workload(n)
    out = {"quick": bool(args.quick), "backend": jax.default_backend(),
           "n_events": n, "chunk_size": chunk, "fn_bound": FN_BOUND,
           "cells": []}
    t_all = time.time()

    print("cell,completions,faults,restores,max_rung,gates")
    cells = [("clean", ())]
    cells += [(k, (k,)) for k in INPROC_FAULTS]
    cells += [("all_faults", INPROC_FAULTS)]
    clean_completions = None
    for name, kinds in cells:
        row = run_cell(name, kinds, specs, cfg, model, ev, chunk, push)
        # Bounded FN degradation vs the clean cell (fault cells only).
        # The FN bound compares completions OBSERVED (telemetry's
        # per-chunk deltas: matches already delivered downstream), not
        # the final carry counter — a guard restore rewinds the carry,
        # but delivered matches are not un-delivered by it.
        if name == "clean":
            clean_completions = row.get("completions_observed", 0.0)
            row["ok_clean_nonempty"] = clean_completions > 0
        elif row["ok_no_exception"] and clean_completions:
            lost = 1.0 - row["completions_observed"] / clean_completions
            row["fn_vs_clean"] = lost
            row["ok_fn_bounded"] = lost <= FN_BOUND
        bad = _gates(row)
        out["cells"].append(row)
        print(f"{name},{row.get('completions', 'ERR')},"
              f"{row.get('faults_applied', 0)},"
              f"{row.get('guard_restores', 0)},"
              f"{row.get('max_rung', 0)},"
              f"{'FAIL:' + '+'.join(bad) if bad else 'pass'}")

    row = run_process_kill_cell(n=1536, chunk=128, push=512)
    bad = _gates(row)
    out["cells"].append(row)
    print(f"process_kill,{row.get('completions', 'ERR')},"
          f"{row.get('faults_applied', 0)},"
          f"{row.get('guard_restores', 0)},"
          f"{row.get('max_rung', 0)},"
          f"{'FAIL:' + '+'.join(bad) if bad else 'pass'}")

    for backend in (eng.BACKEND_XLA, eng.BACKEND_PALLAS,
                    eng.BACKEND_PALLAS_BLOCK):
        row = run_bitwise_cell(backend, bw_n, chunk)
        bad = _gates(row)
        out["cells"].append(row)
        print(f"{row['cell']},-,-,-,-,"
              f"{'FAIL:' + '+'.join(bad) if bad else 'pass'}")

    failures = {r["cell"]: _gates(r) for r in out["cells"] if _gates(r)}
    out["failures"] = failures
    out["wall_s_total"] = time.time() - t_all
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out} ({out['wall_s_total']:.1f}s)",
          file=sys.stderr)
    if failures:
        print(f"# CHAOS GATE FAILURES: {failures}", file=sys.stderr)
        for r in out["cells"]:
            if r.get("traceback"):
                print(r["traceback"], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
