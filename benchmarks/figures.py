"""One benchmark per paper figure (pSPICE §IV-B).

Each function returns a list of row-dicts and is invoked by benchmarks.run.
Streams are synthetic but statistically shaped like the paper's datasets
(repro/data/streams.py); match probability is controlled exactly the way the
paper controls it (window size for Q1/Q2, pattern size for Q3/Q4).
"""
from __future__ import annotations

import time

import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams

from repro.configs.pspice_paper import COST
SHEDDERS = (eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)


def _stock(n, seed=1, p_class=0.03):
    return streams.gen_stock(n, num_symbols=500, pattern_symbols=10,
                             hot_fraction=0.9, p_class=p_class, seed=seed)


def _run(specs, raw, rate_multiplier=1.2, shedders=SHEDDERS, **kw):
    args = dict(COST, max_pms=128, bin_size=64, latency_bound=1.0)
    args.update(kw)
    return runner.run_experiment(specs, raw, shedders=shedders,
                                 rate_multiplier=rate_multiplier, **args)


def _rows(fig, query, xlabel, xval, res, wall):
    rows = []
    for name, r in res.items():
        rows.append({
            "figure": fig, "query": query, xlabel: xval, "shedder": name,
            "fn_pct": round(100 * r.fn, 2),
            "match_prob": round(r.match_probability, 4),
            "gt_complex": float(r.ground_truth.complex_count.sum()),
            "pms_shed": r.result.pms_shed,
            "ebl_dropped": r.result.ebl_dropped,
            "max_l_e": round(float(r.result.l_e.max()), 4),
            "lb_violation_frac": round(
                float((r.result.l_e > 1.01).mean()), 5),
            "wall_s": round(wall, 1),
        })
    return rows


# ---------------------------------------------------------------------------
# Fig. 5 — impact of match probability (FN% vs matchP per query × shedder)
# ---------------------------------------------------------------------------

def fig5_match_probability(quick: bool = False):
    rows = []
    ws_list = [2000, 3000, 4000, 6000, 8000] if not quick else [2000, 6000]
    for ws in ws_list:                                     # Q1
        n = 100_000 if ws <= 3000 and not quick else 60_000
        t0 = time.time()
        res = _run([pat.make_q1(ws, num_symbols=10)], _stock(n))
        rows += _rows("fig5a", "Q1", "window_size", ws, res,
                      time.time() - t0)
    ws_list = [3000, 4500, 6000, 9000, 12000] if not quick else [4000]
    for ws in ws_list:                                     # Q2 (repetition)
        t0 = time.time()
        res = _run([pat.make_q2(ws)], _stock(60_000, seed=2))
        rows += _rows("fig5b", "Q2", "window_size", ws, res,
                      time.time() - t0)
    n_list = [2, 3, 4, 5, 6] if not quick else [4]
    for n_def in n_list:                                   # Q3 (seq+any)
        t0 = time.time()
        raw = streams.gen_soccer(60_000, p_striker=0.004, p_defend=0.006,
                                 seed=3)
        res = _run([pat.make_q3(any_n=n_def, window_size=1500)], raw,
                   max_any_ids=8)
        rows += _rows("fig5c", "Q3", "pattern_size", n_def, res,
                      time.time() - t0)
    n_list = [2, 3, 4, 5, 7] if not quick else [3]
    for n_bus in n_list:                                   # Q4 (any)
        t0 = time.time()
        raw = streams.gen_bus(60_000, p_delay=0.02, seed=4)
        res = _run([pat.make_q4(any_n=n_bus, window_size=3000, slide=500)],
                   raw, max_any_ids=8, ring_size=6)
        rows += _rows("fig5d", "Q4", "pattern_size", n_bus, res,
                      time.time() - t0)
    return rows


# ---------------------------------------------------------------------------
# Fig. 6 — impact of input event rate (120%..200% of max throughput)
# ---------------------------------------------------------------------------

def fig6_event_rate(quick: bool = False):
    rows = []
    rates = [1.2, 1.4, 1.6, 1.8, 2.0] if not quick else [1.2, 1.8]
    for mult in rates:                                     # Q1 @ moderate mP
        t0 = time.time()
        res = _run([pat.make_q1(3000, num_symbols=10)], _stock(60_000),
                   rate_multiplier=mult)
        rows += _rows("fig6a", "Q1", "rate_pct", int(mult * 100), res,
                      time.time() - t0)
    for mult in rates:                                     # Q3 @ low mP
        t0 = time.time()
        raw = streams.gen_soccer(60_000, p_striker=0.004, p_defend=0.006,
                                 seed=3)
        res = _run([pat.make_q3(any_n=5, window_size=1500)], raw,
                   rate_multiplier=mult, max_any_ids=8)
        rows += _rows("fig6b", "Q3", "rate_pct", int(mult * 100), res,
                      time.time() - t0)
    return rows


# ---------------------------------------------------------------------------
# Fig. 7 — maintaining the latency bound (l_e trace under overload)
# ---------------------------------------------------------------------------

def fig7_latency_bound(quick: bool = False):
    rows = []
    for mult, tag in ((1.2, "R1"), (1.4, "R2")):
        t0 = time.time()
        res = _run([pat.make_q2(6000)], _stock(60_000, seed=2),
                   rate_multiplier=mult, shedders=(eng.SHED_PSPICE,))
        r = res[eng.SHED_PSPICE]
        le = r.result.l_e
        # decimated trace for the report
        dec = le[:: max(1, len(le) // 200)]
        rows.append({
            "figure": "fig7", "query": "Q2", "rate": tag,
            "max_l_e": round(float(le.max()), 4),
            "p99_l_e": round(float(np.percentile(le, 99)), 4),
            "violation_frac": round(float((le > 1.01).mean()), 5),
            "trace_head": [round(float(x), 3) for x in dec[:20]],
            "wall_s": round(time.time() - t0, 1),
        })
    return rows


# ---------------------------------------------------------------------------
# Fig. 8 — impact of the processing-time term (pSPICE vs pSPICE--)
# ---------------------------------------------------------------------------

def fig8_processing_time(quick: bool = False):
    rows = []
    factors = [1, 2, 4, 8, 12, 16] if not quick else [1, 16]
    for f in factors:
        # Q1 and Q2 in ONE multi-query operator; Q1's per-PM match cost is
        # f× Q2's (the paper's tau_Q1/tau_Q2 knob); both weight 1.
        specs = [pat.make_q1(4000, num_symbols=10, proc_cost=float(f)),
                 pat.make_q2(4000, proc_cost=1.0)]
        raw = _stock(60_000, seed=5)
        for use_tau, name in ((True, "pspice"), (False, "pspice--")):
            t0 = time.time()
            res = _run(specs, raw, shedders=(eng.SHED_PSPICE,),
                       use_remaining_time=use_tau)
            r = res[eng.SHED_PSPICE]
            rows.append({
                "figure": "fig8", "variant": name, "tau_factor": f,
                "fn_pct": round(100 * r.fn, 2),
                "match_prob": round(r.match_probability, 4),
                "wall_s": round(time.time() - t0, 1),
            })
    return rows


# ---------------------------------------------------------------------------
# Fig. 9 — shedding overhead + model-build time
# ---------------------------------------------------------------------------

def fig9_overhead(quick: bool = False):
    rows = []
    ws_list = [2000, 4000, 8000] if not quick else [2000]
    for ws in ws_list:
        res = _run([pat.make_q1(ws, num_symbols=10)], _stock(60_000))
        for name, r in res.items():
            # overhead := simulated shed time / total operator busy time
            if name == eng.SHED_EBL:
                shed_time = r.result.ebl_dropped * COST["c_ebl"]
            else:
                shed_time = (r.result.shed_calls * COST["c_shed_base"]
                             + r.result.pms_shed * COST["c_shed_pm"])
            total = float(r.result.l_e.shape[0]) * COST["c_base"] \
                + float(r.result.n_pm.mean()) * COST["c_match"] \
                * r.result.l_e.shape[0]
            rows.append({
                "figure": "fig9a", "query": "Q1", "window_size": ws,
                "shedder": name,
                "overhead_pct": round(100 * shed_time / total, 3),
            })
    # model-build wall time vs window size (value-iteration cost)
    from repro.core import markov, utility
    import jax.numpy as jnp
    for ws in ([6000, 12000, 24000, 32000] if not quick else [6000]):
        m = 11
        rng = np.random.default_rng(0)
        T = rng.random((m, m))
        T /= T.sum(1, keepdims=True)
        T = jnp.asarray(T, jnp.float32)
        R = jnp.asarray(rng.random((m, m)), jnp.float32)
        t0 = time.time()
        ut = utility.build_utility_table(T, R, window_size=ws, bin_size=64)
        ut.table.block_until_ready()
        rows.append({"figure": "fig9b", "window_size": ws,
                     "model_build_s": round(time.time() - t0, 3)})
    return rows


# ---------------------------------------------------------------------------
# Beyond-paper: pSPICE-on-serving benchmark
# ---------------------------------------------------------------------------

def serving_shed(quick: bool = False):
    from repro.serving.scheduler import (SchedulerConfig, run_simulation,
                                         synth_workload)
    rows = []
    rates = [80.0, 120.0, 160.0] if not quick else [120.0]
    for rate in rates:
        for pol in ("pspice", "random", "admission"):
            cfg = SchedulerConfig(policy=pol, max_slots=48, slo=1.5)
            reqs = synth_workload(600 if quick else 1000, rate=rate,
                                  cfg=cfg, seed=3)
            t0 = time.time()
            m = run_simulation(cfg, reqs)
            rows.append({"figure": "serving", "policy": pol, "rate": rate,
                         "goodput": round(m["goodput"], 4),
                         "completed": m["completed"],
                         "evictions": m["evictions"],
                         "wall_s": round(time.time() - t0, 1)})
    return rows
