# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV rows (us_per_call = wall time per processed event/request for the
# benchmark; derived = the figure's headline metric) and dumps full row data
# to results/paper_figures.json.
from __future__ import annotations

import argparse
import json
import os
import sys
import time

from benchmarks import figures


def _emit(name: str, us_per_call: float, derived: str) -> None:
    print(f"{name},{us_per_call:.2f},{derived}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="reduced grids (CI-sized)")
    ap.add_argument("--only", default=None,
                    help="comma-separated figure names")
    ap.add_argument("--out", default="results/paper_figures.json")
    args = ap.parse_args(argv)

    figs = {
        "fig5": figures.fig5_match_probability,
        "fig6": figures.fig6_event_rate,
        "fig7": figures.fig7_latency_bound,
        "fig8": figures.fig8_processing_time,
        "fig9": figures.fig9_overhead,
        "serving": figures.serving_shed,
    }
    if args.only:
        names = args.only.split(",")
        figs = {k: v for k, v in figs.items() if k in names}

    all_rows = {}
    print("name,us_per_call,derived")
    for name, fn in figs.items():
        t0 = time.time()
        rows = fn(quick=args.quick)
        wall = time.time() - t0
        all_rows[name] = rows
        n_units = max(len(rows), 1)
        if name in ("fig5", "fig6"):
            for r in rows:
                tag = f"{r['figure']}:{r['query']}:{r['shedder']}"
                xk = "window_size" if "window_size" in r else (
                    "pattern_size" if "pattern_size" in r else "rate_pct")
                _emit(f"{tag}:{xk}={r[xk]}",
                      1e6 * r["wall_s"] / 60_000,
                      f"FN%={r['fn_pct']} matchP={r['match_prob']}")
        elif name == "fig7":
            for r in rows:
                _emit(f"fig7:{r['rate']}", 1e6 * r["wall_s"] / 60_000,
                      f"max_l_e={r['max_l_e']} viol={r['violation_frac']}")
        elif name == "fig8":
            for r in rows:
                _emit(f"fig8:{r['variant']}:tau={r['tau_factor']}",
                      1e6 * r["wall_s"] / 60_000, f"FN%={r['fn_pct']}")
        elif name == "fig9":
            for r in rows:
                if r["figure"] == "fig9a":
                    _emit(f"fig9a:{r['shedder']}:ws={r['window_size']}",
                          0.0, f"overhead%={r['overhead_pct']}")
                else:
                    _emit(f"fig9b:ws={r['window_size']}", 0.0,
                          f"model_build_s={r['model_build_s']}")
        elif name == "serving":
            for r in rows:
                _emit(f"serving:{r['policy']}:rate={r['rate']}",
                      1e6 * r["wall_s"] / max(r["completed"], 1),
                      f"goodput={r['goodput']}")
        print(f"# {name} total wall: {wall:.1f}s", file=sys.stderr)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(all_rows, f, indent=1)
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
