"""Crash-kill recovery benchmark: the process-level chaos matrix
(DESIGN.md §13).

Every cell of (backend × shedder) runs the seeded supervisor workload
twice: once uninterrupted in-process (the reference), once under the
chaos harness — a subprocess SIGKILLed at a seeded kill site (mid-chunk,
mid-refresh, or mid-snapshot-write, cycled across the grid), then
relaunched to recover from the newest valid snapshot + WAL tail and
finish the stream.  The gates are absolute:

- ``ok_killed``      the armed SIGKILL actually fired (rc == -9);
- ``ok_recovered``   the relaunched child finished the stream;
- ``ok_bitwise``     carry sha256, decoded match sets, semantic
                     telemetry counters and the event count all equal
                     the uninterrupted run — divergence == 0;
- ``ok_torn_rejected`` (snapshot-kill cells) the mid-write kill left a
                     torn file that recovery CRC-rejected in favor of
                     the previous generation.

A snapshot-cadence sweep (in-process crash simulation: abandon the
runtime mid-stream, recover in a fresh one) reports recovery wall time
vs WAL replay length as the cadence coarsens — the knob's cost curve.

Writes BENCH_recovery.json (always, also on failure) and exits 1 on any
gate failure; CI runs ``--quick`` and gates merges on it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time
import traceback

import jax

from repro.cep import engine as eng
from repro import runtime as RT
from repro.runtime import supervisor as SV

BACKENDS = (eng.BACKEND_XLA, eng.BACKEND_PALLAS, eng.BACKEND_PALLAS_BLOCK)
SHEDDERS = (eng.SHED_NONE, eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)

# Seeded kill-point draw ranges per site.  The snapshot site must strike
# the SECOND write so a previous generation exists for the torn-file
# fallback the cell asserts on.
KILL_RANGES = {"chunk": (2, 10), "refresh": (1, 2), "snapshot": (2, 2)}


def make_spec(backend: str, shedder: str, n: int, push: int,
              chunk: int) -> dict:
    return {"backend": backend, "shedder": shedder, "n": n, "push": push,
            "chunk": chunk, "max_pms": 32, "block_events": 16,
            "rate_mult": 3.0, "refresh_every": 4, "snapshot_every": 4,
            "min_observations": 64.0}


def plan_cell_kill(site: str, seed: int) -> RT.KillSwitch:
    """Seeded kill draw via the fault injector — the chaos matrix uses
    the same randomness discipline as the in-process fault matrix."""
    inj = RT.FaultInjector(RT.FaultConfig(kinds=RT.PROCESS_FAULTS,
                                          seed=seed))
    lo, hi = KILL_RANGES[site]
    return inj.plan_kill(site, lo=lo, hi=hi)


def run_cell(backend: str, shedder: str, site: str, spec: dict,
             ref: dict, seed: int) -> dict:
    row: dict = {"cell": f"{backend}/{shedder}", "backend": backend,
                 "shedder": shedder, "kill_site": site}
    try:
        ks = plan_cell_kill(site, seed)
        row["kill_spec"] = ks.spec()
        with tempfile.TemporaryDirectory() as d:
            t0 = time.perf_counter()
            res = SV.Supervisor(d).run(spec, kill=ks.spec())
            row["wall_s"] = time.perf_counter() - t0
        rep = res["report"]
        rec = rep["recovery"]
        row.update(
            attempts=[a["returncode"] for a in res["attempts"]],
            snapshot_chunk=rec["snapshot_chunk"],
            replayed_records=rec["replayed_records"],
            rejected_snapshots=len(rec["rejected_snapshots"]),
            recovery_wall_s=rec["recovery_wall_s"],
            events_processed=rep["events_processed"],
            n_matches=sum(len(m) for m in rep["matches"]),
        )
        row["ok_killed"] = res["killed"]
        row["ok_recovered"] = res["recovered"]
        row["ok_bitwise"] = (
            rep["carry_sha"] == ref["carry_sha"]
            and rep["matches"] == ref["matches"]
            and rep["counters"] == ref["counters"]
            and rep["events_processed"] == ref["events_processed"])
        if site == "snapshot":
            row["ok_torn_rejected"] = row["rejected_snapshots"] >= 1
    except Exception:
        row["ok_no_exception"] = False
        row["traceback"] = traceback.format_exc()
    return row


def cadence_sweep(spec: dict, everies: tuple[int, ...],
                  crash_after_pushes: int = 3) -> list[dict]:
    """In-process crash simulation per snapshot cadence: run
    ``crash_after_pushes`` pushes, abandon the runtime (its disk state is
    exactly what a SIGKILL leaves), recover in a fresh runtime, finish,
    and compare against the uninterrupted run.  Coarser cadences replay
    more WAL records; the rows quantify that recovery-time cost."""
    ref = SV.run_service(spec, persist_dir=None)
    rows = []
    for every in everies:
        s = dict(spec, snapshot_every=every)
        row: dict = {"cell": f"cadence_{every}", "snapshot_every": every}
        try:
            with tempfile.TemporaryDirectory() as d:
                specs, cfg, model, ev = SV.build_workload(s)
                a = SV.MatchRuntime(cfg, model, SV.runtime_config(s, d),
                                    specs=specs)
                n = RT.num_events(ev)
                push = s["push"]
                for st in range(0, crash_after_pushes * push, push):
                    a.push(RT.slice_events(ev, st, min(st + push, n)))
                a.persist.wal.close()
                del a
                b = SV.MatchRuntime(cfg, model, SV.runtime_config(s, d),
                                    specs=specs)
                rec = b.recover_from_disk()
                for st in range(b.persist.wal.next_record_id * push, n,
                                push):
                    b.push(RT.slice_events(ev, st, min(st + push, n)))
                b.flush()
            row.update(replayed_records=rec["replayed_records"],
                       recovery_wall_s=rec["recovery_wall_s"],
                       snapshot_chunk=rec["snapshot_chunk"])
            row["ok_bitwise"] = (
                SV.carry_sha(b) == ref["carry_sha"]
                and SV.semantic_counters(b) == ref["counters"])
        except Exception:
            row["ok_no_exception"] = False
            row["traceback"] = traceback.format_exc()
        rows.append(row)
    return rows


def _gates(row: dict) -> list[str]:
    return [k for k, v in row.items() if k.startswith("ok_") and not v]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="CI-sized run")
    ap.add_argument("--out", default="BENCH_recovery.json")
    args = ap.parse_args(argv)

    n, push, chunk = (1536, 256, 128) if args.quick else (3072, 256, 128)

    out = {"quick": bool(args.quick), "backend": jax.default_backend(),
           "n_events": n, "chunk_size": chunk, "cells": [],
           "cadence_sweep": []}
    t_all = time.time()

    print("cell,kill,replayed,rejected,recovery_s,gates")
    sites = list(RT.KILL_SITES)
    i = 0
    for backend in BACKENDS:
        for shedder in SHEDDERS:
            site = sites[i % len(sites)]
            spec = make_spec(backend, shedder, n, push, chunk)
            try:
                ref = SV.run_service(spec, persist_dir=None)
            except Exception:
                out["cells"].append({
                    "cell": f"{backend}/{shedder}", "kill_site": site,
                    "ok_no_exception": False,
                    "traceback": traceback.format_exc()})
                i += 1
                continue
            row = run_cell(backend, shedder, site, spec, ref, seed=100 + i)
            bad = _gates(row)
            out["cells"].append(row)
            print(f"{row['cell']},{row.get('kill_spec', '?')},"
                  f"{row.get('replayed_records', '-')},"
                  f"{row.get('rejected_snapshots', '-')},"
                  f"{row.get('recovery_wall_s', -1):.3f},"
                  f"{'FAIL:' + '+'.join(bad) if bad else 'pass'}")
            i += 1

    spec = make_spec(eng.BACKEND_XLA, eng.SHED_PSPICE, n, push, chunk)
    for row in cadence_sweep(spec, everies=(2, 4, 8)):
        bad = _gates(row)
        out["cadence_sweep"].append(row)
        print(f"{row['cell']},-,{row.get('replayed_records', '-')},-,"
              f"{row.get('recovery_wall_s', -1):.3f},"
              f"{'FAIL:' + '+'.join(bad) if bad else 'pass'}")

    failures = {r["cell"]: _gates(r)
                for r in out["cells"] + out["cadence_sweep"] if _gates(r)}
    out["failures"] = failures
    out["wall_s_total"] = time.time() - t_all
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out} ({out['wall_s_total']:.1f}s)",
          file=sys.stderr)
    if failures:
        print(f"# RECOVERY GATE FAILURES: {failures}", file=sys.stderr)
        for r in out["cells"] + out["cadence_sweep"]:
            if r.get("traceback"):
                print(r["traceback"], file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
