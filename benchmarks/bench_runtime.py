"""Throughput benchmark for the repro.runtime streaming layer.

Three measurements, written to BENCH_runtime.json (the repo's perf
trajectory — CI uploads it per PR):

  multitenant  (headline)  events/sec of L tenant lanes through the
      vmapped chunked runtime vs the SAME L streams run back-to-back
      through monolithic ``run_engine`` scans.  The vmapped runtime
      collapses L scans into one lane-batched scan, so it must win.
  chunk_sweep   single-lane chunked throughput across chunk sizes vs the
      monolithic scan — the price of host-side control between chunks.
  refresh       multi-tenant throughput with per-lane online model
      refresh on vs off — the cost of staying adapted.

Usage:  PYTHONPATH=src python benchmarks/bench_runtime.py [--quick]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro import runtime as RT

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)
REPEATS = 3  # best-of-N walls (2-core CI boxes are noisy)


def build_workload(num_lanes: int, n_per_lane: int, max_pms: int,
                   gather_stats: bool, shedder: str = eng.SHED_PSPICE,
                   drift: bool = False):
    """L drifting stock streams against one Q1 pattern set."""
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=1.0,
                                gather_stats=gather_stats, shedder=shedder,
                                **COST)
    model = eng.make_model(cp, cfg)
    # Rate ~20% above what the cost model sustains at a mid-size PM pool.
    rate = 1.2 / (cfg.c_base + cfg.c_match * 0.5 * max_pms)
    evs = []
    for lane in range(num_lanes):
        gen = streams.gen_stock_drift if drift else streams.gen_stock
        raw = gen(n_per_lane, num_symbols=50, pattern_symbols=4,
                  p_class=0.05, seed=100 + lane)
        evs.append(streams.classify(specs, raw, rate=rate * (1 + 0.1 * lane),
                                    seed=lane,
                                    rate_end=1.5 * rate if drift else None))
    return specs, cfg, model, evs


def _block(tree):
    jax.block_until_ready(jax.tree.leaves(tree)[0])


def bench_multitenant(num_lanes: int, n_per_lane: int, chunk_size: int,
                      max_pms: int) -> dict:
    specs, cfg, model, evs = build_workload(num_lanes, n_per_lane, max_pms,
                                            gather_stats=False)
    evL = RT.stack(evs)
    mL = RT.broadcast_model(model, num_lanes)
    total = num_lanes * n_per_lane

    # -- baseline: back-to-back monolithic scans, one per tenant ----------
    def run_sequential():
        # Carry init outside the timed region, mirroring the runtime path
        # (MultiTenantRuntime builds its lane carries before its t0).
        carries = [eng.init_carry(cfg, seed=lane)
                   for lane in range(num_lanes)]
        t0 = time.perf_counter()
        for lane in range(num_lanes):
            c, _ = eng.run_engine(cfg, model, evs[lane], carries[lane])
            _block(c)
        return time.perf_counter() - t0

    # -- lane-batched chunked runtime --------------------------------------
    def run_runtime():
        mt = RT.MultiTenantRuntime(
            cfg, mL, num_lanes=num_lanes,
            rt=RT.RuntimeConfig(chunk_size=chunk_size))
        t0 = time.perf_counter()
        mt.push(evL, flush=True)
        return time.perf_counter() - t0, mt

    run_sequential()                    # compile
    run_runtime()                       # compile the lane chunk shapes
    wall_seq = min(run_sequential() for _ in range(REPEATS))
    wall_rt, mt = min((run_runtime() for _ in range(REPEATS)),
                      key=lambda t: t[0])
    agg = mt.telemetry.aggregate()
    return {
        "num_lanes": num_lanes, "events_per_lane": n_per_lane,
        "chunk_size": chunk_size, "total_events": total,
        "events_per_s_sequential": total / wall_seq,
        "events_per_s_multitenant": total / wall_rt,
        "speedup": wall_seq / wall_rt,
        "wall_s_sequential": wall_seq, "wall_s_multitenant": wall_rt,
        "l_e_p99_max": agg["l_e_p99_max"],
        "pms_shed": agg["pms_shed"],
    }


def bench_chunk_sweep(n: int, chunk_sizes, max_pms: int) -> list[dict]:
    _, cfg, model, evs = build_workload(1, n, max_pms, gather_stats=False)
    ev = evs[0]

    def run_mono():
        t0 = time.perf_counter()
        c, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        _block(c)
        return time.perf_counter() - t0

    run_mono()
    wall_mono = min(run_mono() for _ in range(REPEATS))
    rows = [{"chunk_size": 0, "variant": "monolithic",
             "events_per_s": n / wall_mono, "wall_s": wall_mono}]
    for cs in chunk_sizes:
        def run():
            srt = RT.StreamRuntime(cfg, model,
                                   rt=RT.RuntimeConfig(chunk_size=cs))
            t0 = time.perf_counter()
            srt.push(ev, flush=True)
            return time.perf_counter() - t0
        run()
        wall = min(run() for _ in range(REPEATS))
        rows.append({"chunk_size": cs, "variant": "chunked",
                     "events_per_s": n / wall, "wall_s": wall,
                     "overhead_vs_monolithic_pct":
                         100.0 * (wall / wall_mono - 1.0)})
    return rows


def bench_refresh(num_lanes: int, n_per_lane: int, chunk_size: int,
                  max_pms: int, every: int) -> dict:
    specs, cfg, model, evs = build_workload(num_lanes, n_per_lane, max_pms,
                                            gather_stats=True, drift=True)
    rcfg = RT.RefreshConfig(every_chunks=every, min_observations=128.0)
    evL = RT.stack(evs)
    # Widen utility tables up front for BOTH runs so refresh-on and
    # refresh-off share one compiled chunk executable (no retrace noise).
    mL = RT.prepare_model(specs, RT.broadcast_model(model, num_lanes), rcfg)
    total = num_lanes * n_per_lane

    def run(refresh):
        mt = RT.MultiTenantRuntime(
            cfg, mL, num_lanes=num_lanes, specs=specs,
            rt=RT.RuntimeConfig(chunk_size=chunk_size, refresh=refresh))
        t0 = time.perf_counter()
        mt.push(evL, flush=True)
        return time.perf_counter() - t0, mt

    run(None)                           # compile the chunk executable
    run(rcfg)                           # compile the refresh path's jits
    wall_off = min(run(None)[0] for _ in range(REPEATS))
    wall_on, mt = min((run(rcfg) for _ in range(REPEATS)),
                      key=lambda t: t[0])
    return {
        "refresh_every_chunks": every,
        "events_per_s_no_refresh": total / wall_off,
        "events_per_s_refresh": total / wall_on,
        "refresh_overhead_pct": 100.0 * (wall_on / wall_off - 1.0),
        "refreshes_per_lane":
            [s.refresh_count for s in mt.refresh_state],
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI-sized run")
    ap.add_argument("--out", default="BENCH_runtime.json")
    args = ap.parse_args(argv)

    # max_pms=64 on both tiers: multi-tenant consolidation is the
    # many-SMALL-tenants regime — lane-batching amortizes per-op overhead
    # of small PM stores; at much larger stores the sequential scans are
    # already amortized and lane-batching stops paying.
    if args.quick:
        L, n, chunk, max_pms = 4, 4096, 512, 64
        sweep_n, sweep = 8192, (256, 1024)
    else:
        L, n, chunk, max_pms = 8, 16384, 1024, 64
        sweep_n, sweep = 32768, (256, 1024, 4096)

    out = {"quick": bool(args.quick), "num_devices": len(jax.devices()),
           "backend": jax.default_backend()}
    print("name,events_per_s,derived")
    t0 = time.time()
    head = bench_multitenant(L, n, chunk, max_pms)
    out["multitenant"] = head
    print(f"multitenant:L={L},{head['events_per_s_multitenant']:.0f},"
          f"speedup_vs_sequential={head['speedup']:.2f}x")
    out["chunk_sweep"] = bench_chunk_sweep(sweep_n, sweep, max_pms)
    for r in out["chunk_sweep"]:
        tag = r["variant"] if r["chunk_size"] == 0 \
            else f"chunk={r['chunk_size']}"
        print(f"chunk_sweep:{tag},{r['events_per_s']:.0f},"
              f"wall_s={r['wall_s']:.3f}")
    out["refresh"] = bench_refresh(L, n, chunk, max_pms, every=4)
    print(f"refresh:every=4,{out['refresh']['events_per_s_refresh']:.0f},"
          f"overhead={out['refresh']['refresh_overhead_pct']:.1f}%")
    print(f"# total wall: {time.time() - t0:.1f}s", file=sys.stderr)

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"# wrote {args.out}", file=sys.stderr)
    if head["speedup"] <= 1.0:
        print("# WARNING: multi-tenant runtime did not beat sequential "
              "scans", file=sys.stderr)


if __name__ == "__main__":
    main()
