"""Quality-of-results benchmark: the paper-figure sweep + the CI gate.

Runs ``repro.eval.sweep.run_quality_sweep`` — {stock, soccer, bus} ×
{pspice, PM-BL, E-BL} × overload levels on the seeded scenario registry
— and writes:

  BENCH_quality.json        the full grid + the headline table
  results/quality_<ds>.json per-dataset grids incl. degradation curves

Gate (--check): the run FAILS (exit 1) unless the paper's headline
ordering holds — pSPICE's match-set false-negative ratio ≤ PM-BL's and
≤ E-BL's on EVERY dataset at the paper overload level (120%).  Unlike
the throughput benchmarks this gate needs no machine normalization: FN
ratios are determined by the seeded streams and the simulated-time
model, not by wall-clock speed, so --quick CI runs reproduce them
exactly.

Usage:  PYTHONPATH=src python benchmarks/bench_quality.py
            [--quick] [--check] [--out BENCH_quality.json]
            [--results-dir results]
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

from repro.eval import sweep


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="short streams (the per-PR CI configuration)")
    ap.add_argument("--check", action="store_true",
                    help="exit 1 unless the headline ordering holds")
    ap.add_argument("--out", default="BENCH_quality.json")
    ap.add_argument("--results-dir", default=None,
                    help="also write per-dataset quality_<ds>.json here")
    args = ap.parse_args(argv)

    bench = sweep.run_quality_sweep(quick=args.quick,
                                    results_dir=args.results_dir)

    pathlib.Path(args.out).write_text(
        json.dumps(bench, indent=2, sort_keys=True) + "\n")

    print(f"headline (overload x{bench['config']['headline_level']:g}, "
          f"match-set FN ratio vs no-shed ground truth):")
    for ds, cells in bench["headline"].items():
        cols = "  ".join(f"{sh}={fn:.4f}" for sh, fn in cells.items())
        print(f"  {ds:8s} {cols}")
    if bench["violations"]:
        for v in bench["violations"]:
            print(f"VIOLATION: {v}")
    print(f"ordering_ok={bench['ordering_ok']}  -> {args.out}")

    if args.check and not bench["ordering_ok"]:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
