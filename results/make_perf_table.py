"""Build the EXPERIMENTS.md §Perf before/after table from the baseline
(dryrun_v2.jsonl) and hillclimb (hillclimb.jsonl) rows."""
from __future__ import annotations

import json
import sys

from make_report import fmt_s, load  # noqa: E402

CLIMBS = [
    ("H1 qwen train: TP+FSDP → pure FSDP",
     ("qwen1.5-110b", "train_4k", "single"), {"scheme": "fsdp"},
     "collective_s"),
    ("H2 minitron train: attention batch-flip",
     ("minitron-4b", "train_4k", "single"), {"attn_flip": True},
     "compute_s"),
    ("H3 deepseek-v3 decode: 2-D expert sharding",
     ("deepseek-v3-671b", "decode_32k", "single"), {"scheme": "moe2d"},
     "collective_s"),
    ("H4 internlm prefill: triangle flash (baseline=OFF)",
     ("internlm2-1.8b", "prefill_32k", "single"), {"causal_skip": False},
     "compute_s"),
]


def find(rows, key, flags=None):
    out = None
    for r in rows:
        if (r["arch"], r["shape"], r["mesh"]) != key:
            continue
        if flags is not None:
            if all(r.get(k) == v for k, v in flags.items()):
                out = r
        else:
            out = r
    return out


def main():
    base = load(sys.argv[1] if len(sys.argv) > 1
                else "results/dryrun_v2.jsonl")
    climb = load(sys.argv[2] if len(sys.argv) > 2
                 else "results/hillclimb.jsonl")
    print("| climb | term | before | after | Δ | dominant before→after | "
          "useful_ratio |")
    print("|---|---|---|---|---|---|---|")
    for name, key, flags, term in CLIMBS:
        b = find(base, key)
        c = find(climb, key, flags)
        if not b or not c or term not in b or term not in c:
            print(f"| {name} | {term} | — | — | pending | | |")
            continue
        # H4 is inverted: the hillclimb row IS the baseline (skip off).
        if name.startswith("H4"):
            b, c = c, b
        delta = b[term] / max(c[term], 1e-12)
        print(f"| {name} | {term} | {fmt_s(b[term])} | {fmt_s(c[term])} | "
              f"**{delta:.2f}×** | {b.get('dominant')}→{c.get('dominant')} | "
              f"{b.get('useful_ratio', 0):.3f}→{c.get('useful_ratio', 0):.3f} |")
        for t in ("compute_s", "memory_s", "collective_s"):
            if t != term:
                print(f"|   · {t} | | {fmt_s(b.get(t))} | {fmt_s(c.get(t))} "
                      f"| {b.get(t, 0) / max(c.get(t, 1e-12), 1e-12):.2f}× | | |")


if __name__ == "__main__":
    sys.path.insert(0, "results")
    main()
