"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the sweep
jsonl (+ optional hillclimb rows)."""
from __future__ import annotations

import json
import sys


def load(path):
    rows = []
    for line in open(path):
        line = line.strip()
        if not line or line == "DONE":
            continue
        rows.append(json.loads(line))
    # dedupe (arch, shape, mesh) keeping the LAST occurrence (re-runs win)
    seen = {}
    for r in rows:
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    return list(seen.values())


def fmt_s(x):
    if x is None:
        return "—"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}µs"


def dryrun_table(rows):
    out = ["| arch | shape | mesh | chips | status | peak GB/dev | compile |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        mem = r.get("memory_analysis", {})
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r.get('chips', '—')} | {r['status']}"
            f"{(' (' + r.get('reason', '')[:40] + ')') if r['status'] == 'skipped' else ''} | "
            f"{mem.get('peak_gb', 0):.1f} | {r.get('compile_s', '—')}s |")
    return "\n".join(out)


def roofline_table(rows):
    out = ["| arch | shape | compute | memory | collective | dominant | "
           "MODEL_FLOPs/HLO_FLOPs | note |",
           "|---|---|---|---|---|---|---|---|"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != "single" or r["status"] != "ok" \
           or "compute_s" not in r:
            continue
        ratio = r.get("useful_ratio", 0)
        note = ""
        if r["shape"].startswith(("decode", "long")):
            note = "decode: MODEL_FLOPS excl. attention-over-cache"
        out.append(
            f"| {r['arch']} | {r['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"**{r['dominant']}** | {ratio:.3f} | {note} |")
    return "\n".join(out)


def summarize(rows):
    ok = [r for r in rows if r["status"] == "ok"]
    sk = [r for r in rows if r["status"] == "skipped"]
    bad = [r for r in rows if r["status"] not in ("ok", "skipped")]
    return (f"{len(ok)} compiled ok, {len(sk)} skipped (per the "
            f"applicability rules), {len(bad)} failed")


if __name__ == "__main__":
    rows = load(sys.argv[1] if len(sys.argv) > 1
                else "results/dryrun_baseline.jsonl")
    for extra in sys.argv[2:]:
        extras = load(extra)
        merged = {(r["arch"], r["shape"], r["mesh"]): r for r in rows}
        for r in extras:
            merged[(r["arch"], r["shape"], r["mesh"])] = r
        rows = list(merged.values())
    print("## Summary:", summarize(rows))
    print()
    print(dryrun_table(rows))
    print()
    print("## Roofline (single-pod)")
    print(roofline_table(rows))
