"""Durable persistence tests (DESIGN.md §13): snapshot codec, store
rotation + torn-file fallback, write-ahead log, bitwise crash recovery
through the streaming runtime, guard-control rewind, telemetry JSON,
and one REAL SIGKILL through the chaos-harness supervisor.

The load-bearing property: snapshot + WAL-tail replay lands the runtime
bitwise-identical — carry, counters, match sets — to a run that never
died, on every backend/shedder combination sampled here (the full grid
is benchmarks/bench_recovery.py).
"""
import dataclasses
import json
import os
import struct
import zlib

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements-dev.txt; deterministic
    from _hyp_fallback import given, settings, st  # fallback sweeps

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.runtime as RT
from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro.runtime import persist as PS
from repro.runtime import supervisor as SV

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)


def _assert_tree_equal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


def _spec(kind: str) -> pat.PatternSpec:
    return {"q1": lambda: pat.make_q1(window_size=400, num_symbols=4),
            "q2": lambda: pat.make_q2(window_size=300),
            "q3": lambda: pat.make_q3(any_n=3, window_size=200),
            "q4": lambda: pat.make_q4(any_n=3, window_size=120, slide=40),
            }[kind]()


def _randomize(tree, seed: int):
    """Same-shape pytree with seeded random bytes in every leaf — the
    codec must round-trip arbitrary states, not just freshly-inited
    ones."""
    rng = np.random.default_rng(seed)

    def rand(leaf):
        a = np.asarray(leaf)
        if a.dtype == bool:
            return rng.random(a.shape) < 0.5
        if np.issubdtype(a.dtype, np.integer):
            info = np.iinfo(a.dtype)
            return rng.integers(info.min, info.max, a.shape,
                                dtype=a.dtype, endpoint=True)
        return rng.standard_normal(a.shape).astype(a.dtype)

    return jax.tree.map(rand, tree)


# ---------------------------------------------------------------------------
# Snapshot codec: property round-trip + actionable failures
# ---------------------------------------------------------------------------

class TestSnapshotCodec:
    @given(st.integers(9, 61), st.sampled_from(["q1", "q2", "q3", "q4"]),
           st.integers(0, 5))
    @settings(max_examples=8, deadline=None)
    def test_roundtrip_bitwise(self, max_pms, kind, seed):
        """encode → container → parse → decode is bitwise for carry AND
        model pytrees, across odd/even max_pms, all pattern kinds (both
        spawn modes), with every leaf randomized.  Pure codec — no
        engine compile."""
        cp = pat.compile_patterns([_spec(kind)])
        cfg = runner.default_config(cp, max_pms=max_pms, **COST)
        carry = _randomize(eng.init_carry(cfg, seed=0), seed)
        model = _randomize(eng.make_model(cp, cfg), seed + 1)
        ctl = {"wal_next_record": 7, "nested": {"a": [1, 2.5, None]}}
        data = PS.build_snapshot_bytes(3, ctl, {"carry": carry,
                                                "model": model,
                                                "skipped": None})
        header, sections = PS.parse_snapshot_bytes(data)
        assert header["chunk_index"] == 3
        assert header["control"] == ctl
        assert set(sections) == {"carry", "model"}
        _assert_tree_equal(carry,
                           PS.decode_tree(*sections["carry"], carry,
                                          what="carry"), "carry")
        _assert_tree_equal(model,
                           PS.decode_tree(*sections["model"], model,
                                          what="model"), "model")

    @pytest.fixture(scope="class")
    def small(self):
        cp = pat.compile_patterns([_spec("q1")])
        cfg = runner.default_config(cp, max_pms=16, **COST)
        carry = eng.init_carry(cfg, seed=0)
        data = PS.build_snapshot_bytes(0, {"wal_next_record": 0},
                                       {"carry": carry})
        return cp, carry, data

    def test_torn_file_is_corrupt(self, small):
        _, _, data = small
        with pytest.raises(PS.CorruptSnapshotError, match="CRC"):
            PS.parse_snapshot_bytes(data[: len(data) // 2]
                                    + data[: len(data) - len(data) // 2])
        with pytest.raises(PS.CorruptSnapshotError, match="torn"):
            PS.parse_snapshot_bytes(data[:10])

    def test_wrong_magic(self, small):
        _, _, data = small
        with pytest.raises(PS.CorruptSnapshotError, match="magic"):
            PS.parse_snapshot_bytes(b"NOTSNAP!" + data[8:])

    def test_wrong_version_actionable(self, small):
        """A future-version file must fail on VERSION (with both numbers
        in the message), not on CRC — re-sign the tampered body."""
        _, _, data = small
        body = bytearray(data[len(PS.SNAP_MAGIC):-4])
        struct.pack_into("<I", body, 0, PS.SNAP_VERSION + 1)
        tampered = (PS.SNAP_MAGIC + bytes(body)
                    + struct.pack("<I", zlib.crc32(bytes(body))))
        with pytest.raises(PS.CorruptSnapshotError,
                           match=f"version {PS.SNAP_VERSION + 1}"):
            PS.parse_snapshot_bytes(tampered)

    def test_wrong_manifest_actionable(self, small):
        cp, carry, data = small
        _, sections = PS.parse_snapshot_bytes(data)
        other = eng.init_carry(
            runner.default_config(cp, max_pms=32, **COST), seed=0)
        with pytest.raises(PS.ManifestMismatchError, match="different "
                           "config"):
            PS.decode_tree(*sections["carry"], other, what="carry")

    def test_manifest_paths_are_named(self, small):
        _, carry, _ = small
        paths = [e["path"] for e in eng.pytree_manifest(carry)]
        assert ".pms.active" in paths and ".lat_ptr" in paths


# ---------------------------------------------------------------------------
# Store rotation / torn fallback + WAL reopen / truncation
# ---------------------------------------------------------------------------

class TestStoreAndWal:
    def test_rotation_and_torn_fallback(self, tmp_path):
        cp = pat.compile_patterns([_spec("q1")])
        cfg = runner.default_config(cp, max_pms=16, **COST)
        carry = eng.init_carry(cfg, seed=0)
        store = PS.SnapshotStore(str(tmp_path), keep_generations=2)
        for chunk in (1, 2, 3):
            p = store.save(chunk, {"wal_next_record": chunk},
                           {"carry": carry})
        assert len(store.paths()) == 2  # generation 1 pruned
        header, _, meta = store.load_latest()
        assert header["chunk_index"] == 3 and meta["rejected"] == []
        # Tear the newest generation: load falls back to the previous
        # one and records the rejection.
        data = open(p, "rb").read()
        with open(p, "wb") as f:
            f.write(data[: len(data) // 2])
        header, sections, meta = store.load_latest()
        assert header["chunk_index"] == 2
        assert len(meta["rejected"]) == 1
        assert "CRC" in meta["rejected"][0]["error"]
        _assert_tree_equal(carry, PS.decode_tree(*sections["carry"], carry))

    def test_wal_append_reopen_replay(self, tmp_path):
        ev = eng.EventBatch(*[np.arange(4, dtype=np.float32) + i
                              for i in range(len(eng.EventBatch._fields))])
        ev2 = jax.tree.map(lambda x: x * 3, ev)
        wal = PS.WriteAheadLog(str(tmp_path), fsync_every=2)
        assert (wal.append(ev), wal.append(ev2)) == (0, 1)
        wal.close()
        # Reopen resumes ids; a fresh append lands in a NEW segment.
        wal = PS.WriteAheadLog(str(tmp_path))
        assert wal.next_record_id == 2
        assert wal.append(ev) == 2
        wal.close()
        assert len(wal.segments()) == 2
        recs = PS.WriteAheadLog(str(tmp_path)).records_since(1)
        assert [r[0] for r in recs] == [1, 2]
        _assert_tree_equal(ev2, recs[0][1], "record 1")
        _assert_tree_equal(ev, recs[1][1], "record 2")

    def test_truncated_segment_actionable(self, tmp_path):
        ev = eng.EventBatch(*[np.zeros(3, np.float32)
                              for _ in eng.EventBatch._fields])
        wal = PS.WriteAheadLog(str(tmp_path))
        wal.append(ev)
        wal.append(ev)
        seg = wal.segments()[-1][1]
        wal.close()
        data = open(seg, "rb").read()
        with open(seg, "wb") as f:
            f.write(data[:-5])
        with pytest.raises(PS.CorruptSegmentError, match="torn record"):
            PS.WriteAheadLog(str(tmp_path))

    def test_config_validation(self, tmp_path):
        with pytest.raises(ValueError, match="snapshot_every_chunks"):
            PS.PersistConfig(dir=str(tmp_path), snapshot_every_chunks=0)
        with pytest.raises(ValueError, match="keep_generations"):
            PS.PersistConfig(dir=str(tmp_path), keep_generations=0)
        with pytest.raises(ValueError, match="dir"):
            PS.PersistConfig(dir="")


# ---------------------------------------------------------------------------
# Recovery through the streaming runtime: bitwise resume
# ---------------------------------------------------------------------------

N_EVENTS = 1536
PUSH = 256

# Wall-clock aggregate fields are real time, not recovered state.
WALL = SV.WALL_FIELDS


@pytest.fixture(scope="module")
def workload():
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)

    def build(backend, shedder, max_pms=32):
        cfg = runner.default_config(cp, max_pms=max_pms,
                                    latency_bound=0.005, gather_stats=True,
                                    shedder=shedder, backend=backend,
                                    block_events=16, **COST)
        model = eng.make_model(cp, cfg)
        rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)
        raw = streams.gen_stock(N_EVENTS, num_symbols=50,
                                pattern_symbols=4, p_class=0.05, seed=101)
        ev = streams.classify(specs, raw, rate=rate, seed=7)
        return specs, cfg, model, ev

    return build


def _resilient_rt(persist_dir=None, snapshot_every=4):
    return RT.RuntimeConfig(
        chunk_size=128,
        refresh=RT.RefreshConfig(every_chunks=4, min_observations=64.0),
        ingest=RT.IngestConfig(max_queue_events=1 << 15,
                               high_watermark=1 << 13,
                               low_watermark=1 << 11, seed=5),
        ladder=RT.LadderConfig(escalate_streak=2, deescalate_streak=2,
                               latency_bound=0.01),
        guard=RT.GuardConfig(check_every_chunks=1,
                             checkpoint_every_chunks=4),
        persist=None if persist_dir is None else PS.PersistConfig(
            dir=str(persist_dir), snapshot_every_chunks=snapshot_every))


def _push_all(srt, ev, lo=0):
    n = RT.num_events(ev)
    for s in range(lo * PUSH, n, PUSH):
        srt.push(RT.slice_events(ev, s, min(s + PUSH, n)))
    srt.flush()


def _semantic(srt):
    return {k: v for k, v in srt.telemetry.aggregate().items()
            if k not in WALL}


class TestRuntimeRecovery:
    @pytest.mark.parametrize("backend,shedder", [
        (eng.BACKEND_XLA, eng.SHED_PSPICE),
        (eng.BACKEND_PALLAS_BLOCK, eng.SHED_PMBL),
    ])
    def test_crash_resume_bitwise(self, workload, tmp_path, backend,
                                  shedder):
        """Abandon a persist-enabled runtime mid-stream (disk state is
        exactly what SIGKILL leaves), recover in a FRESH runtime, finish
        the stream: carry, counters and event totals must equal the
        uninterrupted run bit for bit — full resilience stack on."""
        specs, cfg, model, ev = workload(backend, shedder)
        clean = RT.StreamRuntime(cfg, model, _resilient_rt(), specs=specs)
        _push_all(clean, ev)

        a = RT.StreamRuntime(cfg, model, _resilient_rt(tmp_path),
                             specs=specs)
        for s in range(0, 3 * PUSH, PUSH):
            a.push(RT.slice_events(ev, s, s + PUSH))
        a.persist.wal.close()
        del a

        b = RT.StreamRuntime(cfg, model, _resilient_rt(tmp_path),
                             specs=specs)
        rep = b.recover_from_disk()
        assert rep["snapshot_chunk"] is not None
        assert b.persist.wal.next_record_id == 3
        _push_all(b, ev, lo=3)
        _assert_tree_equal(clean.carry, b.carry, "recovered carry")
        assert _semantic(clean) == _semantic(b)
        assert clean.events_processed == b.events_processed

    def test_recover_empty_dir_is_noop(self, workload, tmp_path):
        specs, cfg, model, ev = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        srt = RT.StreamRuntime(cfg, model, _resilient_rt(tmp_path),
                               specs=specs)
        rep = srt.recover_from_disk()
        assert rep["snapshot_chunk"] is None
        assert rep["replayed_records"] == 0

    def test_snapshot_requires_persist(self, workload):
        specs, cfg, model, _ = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        srt = RT.StreamRuntime(cfg, model, _resilient_rt(), specs=specs)
        with pytest.raises(ValueError, match="persist"):
            srt.snapshot_now()
        with pytest.raises(ValueError, match="persist"):
            srt.recover_from_disk()

    def test_multitenant_roundtrip(self, workload, tmp_path):
        """Lane-stacked runtime: snapshot + recovery must preserve every
        lane's carry and per-lane queue state bitwise."""
        specs, cfg, model, ev = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        L = 2
        evL = RT.stack([ev, ev])
        mL = RT.broadcast_model(model, L)
        rt_kw = dict(chunk_size=128,
                     guard=RT.GuardConfig(check_every_chunks=1,
                                          checkpoint_every_chunks=2))
        clean = RT.MultiTenantRuntime(
            cfg, mL, num_lanes=L, rt=RT.RuntimeConfig(**rt_kw),
            specs=specs)
        clean.push(evL, flush=True)

        mt = RT.MultiTenantRuntime(
            cfg, RT.broadcast_model(model, L), num_lanes=L,
            rt=RT.RuntimeConfig(persist=PS.PersistConfig(
                dir=str(tmp_path), snapshot_every_chunks=2), **rt_kw),
            specs=specs)
        half = (RT.num_events(evL, axis=1) // 2 // 128) * 128
        mt.push(RT.slice_events(evL, 0, half, axis=1))
        mt.persist.wal.close()
        del mt

        mt2 = RT.MultiTenantRuntime(
            cfg, RT.broadcast_model(model, L), num_lanes=L,
            rt=RT.RuntimeConfig(persist=PS.PersistConfig(
                dir=str(tmp_path), snapshot_every_chunks=2), **rt_kw),
            specs=specs)
        rep = mt2.recover_from_disk()
        assert rep["replayed_records"] >= 0
        mt2.push(RT.slice_events(evL, half, RT.num_events(evL, axis=1),
                                 axis=1), flush=True)
        _assert_tree_equal(clean.carry, mt2.carry, "lane carries")
        assert _semantic(clean) == _semantic(mt2)


# ---------------------------------------------------------------------------
# Guard control rewind (satellite regression)
# ---------------------------------------------------------------------------

class TestGuardControlRewind:
    def test_restore_rewinds_ladder_rung_and_admission(self, workload):
        """Checkpoint while ESCALATED, de-escalate, poison the carry:
        the guard restore must resume at the checkpointed rung with the
        matching standing input-shed fraction — not at the pre-fault
        rung.  (Before control-state checkpointing, restores rewound
        the arrays but left the controllers at post-fault values.)"""
        specs, cfg, model, ev = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        srt = RT.StreamRuntime(cfg, model, _resilient_rt(), specs=specs)
        srt.push(RT.slice_events(ev, 0, 2 * PUSH))

        # Drive the ladder to INPUT_SHED via its own observe path.
        for _ in range(4):
            srt._apply_ladder(srt.ladder.observe(True, srt._chunk_i))
        assert srt.ladder.rung == RT.RUNG_INPUT_SHED
        assert srt.ingest.forced_drop > 0
        srt.guard.save(srt.carry, srt.model, srt._chunk_i,
                       control=srt._control_state(scope="guard"))
        n_transitions = len(srt.ladder.transitions)

        # De-escalate back to normal, then poison the carry.
        for _ in range(4):
            srt._apply_ladder(srt.ladder.observe(False, srt._chunk_i))
        assert srt.ladder.rung == RT.RUNG_NORMAL
        assert srt.ingest.forced_drop == 0.0
        srt.carry = srt.carry._replace(
            sim_time=jnp.full_like(srt.carry.sim_time, jnp.nan))
        viols = srt.guard_now()
        assert viols and srt.guard.restores == 1

        # Rung, streaks and standing admission effects all rewound ...
        assert srt.ladder.rung == RT.RUNG_INPUT_SHED
        assert srt.ingest.forced_drop \
            == srt.rt.ladder.input_shed_frac
        # ... but the transitions LOG is history, not state: the
        # de-escalations stay recorded (ladder/telemetry mirror).
        assert len(srt.ladder.transitions) > n_transitions
        assert len(srt.ladder.transitions) \
            == len(srt.telemetry.events_of("ladder"))

    def test_quarantine_counter_rides_checkpoint(self, workload,
                                                 tmp_path):
        specs, cfg, model, ev = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        srt = RT.StreamRuntime(cfg, model, _resilient_rt(tmp_path),
                               specs=specs)
        srt.push(RT.slice_events(ev, 0, PUSH))
        srt.quarantine_dropped = 17
        srt.snapshot_now()
        b = RT.StreamRuntime(cfg, model, _resilient_rt(tmp_path),
                             specs=specs)
        b.recover_from_disk()
        assert b.quarantine_dropped == 17


# ---------------------------------------------------------------------------
# Telemetry JSON round-trip (satellite)
# ---------------------------------------------------------------------------

class TestTelemetryJson:
    def test_roundtrip(self, workload):
        specs, cfg, model, ev = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        srt = RT.StreamRuntime(cfg, model, _resilient_rt(), specs=specs)
        srt.push(RT.slice_events(ev, 0, 2 * PUSH), flush=True)
        d = srt.telemetry.to_json()
        json.dumps(d)  # must be pure JSON
        back = RT.TelemetryLog.from_json(d)
        assert [dataclasses.asdict(r) for r in back.chunks] \
            == [dataclasses.asdict(r) for r in srt.telemetry.chunks]
        assert [dataclasses.asdict(r) for r in back.events] \
            == [dataclasses.asdict(r) for r in srt.telemetry.events]
        # The aggregate is recomputed, never trusted from the file.
        assert back.aggregate() == srt.telemetry.aggregate()

    def test_aggregate_not_trusted(self, workload):
        specs, cfg, model, ev = workload(eng.BACKEND_XLA, eng.SHED_PSPICE)
        srt = RT.StreamRuntime(cfg, model, _resilient_rt(), specs=specs)
        srt.push(RT.slice_events(ev, 0, PUSH), flush=True)
        d = srt.telemetry.to_json()
        d["aggregate"]["n_events"] = -999
        assert RT.TelemetryLog.from_json(d).aggregate()["n_events"] \
            == srt.telemetry.aggregate()["n_events"]


# ---------------------------------------------------------------------------
# The real thing: SIGKILL a subprocess, restart, bitwise recovery
# ---------------------------------------------------------------------------

class TestSupervisorSigkill:
    def test_sigkill_mid_chunk_recovers_bitwise(self, tmp_path):
        spec = {"backend": eng.BACKEND_XLA, "shedder": eng.SHED_PSPICE,
                "n": 1024, "push": 256, "chunk": 128, "max_pms": 32,
                "rate_mult": 3.0, "refresh_every": 4, "snapshot_every": 3,
                "min_observations": 64.0}
        ref = SV.run_service(spec, persist_dir=None)
        res = SV.Supervisor(str(tmp_path)).run(spec, kill="chunk:3")
        assert res["killed"] and res["recovered"]
        assert res["attempts"][0]["returncode"] == -9
        rep = res["report"]
        assert rep["carry_sha"] == ref["carry_sha"]
        assert rep["matches"] == ref["matches"]
        assert rep["counters"] == ref["counters"]
        assert rep["events_processed"] == ref["events_processed"]
        # Satellite: a real recovery dumps the restored telemetry.
        dump = os.path.join(str(tmp_path), "persist",
                            "telemetry_recovered.json")
        assert os.path.exists(dump)
        with open(dump) as f:
            assert "chunks" in json.load(f)

    def test_kill_switch_env_spec(self, monkeypatch):
        from repro.runtime import faults as FT
        prev = FT.active_kill_switch()
        monkeypatch.setenv(RT.KILL_ENV, "refresh:2")
        try:
            ks = RT.install_kill_from_env()
            assert ks is FT.active_kill_switch()
            assert ks is not None and ks.spec() == "refresh:2"
            assert not ks.pending("chunk")
            assert not ks.pending("refresh")
            assert ks.pending("refresh")
        finally:
            FT.install_kill_switch(prev)

    def test_plan_kill_is_seeded(self):
        draws = []
        for _ in range(2):
            inj = RT.FaultInjector(RT.FaultConfig(
                kinds=RT.PROCESS_FAULTS, seed=11))
            draws.append(inj.plan_kill("chunk", lo=2, hi=9).spec())
        assert draws[0] == draws[1]
        with pytest.raises(ValueError, match="process_kill"):
            RT.FaultInjector(RT.FaultConfig(
                kinds=("burst",), seed=1)).plan_kill("chunk")
