"""The contract checker is LIVE (DESIGN.md §11).

A static-analysis layer that always passes is worse than none, so every
rule family is proven by mutation: reintroduce the legacy sort plans,
drop a donation, leak a static argument — the corresponding rule must
FAIL, and the unmutated build must pass the same rule.
"""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import analysis as A
from repro.analysis import contracts as C
from repro.analysis import pallas_rules as PR
from repro.analysis import rules as R
from repro.analysis import tracing as T
from repro.analysis.driver import _workload, check_all
from repro.cep import engine as eng


@pytest.fixture(scope="module")
def workload():
    return _workload(n=64)


def _artifact(cfg, model, ev, name="cell", compile=True):
    return R.trace_artifact(eng.run_engine, cfg, model, ev,
                            eng.init_carry(cfg), name=name,
                            n_events=ev.ev_class.shape[0],
                            compile=compile)


def _rule(findings, rule):
    out = [f for f in findings if f.rule == rule]
    assert out, f"rule {rule} produced no findings"
    return out


class TestMutationNoSort:
    """The ISSUE's liveness criterion: the legacy sort plans MUST trip
    the no-sort rule, and the default plans must pass it."""

    def test_default_config_passes(self, workload):
        cfg, model, ev = workload
        art = _artifact(cfg, model, ev)
        fs = _rule(R.run_rules(art, C.get_contract("cep.run_engine")),
                   "no-sort")
        assert all(f.ok for f in fs), [f.evidence for f in fs]

    def test_argsort_spawn_trips(self, workload):
        cfg, model, ev = workload
        mut = dataclasses.replace(cfg, spawn_alloc="argsort")
        art = _artifact(mut, model, ev, name="mut[argsort]")
        fs = _rule(R.run_rules(art, C.get_contract("cep.run_engine")),
                   "no-sort")
        assert any(not f.ok for f in fs), "argsort spawn not detected"

    def test_sort_shed_plan_trips(self, workload):
        cfg, model, ev = workload
        mut = dataclasses.replace(cfg, shed_plan="sort")
        art = _artifact(mut, model, ev, name="mut[sortplan]")
        fs = _rule(R.run_rules(art, C.get_contract("cep.run_engine")),
                   "no-sort")
        assert any(not f.ok for f in fs), "sort shed plan not detected"

    def test_waiver_suppresses(self, workload):
        """A waived rule reports a PASSING finding naming the waiver —
        the legacy/oracle escape hatch is visible, not silent."""
        cfg, model, ev = workload
        mut = dataclasses.replace(cfg, shed_plan="sort")
        art = _artifact(mut, model, ev, name="legacy")
        legacy = C.Contract(name="legacy.oracle", waived=("no-sort",))
        fs = _rule(R.run_rules(art, legacy), "no-sort")
        assert all(f.ok for f in fs)
        assert "waived" in fs[0].evidence


class TestMutationDonation:
    """Dropping donate_argnames produces bitwise-identical results with
    double the steady-state memory — exactly what the donation rule
    must catch (input_output_alias table goes empty)."""

    def test_donated_chunk_passes(self, workload):
        cfg, model, ev = workload
        carry = eng.init_carry(cfg)
        piece = jax.tree.map(lambda x: x[:32], ev)
        art = R.trace_artifact(
            eng.run_engine_chunk, cfg, model, piece, carry, jnp.int32(0),
            name="chunk", n_events=32,
            min_alias_pairs=len(jax.tree.leaves(carry)))
        fs = _rule(R.run_rules(art,
                               C.get_contract("cep.run_engine_chunk")),
                   "donation")
        assert all(f.ok for f in fs), [f.evidence for f in fs]

    def test_undonated_chunk_trips(self, workload):
        cfg, model, ev = workload
        carry = eng.init_carry(cfg)
        piece = jax.tree.map(lambda x: x[:32], ev)
        undonated = jax.jit(       # the mutation: donate_argnames dropped
            lambda cfg, model, events, carry, start:
            eng._scan_events_backend(cfg, model, events, carry, start),
            static_argnames=("cfg",))
        art = R.trace_artifact(
            undonated, cfg, model, piece, carry, jnp.int32(0),
            name="mut[undonated]", n_events=32,
            min_alias_pairs=len(jax.tree.leaves(carry)))
        fs = _rule(R.run_rules(art,
                               C.get_contract("cep.run_engine_chunk")),
                   "donation")
        assert any(not f.ok for f in fs), "dropped donation not detected"


class TestMutationRetrace:
    """A static argument that varies per call compiles once per VALUE."""

    def test_leaked_static_arg_trips(self):
        leaky = jax.jit(lambda x, n: x + n, static_argnums=(1,))
        with T.CompileCounter(leaky) as cc:
            for k in range(3):
                leaky(jnp.zeros((4,), jnp.float32), k)
            measured = {"leaky": cc.compiles(leaky)}
        fs = T.retrace_findings(measured, {"leaky": 1})
        assert measured["leaky"] == 3
        assert any(not f.ok for f in fs)
        assert "leaked static" in [f for f in fs if not f.ok][0].evidence

    def test_traced_arg_passes(self):
        tight = jax.jit(lambda x, n: x + n)
        with T.CompileCounter(tight) as cc:
            for k in range(3):
                tight(jnp.zeros((4,), jnp.float32), jnp.int32(k))
            measured = {"tight": cc.compiles(tight)}
        fs = T.retrace_findings(measured, {"tight": 1})
        assert all(f.ok for f in fs), [f.evidence for f in fs]

    def test_count_traces_counts_traces_not_calls(self):
        T.reset_trace_counts()

        @T.count_traces("test.body")
        def body(x):
            return x * 2

        f = jax.jit(body)
        for _ in range(3):
            f(jnp.zeros((4,)))          # one trace, three calls
        assert T.trace_counts()["test.body"] == 1
        f(jnp.zeros((8,)))              # new shape -> second trace
        assert T.trace_counts()["test.body"] == 2

    def test_engine_bodies_are_counted(self):
        """The engine's scan bodies carry their trace counters."""
        assert eng._step_lanes._trace_counter_name == "cep._step_lanes"
        assert eng._run_block._trace_counter_name == "cep._run_block"


class TestPallasRules:
    """BlockSpec geometry checks see the actual kernel launches."""

    def test_xla_backend_has_no_pallas(self, workload):
        cfg, model, ev = workload
        art = _artifact(cfg, model, ev, compile=False)
        assert PR.pallas_calls(art.jaxpr) == []

    def test_pallas_backend_census(self, workload):
        cfg, model, ev = workload
        cfg_p = dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS)
        art = _artifact(cfg_p, model, ev, compile=False)
        calls = PR.pallas_calls(art.jaxpr)
        assert calls, "pallas backend must launch kernels"
        fs = PR.check_pallas_calls(art, C.get_contract("cep.run_engine"))
        assert all(f.ok for f in fs), [f.evidence for f in fs
                                       if not f.ok]

    def test_block_kernel_aliases_checked(self, workload):
        cfg, model, ev = workload
        cfg_b = dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS_BLOCK)
        art = _artifact(cfg_b, model, ev, compile=False)
        fs = PR.check_pallas_calls(art, C.get_contract("cep.run_engine"))
        alias = [f for f in fs if f.rule == "pallas-block-alias"]
        assert alias and all(f.ok for f in alias), \
            [f.evidence for f in alias]

    def test_missing_block_kernel_trips(self, workload):
        """A pallas_block cfg whose jaxpr launches no block kernel is a
        broken dispatch — the checker must not silently pass it."""
        cfg, model, ev = workload
        cfg_b = dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS_BLOCK)
        art = _artifact(cfg, model, ev, compile=False)   # xla jaxpr...
        art.cfg = cfg_b                                  # ...block cfg
        fs = PR.check_pallas_calls(art, C.get_contract("cep.run_engine"))
        bad = [f for f in fs if f.rule == "pallas-block-alias"]
        assert bad and not bad[0].ok


class TestCheckAll:
    """The CI driver end to end on the reduced grid."""

    def test_quick_sweep_green(self, tmp_path):
        out = tmp_path / "ANALYSIS.json"
        result = check_all(quick=True, out=str(out))
        bad = [r for r in result["rows"] if r["status"] != "pass"]
        assert result["ok"], bad
        assert out.exists()
        assert result["cells"] >= 8
        rules_seen = {r["rule"] for r in result["rows"]}
        for must in ("no-sort", "donation", "temp-bytes", "retrace",
                     "pallas-block-alias"):
            assert must in rules_seen, must

    def test_registry_covers_entry_points(self):
        import repro.runtime.lanes       # noqa: F401 — registers lanes
        import repro.runtime.service     # noqa: F401 — registers groups
        names = set(A.registry())
        assert {"cep.run_engine", "cep.run_engine_chunk",
                "runtime.run_chunk_lanes",
                "runtime.run_chunk_lanes_donated",
                "runtime._run_group_single",
                "runtime._run_group_lanes"} <= names


def test_contract_decorator_is_zero_cost():
    """The decorator returns the function object unchanged — no wrapper
    frame on the hot path."""
    marker = object()

    @C.contract("test.zero_cost", max_compiles=1)
    def fn():
        return marker

    assert fn() is marker
    assert C.get_entry("test.zero_cost") is fn
    assert C.get_contract("test.zero_cost").max_compiles == 1


def test_budget_resolution(workload):
    cfg, _, _ = workload
    ctr = C.get_contract("cep.run_engine")
    b = ctr.budget("max_temp_bytes", cfg, 64)
    assert isinstance(b, int) and b > 0
    assert ctr.budget("max_while", cfg, 64) == ctr.max_while


def test_alias_pair_parser():
    head = ("HloModule jit_f, input_output_alias={ {0}: (0, {}, "
            "may-alias), {1}: (3, {}, may-alias) }, "
            "entry_computation_layout={(f32[4])->f32[4]}")
    assert R.hlo_alias_pairs(head + "\nbody") == 2
    assert R.hlo_alias_pairs("HloModule jit_f, entry_layout={x}") == 0


def test_hlo_op_lines_matches_applications_only():
    hlo = "\n".join([
        "  %sort.1 = f32[8]{0} sort(f32[8]{0} %p), dimensions={0}",
        "  %fused_sorted = f32[8]{0} fusion(f32[8]{0} %q)",
        "  %x = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)",
    ])
    lines = R.hlo_op_lines(hlo, "sort")
    assert len(lines) == 1 and "sort(" in lines[0]
