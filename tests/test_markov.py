"""Unit + property tests for the pSPICE Markov machinery (paper §III-C)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements-dev.txt; deterministic
    from _hyp_fallback import given, settings, st  # fallback sweeps

from repro.core import markov, overload, utility


def _random_stats(rng, m):
    stats = markov.TransitionStats.zeros(m)
    n = 200
    s = jnp.asarray(rng.integers(0, m, n), jnp.int32)
    sn = jnp.asarray(np.minimum(s + rng.integers(0, 2, n), m - 1), jnp.int32)
    t = jnp.asarray(rng.random(n), jnp.float32)
    return markov.add_observations(stats, s, sn, t, jnp.ones(n, bool))


class TestTransitionMatrix:
    def test_rows_are_distributions(self):
        stats = _random_stats(np.random.default_rng(0), 5)
        T = markov.estimate_transition_matrix(stats)
        np.testing.assert_allclose(np.asarray(T.sum(1)), 1.0, atol=1e-5)
        assert (np.asarray(T) >= 0).all()

    def test_final_state_absorbing(self):
        stats = _random_stats(np.random.default_rng(1), 4)
        T = markov.estimate_transition_matrix(stats)
        np.testing.assert_allclose(np.asarray(T[-1]),
                                   [0, 0, 0, 1], atol=1e-6)

    def test_unseen_state_self_loops(self):
        stats = markov.TransitionStats.zeros(3)
        stats = markov.add_observations(
            stats, jnp.array([0]), jnp.array([1]), jnp.array([1.0]),
            jnp.array([True]))
        T = markov.estimate_transition_matrix(stats)
        assert float(T[1, 1]) == 1.0  # state 1 never observed

    def test_masked_observations_ignored(self):
        stats = markov.TransitionStats.zeros(3)
        stats = markov.add_observations(
            stats, jnp.array([0, 0]), jnp.array([1, 2]),
            jnp.array([1.0, 1.0]), jnp.array([True, False]))
        assert float(stats.counts[0, 2]) == 0.0


class TestCompletionProbability:
    @given(st.integers(2, 6), st.integers(1, 5), st.integers(1, 4))
    @settings(max_examples=20, deadline=None)
    def test_matches_matrix_power_oracle(self, m, num_bins, bin_size):
        rng = np.random.default_rng(m * 100 + num_bins)
        T = rng.random((m, m))
        T /= T.sum(1, keepdims=True)
        P = markov.completion_probability_table(jnp.asarray(T, jnp.float32),
                                                num_bins, bin_size)
        for j in range(num_bins):
            oracle = markov.np_completion_probability(T, (j + 1) * bin_size)
            np.testing.assert_allclose(np.asarray(P[j]), oracle, atol=2e-4)

    def test_monotone_in_horizon_with_absorbing_final(self):
        # With an absorbing final state, completion prob can only grow with
        # the number of remaining events.
        stats = _random_stats(np.random.default_rng(2), 5)
        T = markov.estimate_transition_matrix(stats)
        P = markov.completion_probability_table(T, 8, 2)
        assert bool(jnp.all(P[1:] >= P[:-1] - 1e-6))


class TestRemainingTime:
    @given(st.integers(2, 5), st.integers(1, 12))
    @settings(max_examples=20, deadline=None)
    def test_matches_value_iteration_oracle(self, m, rw):
        rng = np.random.default_rng(m * 31 + rw)
        T = rng.random((m, m))
        T /= T.sum(1, keepdims=True)
        T[-1] = 0
        T[-1, -1] = 1
        R = rng.random((m, m))
        tau = markov.remaining_time_table(jnp.asarray(T, jnp.float32),
                                          jnp.asarray(R, jnp.float32),
                                          num_bins=rw, bin_size=1)
        oracle = markov.np_remaining_time(T, R, rw)
        np.testing.assert_allclose(np.asarray(tau[-1]), oracle, rtol=1e-4,
                                   atol=1e-4)

    def test_completed_pm_needs_no_time(self):
        stats = _random_stats(np.random.default_rng(3), 4)
        T = markov.estimate_transition_matrix(stats)
        R = markov.estimate_reward_matrix(stats)
        tau = markov.remaining_time_table(T, R, 6, 4)
        np.testing.assert_allclose(np.asarray(tau[:, -1]), 0.0, atol=1e-6)

    def test_nonnegative_and_monotone(self):
        stats = _random_stats(np.random.default_rng(4), 4)
        T = markov.estimate_transition_matrix(stats)
        R = markov.estimate_reward_matrix(stats)
        tau = markov.remaining_time_table(T, R, 6, 4)
        assert (np.asarray(tau) >= -1e-6).all()
        assert bool(jnp.all(tau[1:] >= tau[:-1] - 1e-5))


class TestUtilityTable:
    def test_shape_and_lookup(self):
        stats = _random_stats(np.random.default_rng(5), 4)
        T = markov.estimate_transition_matrix(stats)
        R = markov.estimate_reward_matrix(stats)
        ut = utility.build_utility_table(T, R, window_size=64, bin_size=8,
                                         weight=2.0)
        assert ut.table.shape == (8, 4)
        u = utility.lookup_utility(ut.table, 8, jnp.array([1, 2]),
                                   jnp.array([8, 64]))
        assert u.shape == (2,) and bool(jnp.isfinite(u).all())

    def test_weight_scales_utility(self):
        stats = _random_stats(np.random.default_rng(6), 4)
        T = markov.estimate_transition_matrix(stats)
        R = markov.estimate_reward_matrix(stats)
        u1 = utility.build_utility_table(T, R, 32, 4, weight=1.0).table
        u3 = utility.build_utility_table(T, R, 32, 4, weight=3.0).table
        np.testing.assert_allclose(np.asarray(u3), 3 * np.asarray(u1),
                                   rtol=1e-5)

    def test_pspice_minus_ignores_time(self):
        """pSPICE-- (Fig. 8 ablation): utility independent of rewards."""
        stats = _random_stats(np.random.default_rng(7), 4)
        T = markov.estimate_transition_matrix(stats)
        R1 = markov.estimate_reward_matrix(stats)
        u_a = utility.build_utility_table(T, R1, 32, 4,
                                          use_remaining_time=False).table
        u_b = utility.build_utility_table(T, R1 * 17.0, 32, 4,
                                          use_remaining_time=False).table
        np.testing.assert_allclose(np.asarray(u_a), np.asarray(u_b),
                                   rtol=1e-5)

    @given(st.integers(1, 300))
    @settings(max_examples=25, deadline=None)
    def test_interpolation_between_bins(self, rw):
        table = jnp.asarray(np.random.default_rng(8).random((10, 4)),
                            jnp.float32)
        u = utility.lookup_utility(table, 32, jnp.array([2]),
                                   jnp.array([rw]))
        lo, hi = float(table[:, 2].min()), float(table[:, 2].max())
        assert lo - 1e-5 <= float(u[0]) <= hi + 1e-5


class TestRetraining:
    def test_drift_detection(self):
        stats = _random_stats(np.random.default_rng(9), 4)
        T = markov.estimate_transition_matrix(stats)
        assert not bool(markov.needs_retraining(T, T))
        T2 = jnp.roll(T, 1, axis=1)
        assert bool(markov.needs_retraining(T, T2))


class TestOverloadDetector:
    def test_fit_recovers_linear_model(self):
        n = jnp.arange(1, 500, dtype=jnp.float32)
        lat = 3e-4 * n + 0.01
        m = overload.fit_latency_model(n, lat)
        assert int(m.kind) == overload.LINEAR
        np.testing.assert_allclose(float(m.a), 3e-4, rtol=1e-3)

    def test_fit_prefers_nlogn_when_true(self):
        n = jnp.arange(1, 500, dtype=jnp.float32)
        lat = 1e-4 * n * jnp.log2(n + 1) + 0.01
        m = overload.fit_latency_model(n, lat)
        assert int(m.kind) == overload.NLOGN

    @given(st.floats(1.0, 1e4))
    @settings(max_examples=25, deadline=None)
    def test_inverse_roundtrip(self, n):
        for kind in (overload.LINEAR, overload.NLOGN):
            m = overload.LatencyModel(a=jnp.float32(2e-4),
                                      b=jnp.float32(0.01),
                                      kind=jnp.int32(kind))
            got = float(overload.invert_latency(
                m, overload.predict_latency(m, jnp.float32(n))))
            assert abs(got - n) / n < 1e-2

    def test_algorithm1_rho(self):
        """Alg. 1: rho drops exactly to the sustainable PM count."""
        f = overload.LatencyModel(a=jnp.float32(1e-3), b=jnp.float32(0.0),
                                  kind=jnp.int32(overload.LINEAR))
        g = overload.LatencyModel(a=jnp.float32(0.0), b=jnp.float32(0.1),
                                  kind=jnp.int32(overload.LINEAR))
        # l_q=0.4, n_pm=1000 → l_p=1.0, l_e+l_s=1.5 > LB=1.0
        dec = overload.detect_overload(f, g, jnp.float32(0.4),
                                       jnp.int32(1000), 1.0)
        assert bool(dec.shed)
        # l'_p = 1.0-0.4-0.1 = 0.5 → n' = 500 → rho = 500
        assert int(dec.rho) == 500

    def test_no_shed_when_under_bound(self):
        f = overload.LatencyModel(a=jnp.float32(1e-6), b=jnp.float32(0.0),
                                  kind=jnp.int32(overload.LINEAR))
        dec = overload.detect_overload(f, f, jnp.float32(0.0),
                                       jnp.int32(10), 1.0)
        assert not bool(dec.shed) and int(dec.rho) == 0
