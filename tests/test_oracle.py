"""Differential testing: the vectorized engine vs the NumPy oracle.

``repro.eval.oracle`` is an independent event-at-a-time implementation
of the operator semantics (DESIGN.md §9).  This suite proves the fast
engine equals it:

  1. NO-SHED EXACTNESS (the acceptance bar): 50 generated scenarios —
     random small PatternSpecs + random event streams — where the
     engine's match set equals the oracle's EXACTLY, for backend="xla"
     and "pallas", monolithic ``run_engine`` and chunked
     ``run_engine_chunk`` (ragged chunk sizes included).
  2. SHEDDER EXACTNESS: with the literal sort plan pinned
     (``shed_plan="sort"``), every shedder (pspice / PM-BL / E-BL)
     reproduces the oracle's match set, shed counters and f32 latency
     trace bit-for-bit on seeded overloaded streams.
  3. PROPERTY FORM: the same no-shed equality as a hypothesis property
     over seeds and pattern-family choices (deterministic fallback
     sweep when hypothesis isn't installed).

All generated scenarios share ONE static EngineConfig (shapes are
padded to fixed P/M/C/N), so the whole suite compiles each entry point
once per backend — scenario randomness lives in the model arrays and
the event streams, never in the compiled program.
"""
import dataclasses

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements-dev.txt; deterministic
    from _hyp_fallback import given, settings, st  # fallback sweeps

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro.eval import oracle as orc
from repro import runtime as RT

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)

# Fixed padded shapes: every generated scenario compiles into the same
# executables (P patterns, M states, C classes, N PM slots).
P, M, C, N_PMS, A, K = 2, 8, 4, 16, 6, 4
N_EVENTS = 256

FAMILIES = ("seq", "seq_bind", "seq_any", "slide_any")


def _random_spec(rng, family=None) -> pat.PatternSpec:
    """A random small PatternSpec within the padded shape budget."""
    family = family if family is not None else FAMILIES[
        int(rng.integers(len(FAMILIES)))]
    ws = int(rng.integers(20, 140))
    if family in ("seq", "seq_bind"):
        length = int(rng.integers(2, 5))                 # states <= 5 <= M
        seq = [int(rng.integers(1, C + 1)) for _ in range(length)]
        return pat.seq_pattern(f"{family}", seq, num_classes=C,
                               window_size=ws,
                               uses_binding=(family == "seq_bind"))
    any_n = int(rng.integers(2, 5))                      # states <= 6 <= M
    if family == "seq_any":
        return pat.seq_any_pattern("seq_any", any_n=any_n, window_size=ws)
    slide = int(rng.integers(10, 50))
    return pat.any_pattern("slide_any", any_n=any_n, window_size=ws,
                           slide=slide)


def _compile_padded(specs) -> pat.CompiledPatterns:
    """compile_patterns with trans padded to the FIXED (M, C+1) shape so
    every scenario shares one jit cache entry."""
    trans = np.stack([pat.build_transition_table(s, M, C) for s in specs])
    return pat.CompiledPatterns(
        specs=tuple(specs), trans=trans,
        kind=np.array([s.kind for s in specs], np.int32),
        spawn_mode=np.array([s.spawn_mode for s in specs], np.int32),
        window_size=np.array([s.window_size for s in specs], np.int32),
        slide=np.array([max(s.slide, 1) for s in specs], np.int32),
        final_state=np.array([s.final_state for s in specs], np.int32),
        weight=np.array([s.weight for s in specs], np.float32),
        uses_binding=np.array([s.uses_binding for s in specs], bool),
        proc_cost=np.array([s.proc_cost for s in specs], np.float32),
        spawn_counts=np.array([s.any_spawn_counts for s in specs], bool),
    )


def _base_cfg(shedder=eng.SHED_NONE) -> eng.EngineConfig:
    return eng.EngineConfig(
        num_patterns=P, max_states=M, max_classes=C, max_pms=N_PMS,
        max_any_ids=A, ring_size=K, latency_bound=0.01,
        emit_matches=True, shedder=shedder, **COST)


def _random_events(rng, n=N_EVENTS) -> eng.EventBatch:
    """A random event stream: dense enough in matchable classes, opens,
    ids and bindings that spawning, advancing, completion, expiry and
    store overflow all occur."""
    cls = np.where(rng.random((n, P)) < 0.4,
                   rng.integers(1, C + 1, size=(n, P)), 0).astype(np.int32)
    opens = (rng.random((n, P)) < 0.15)
    bind = rng.integers(-1, 3, size=(n, P)).astype(np.int32)
    ev_id = rng.integers(0, 8, size=n).astype(np.int32)
    rate = 1.0 / (COST["c_base"] + COST["c_match"] * 0.3 * N_PMS)
    return eng.EventBatch(
        ev_class=jnp.asarray(cls), ev_bind=jnp.asarray(bind),
        ev_open=jnp.asarray(opens), ev_id=jnp.asarray(ev_id),
        ev_rand=jnp.asarray(rng.random(n), dtype=jnp.float32),
        ebl_raw=jnp.asarray(rng.random(n), dtype=jnp.float32),
        arrival=jnp.asarray(np.arange(n) / rate, dtype=jnp.float32))


def _scenario(seed, families=None):
    rng = np.random.default_rng(seed)
    fams = [None, None] if families is None else list(families)
    specs = [_random_spec(rng, f) for f in fams]
    cp = _compile_padded(specs)
    cfg = _base_cfg()
    model = eng.make_model(cp, cfg)
    return cfg, model, _random_events(rng)


def _assert_matches_oracle(cfg, model, ev, o, what):
    """Engine (all three backends × monolithic/chunked) == oracle,
    exactly.  The block backend runs at the default W=32 here; the W
    grid {1, 8, 32, 128} is swept against xla on these same scenarios in
    tests/test_block_backend.py."""
    for backend in (eng.BACKEND_XLA, eng.BACKEND_PALLAS,
                    eng.BACKEND_PALLAS_BLOCK):
        cfg_b = dataclasses.replace(cfg, backend=backend)
        carry, outs = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
        tag = f"{what}/{backend}"
        assert eng.match_sets(outs) == o.matches, tag
        np.testing.assert_array_equal(
            np.asarray(carry.complex_count), o.complex_count, tag)
        np.testing.assert_array_equal(
            np.asarray(carry.pms_created), o.pms_created, tag)
        assert float(carry.overflow) == o.overflow, tag
        np.testing.assert_array_equal(
            np.asarray(outs.l_e), o.l_e, f"{tag} l_e")

        # chunked (ragged: 100 does not divide 256) replays the same run
        carry_c = eng.init_carry(cfg_b)
        found = [set() for _ in range(P)]
        for start, piece in RT.iter_chunks(ev, 100):
            carry_c, outs_c = eng.run_engine_chunk(
                cfg_b, model, piece, carry_c, jnp.int32(start))
            for p, s in enumerate(eng.match_sets(outs_c, start=start)):
                found[p] |= s
        assert found == o.matches, f"{tag}/chunked"
        np.testing.assert_array_equal(
            np.asarray(carry_c.complex_count), o.complex_count,
            f"{tag}/chunked")
        assert float(carry_c.overflow) == o.overflow, f"{tag}/chunked"


class TestDifferentialNoShed:
    """Acceptance bar: >= 50 generated scenarios, exact equality on both
    backends, monolithic and chunked."""

    @pytest.mark.parametrize("seed", range(50))
    def test_generated_scenario_equals_oracle(self, seed):
        cfg, model, ev = _scenario(seed)
        o = orc.run_oracle(cfg, model, ev)
        # The scenarios must exercise real behavior, not vacuous streams.
        assert o.pms_created.sum() > 0, "scenario spawned nothing"
        _assert_matches_oracle(cfg, model, ev, o, f"seed={seed}")


class TestDifferentialShedders:
    """With the literal sort-based Algorithm 2 pinned, every shedder
    reproduces the oracle exactly on seeded overloaded streams —
    including the shed counters and the f32 simulated-latency trace."""

    @staticmethod
    def _fixture(name, shedder, seed=0):
        specs = [pat.make_q1(window_size=400, num_symbols=4) if name == "q1"
                 else pat.make_q4(any_n=3, window_size=120, slide=40)]
        cp = pat.compile_patterns(specs)
        cfg = runner.default_config(
            cp, max_pms=48, latency_bound=0.005, shedder=shedder,
            emit_matches=True, shed_plan="sort", **COST)
        model = eng.make_model(cp, cfg)
        rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)
        raw = streams.gen_stock(600, num_symbols=50, pattern_symbols=4,
                                p_class=0.05, seed=100 + seed)
        ev = streams.classify(specs, raw, rate=rate, seed=seed)
        return cfg, model, ev

    @pytest.mark.parametrize("backend", [eng.BACKEND_XLA,
                                         eng.BACKEND_PALLAS_BLOCK])
    @pytest.mark.parametrize("name", ["q1", "q4"])
    @pytest.mark.parametrize("shedder", [eng.SHED_NONE, eng.SHED_PSPICE,
                                         eng.SHED_PMBL, eng.SHED_EBL])
    def test_shedder_run_equals_oracle(self, name, shedder, backend):
        cfg, model, ev = self._fixture(name, shedder)
        cfg = dataclasses.replace(cfg, backend=backend)
        carry, outs = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        o = orc.run_oracle(cfg, model, ev, seed=0)
        tag = f"{name}/{shedder}"
        if shedder in (eng.SHED_PSPICE, eng.SHED_PMBL):
            assert o.pms_shed > 0, f"{tag}: fixture must shed"
        if shedder == eng.SHED_EBL:
            assert o.ebl_dropped > 0, f"{tag}: fixture must drop"
        assert eng.match_sets(outs) == o.matches, tag
        np.testing.assert_array_equal(np.asarray(carry.complex_count),
                                      o.complex_count, tag)
        np.testing.assert_array_equal(np.asarray(carry.pms_created),
                                      o.pms_created, tag)
        assert float(carry.pms_shed) == o.pms_shed, tag
        assert float(carry.shed_calls) == o.shed_calls, tag
        assert float(carry.overflow) == o.overflow, tag
        assert float(carry.ebl_dropped) == o.ebl_dropped, tag
        np.testing.assert_array_equal(np.asarray(outs.l_e), o.l_e,
                                      f"{tag} l_e")
        np.testing.assert_array_equal(np.asarray(outs.shed), o.shed, tag)
        np.testing.assert_array_equal(np.asarray(outs.dropped), o.dropped,
                                      tag)


class TestDifferentialSheddersOverload:
    """The overload axis against the NumPy oracle: spawn-heavy streams at
    1.2/1.4/1.6× service rate with a tight bound, so Algorithm 2 fires
    many times per block.  The sort plan is pinned (the oracle implements
    the literal argsort Algorithm 2), which also routes ``pallas_block``
    onto the legacy replay driver — the fused kernel requires the
    threshold plan — so this doubles as the replay path's oracle pin."""

    @staticmethod
    def _fixture(shedder, mult, seed=0):
        specs = [pat.make_q1(window_size=400, num_symbols=4)]
        cp = pat.compile_patterns(specs)
        cfg = runner.default_config(
            cp, max_pms=48, latency_bound=0.001, shedder=shedder,
            emit_matches=True, shed_plan="sort", **COST)
        model = eng.make_model(cp, cfg)
        rate = mult * 3.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)
        raw = streams.gen_stock(300, num_symbols=50, pattern_symbols=4,
                                p_class=0.5, seed=100 + seed)
        ev = streams.classify(specs, raw, rate=rate, seed=seed)
        return cfg, model, ev

    @pytest.mark.parametrize("mult", (1.2, 1.4, 1.6))
    @pytest.mark.parametrize("shedder", [eng.SHED_PSPICE, eng.SHED_PMBL])
    def test_overloaded_run_equals_oracle(self, shedder, mult):
        cfg, model, ev = self._fixture(shedder, mult)
        o = orc.run_oracle(cfg, model, ev, seed=0)
        assert o.shed_calls >= 8, \
            f"fixture must fire repeatedly, got {o.shed_calls}"
        for backend in (eng.BACKEND_XLA, eng.BACKEND_PALLAS_BLOCK):
            cfg_b = dataclasses.replace(cfg, backend=backend)
            carry, outs = eng.run_engine(cfg_b, model, ev,
                                         eng.init_carry(cfg_b))
            tag = f"{shedder}/x{mult}/{backend}"
            assert eng.match_sets(outs) == o.matches, tag
            assert float(carry.pms_shed) == o.pms_shed, tag
            assert float(carry.shed_calls) == o.shed_calls, tag
            np.testing.assert_array_equal(np.asarray(carry.complex_count),
                                          o.complex_count, tag)
            np.testing.assert_array_equal(np.asarray(outs.l_e), o.l_e,
                                          f"{tag} l_e")
            np.testing.assert_array_equal(np.asarray(outs.shed), o.shed,
                                          tag)


class TestDifferentialProperty:
    """The no-shed equality as a property over generated scenarios."""

    @given(st.integers(0, 2**20),
           st.sampled_from(FAMILIES), st.sampled_from(FAMILIES))
    @settings(max_examples=12, deadline=None)
    def test_property_engine_equals_oracle(self, seed, fam_a, fam_b):
        cfg, model, ev = _scenario(seed, families=(fam_a, fam_b))
        o = orc.run_oracle(cfg, model, ev)
        _assert_matches_oracle(cfg, model, ev, o,
                               f"prop seed={seed} {fam_a}+{fam_b}")
