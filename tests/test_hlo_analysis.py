"""launch.hlo_analysis parsing units (DESIGN.md §9, §11).

parse_collectives feeds both the launch roofline and the analysis rule
engine, so its regexes get canned-HLO unit coverage here: pair vs list
replica-group forms, -start/-done dedup, tuple result types.  The
analyze() per-device-memory term is checked against memory_analysis()
directly — outputs must be INCLUDED net of donated aliasing (the
``* 0`` bug that silently zeroed them).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch import hlo_analysis as HA


class TestParseCollectives:
    def test_pair_form_replica_groups(self):
        # all-gather over groups of 4: wire factor (g-1)/g on the
        # RESULT bytes (128*256*4 = 131072).
        line = ("  %ag.1 = f32[128,256]{1,0} all-gather(f32[128,64]{1,0} "
                "%p0), replica_groups=[2,4], dimensions={1}")
        st = HA.parse_collectives(line)
        assert st.count_by_kind["all-gather"] == 1
        expected = 128 * 256 * 4 * (4 - 1) / 4
        assert st.bytes_by_kind["all-gather"] == pytest.approx(expected)

    def test_list_form_replica_groups(self):
        # Explicit groups {{0,1},{2,3}}: g=2, all-reduce factor 2(g-1)/g.
        line = ("  %ar.3 = f32[1024]{0} all-reduce(f32[1024]{0} %x), "
                "replica_groups={{0,1},{2,3}}, to_apply=%add")
        st = HA.parse_collectives(line)
        expected = 1024 * 4 * 2.0 * (2 - 1) / 2
        assert st.bytes_by_kind["all-reduce"] == pytest.approx(expected)

    def test_start_done_counted_once(self):
        hlo = "\n".join([
            "  %ar-start.1 = f32[512]{0} all-reduce-start(f32[512]{0} "
            "%p), replica_groups=[1,8], to_apply=%add",
            "  %ar-done.1 = f32[512]{0} all-reduce-done(f32[512]{0} "
            "%ar-start.1)",
        ])
        st = HA.parse_collectives(hlo)
        assert st.count_by_kind["all-reduce"] == 1
        expected = 512 * 4 * 2.0 * (8 - 1) / 8
        assert st.bytes_by_kind["all-reduce"] == pytest.approx(expected)

    def test_tuple_result_sums_elements(self):
        line = ("  %a2a = (f32[64]{0}, f32[64]{0}) all-to-all("
                "f32[64]{0} %a, f32[64]{0} %b), replica_groups=[1,2], "
                "dimensions={0}")
        st = HA.parse_collectives(line)
        expected = 2 * 64 * 4 * (2 - 1) / 2
        assert st.bytes_by_kind["all-to-all"] == pytest.approx(expected)

    def test_non_collective_lines_ignored(self):
        hlo = "\n".join([
            "  %x = f32[8]{0} add(f32[8]{0} %a, f32[8]{0} %b)",
            "  %allgatherish = f32[8]{0} fusion(f32[8]{0} %c)",
        ])
        st = HA.parse_collectives(hlo)
        assert st.total_bytes == 0


class TestParseShapeBytes:
    def test_single_and_tuple(self):
        assert HA.parse_shape_bytes("f32[128,64]{1,0}") == 128 * 64 * 4
        assert HA.parse_shape_bytes(
            "(s32[16]{0}, pred[16]{0})") == 16 * 4 + 16
        assert HA.parse_shape_bytes("scalar f32[]") == 4
        assert HA.parse_shape_bytes("no shapes here") == 0


class TestAnalyzePerDeviceMem:
    def test_outputs_counted_net_of_aliasing(self):
        """per_device_mem = args + outputs - aliased + temps: outputs
        are INCLUDED (the old `* 0` silently dropped them) but donated
        aliases aren't double-counted."""
        f = jax.jit(lambda x: (x + 1.0, jnp.sum(x)), donate_argnums=0)
        x = jnp.zeros((4096,), jnp.float32)
        compiled = f.lower(x).compile()
        roof = HA.analyze(compiled, chips=1)
        mem = compiled.memory_analysis()
        expected = (mem.argument_size_in_bytes
                    + mem.output_size_in_bytes
                    - mem.alias_size_in_bytes
                    + mem.temp_size_in_bytes)
        assert roof.per_device_mem == expected
        # The donated 16 KiB x is reused for the output: net must be
        # strictly below the double-counted sum but still include the
        # non-aliased output scalar.
        assert roof.per_device_mem < (mem.argument_size_in_bytes
                                      + mem.output_size_in_bytes
                                      + mem.temp_size_in_bytes)
        assert mem.output_size_in_bytes > 0

    def test_undonated_outputs_fully_counted(self):
        g = jax.jit(lambda x: x * 2.0)
        x = jnp.ones((1024,), jnp.float32)
        compiled = g.lower(x).compile()
        roof = HA.analyze(compiled, chips=1)
        mem = compiled.memory_analysis()
        assert np.isclose(roof.per_device_mem,
                          mem.argument_size_in_bytes
                          + mem.output_size_in_bytes
                          + mem.temp_size_in_bytes
                          - mem.alias_size_in_bytes)
        assert roof.per_device_mem >= mem.output_size_in_bytes
