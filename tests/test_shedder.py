"""Tests for the load shedders (paper §III-F / §IV-A baselines)."""
import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements-dev.txt; deterministic
    from _hyp_fallback import given, settings, st  # fallback sweeps

from repro.core import shedder


class TestDropLowestUtility:
    @given(st.integers(0, 64), st.integers(1, 64))
    @settings(max_examples=30, deadline=None)
    def test_drops_exactly_rho_of_active(self, rho, n_active):
        N = 64
        rng = np.random.default_rng(rho * 97 + n_active)
        active = np.zeros(N, bool)
        active[rng.choice(N, n_active, replace=False)] = True
        u = jnp.asarray(rng.random(N), jnp.float32)
        u = jnp.where(jnp.asarray(active), u, jnp.inf)
        new = shedder.drop_lowest_utility(jnp.asarray(active), u,
                                          jnp.int32(rho))
        dropped = n_active - int(new.sum())
        assert dropped == min(rho, n_active)

    def test_drops_the_lowest(self):
        active = jnp.ones(6, bool)
        u = jnp.array([5., 1., 3., 0.5, 2., 4.])
        new = shedder.drop_lowest_utility(active, u, jnp.int32(3))
        np.testing.assert_array_equal(
            np.asarray(new), [True, False, True, False, False, True])

    def test_never_revives_inactive(self):
        active = jnp.array([False, True, False, True])
        u = jnp.where(active, jnp.array([1., 2., 3., 4.]), jnp.inf)
        new = shedder.drop_lowest_utility(active, u, jnp.int32(1))
        assert not bool(new[0]) and not bool(new[2])


class TestThresholdDropMask:
    """The O(N) histogram-refinement select vs the argsort oracle."""

    @given(st.integers(0, 500), st.integers(0, 2000))
    @settings(max_examples=40, deadline=None)
    def test_matches_oracle_invariants(self, seed, rho):
        rng = np.random.default_rng(seed)
        N = int(rng.integers(3, 400))
        active = np.asarray(rng.random(N) < rng.uniform(0.1, 1.0))
        scale = float(10 ** rng.uniform(-2, 3))
        if seed % 3 == 0:  # tie-heavy: a handful of distinct levels
            levels = np.linspace(0, scale, int(rng.integers(1, 5)))
            u = rng.choice(levels, N).astype(np.float32)
        else:
            u = (rng.random(N) * scale).astype(np.float32)
        u_act = jnp.where(jnp.asarray(active), jnp.asarray(u), jnp.inf)
        new = shedder.threshold_drop_mask(jnp.asarray(active), u_act,
                                          jnp.int32(rho))
        oracle = shedder.drop_lowest_utility(jnp.asarray(active), u_act,
                                             jnp.int32(rho))
        n_active = int(active.sum())
        # Exactly the oracle's count...
        assert int(new.sum()) == int(oracle.sum())
        assert n_active - int(new.sum()) == min(rho, n_active)
        # ...never revives inactive slots...
        assert not bool(jnp.any(new & ~jnp.asarray(active)))
        # ...and respects the threshold up to the final bucket width.
        dropped = active & ~np.asarray(new)
        kept = np.asarray(new)
        if dropped.any() and kept.any():
            span = u[active].max() - u[active].min()
            tol = max(span / 128.0 ** 3, 1e-6)
            assert u[dropped].max() <= u[kept].min() + tol * 1.01

    def test_all_ties_bitwise_equals_oracle(self):
        """Once every candidate holds one f32 value, the index tie-break
        IS the stable argsort order — bitwise equality."""
        active = jnp.ones(200, bool)
        u = jnp.full((200,), 0.5, jnp.float32)
        for rho in (0, 1, 50, 199, 200, 999):
            np.testing.assert_array_equal(
                np.asarray(shedder.threshold_drop_mask(active, u,
                                                       jnp.int32(rho))),
                np.asarray(shedder.drop_lowest_utility(active, u,
                                                       jnp.int32(rho))))

    def test_shed_dispatch_plans_agree_on_count(self):
        rng = np.random.default_rng(5)
        N = 256
        active = jnp.asarray(rng.random(N) < 0.8)
        tables = jnp.asarray(rng.random((2, 8, 4)), jnp.float32)
        bins = jnp.array([32, 32], jnp.int32)
        pid = jnp.asarray(rng.integers(0, 2, N), jnp.int32)
        state = jnp.asarray(rng.integers(0, 4, N), jnp.int32)
        r_w = jnp.asarray(rng.integers(1, 256, N), jnp.int32)
        key = jax.random.PRNGKey(0)
        kw = dict(key=key, active=active, rho=jnp.int32(37),
                  stacked_tables=tables, bin_sizes=bins, pattern_id=pid,
                  state=state, r_w=r_w)
        a = shedder.shed("pspice", plan="threshold", **kw)
        b = shedder.shed("pspice", plan="sort", **kw)
        assert int(a.sum()) == int(b.sum())


class TestRandomDrop:
    def test_exact_budget(self):
        key = jax.random.PRNGKey(0)
        active = jnp.ones(128, bool)
        new = shedder.random_drop(key, active, jnp.int32(40))
        assert int(new.sum()) == 88

    def test_uniformity(self):
        """Each active PM should be dropped with ~equal frequency."""
        active = jnp.ones(16, bool)
        counts = np.zeros(16)
        for i in range(300):
            new = shedder.random_drop(jax.random.PRNGKey(i), active,
                                      jnp.int32(4))
            counts += ~np.asarray(new)
        freq = counts / 300
        assert abs(freq.mean() - 0.25) < 0.01
        assert freq.std() < 0.06


class TestEBL:
    def test_irrelevant_types_shed_first(self):
        pattern_class = jnp.array([0, 1, 2, 0], jnp.int32)  # types 0,3 irrel
        rep = jnp.array([0.0, 1.0, 2.0])
        freq = jnp.array([0.4, 0.1, 0.1, 0.4])
        u = shedder.ebl_type_utilities(pattern_class, rep, freq)
        assert float(u[0]) == 0.0 and float(u[3]) == 0.0
        assert float(u[2]) > float(u[1]) > 0

    def test_drop_mask_respects_budget(self):
        key = jax.random.PRNGKey(1)
        types = jnp.zeros(10000, jnp.int32)
        utils = jnp.array([0.0])
        mask = shedder.ebl_drop_mask(key, types, utils, jnp.float32(0.3))
        assert abs(float(mask.mean()) - 0.3) < 0.05
