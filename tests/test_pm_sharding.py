"""Pattern-parallel CEP sharding: pm_specs rules + run_engine_sharded
parity with the plain engine (host mesh)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro.dist import sharding as SH


def _cfg(num_patterns=4, **kw):
    base = dict(num_patterns=num_patterns, max_states=4, max_classes=4,
                max_pms=32, max_any_ids=8, ring_size=4)
    base.update(kw)
    return eng.EngineConfig(**base)


class TestPMSpecs:
    def test_pattern_axis_shards_when_divisible(self):
        mesh = SH.abstract_mesh((4,), ("data",))
        sp = SH.pm_specs(mesh, _cfg(num_patterns=8))
        assert sp["pattern_axis"] == "data"
        assert sp["carry"].pms.active == P("data", None)
        assert sp["carry"].pms.idset == P("data", None, None)
        assert sp["carry"].complex_count == P("data")
        assert sp["model"].trans == P("data", None, None)
        assert sp["events"].ev_class == P(None, "data")
        # scalars / per-event telemetry stay replicated
        assert sp["carry"].sim_time == P()
        assert sp["out"].l_e == P(None)

    def test_indivisible_pattern_count_falls_back_replicated(self):
        mesh = SH.abstract_mesh((4,), ("data",))
        sp = SH.pm_specs(mesh, _cfg(num_patterns=3))
        assert sp["pattern_axis"] is None
        assert sp["carry"].pms.active == P(None, None)
        assert sp["events"].ev_class == P(None, None)

    def test_missing_axis_falls_back_replicated(self):
        mesh = SH.abstract_mesh((2, 2), ("x", "y"))
        sp = SH.pm_specs(mesh, _cfg(num_patterns=4))
        assert sp["pattern_axis"] is None


def _planted_run(n_patterns, runner_fn):
    """Plant one Q1-style SEQ completion in pattern 0; run via runner_fn."""
    spec = pat.make_q1(window_size=50, num_symbols=3)
    cp = pat.compile_patterns([spec] * n_patterns)
    cfg = runner.default_config(cp, max_pms=16)
    model = eng.make_model(cp, cfg)
    n = 60
    cls = np.zeros((n, n_patterns), np.int32)
    cls[5, :], cls[10, :], cls[15, :] = 1, 2, 3   # completes in EVERY pattern
    ev = eng.EventBatch(
        ev_class=jnp.asarray(cls),
        ev_bind=jnp.full((n, n_patterns), -1, jnp.int32),
        ev_open=jnp.asarray(cls == 1),
        ev_id=jnp.zeros((n,), jnp.int32),
        ev_rand=jnp.zeros((n,), jnp.float32),
        ebl_raw=jnp.zeros((n,), jnp.float32),
        arrival=jnp.arange(n, dtype=jnp.float32))
    return runner_fn(cfg, model, ev, eng.init_carry(cfg))


class TestRunEngineSharded:
    def test_parity_with_plain_engine_one_shard(self):
        """On a 1-device mesh the shard_map path is bit-identical to the
        plain engine (exercises the full spec/combine plumbing)."""
        mesh1 = jax.make_mesh((1,), ("data",),
                              devices=np.array(jax.devices()[:1]))
        sharded = lambda *a: SH.run_engine_sharded(*a, mesh=mesh1)
        c_plain, o_plain = _planted_run(4, eng.run_engine)
        c_shard, o_shard = _planted_run(4, sharded)
        np.testing.assert_array_equal(np.asarray(c_shard.complex_count),
                                      np.asarray(c_plain.complex_count))
        np.testing.assert_array_equal(np.asarray(c_shard.pms_created),
                                      np.asarray(c_plain.pms_created))
        np.testing.assert_allclose(np.asarray(o_shard.n_pm),
                                   np.asarray(o_plain.n_pm))
        np.testing.assert_allclose(np.asarray(o_shard.l_e),
                                   np.asarray(o_plain.l_e), rtol=1e-6)
        np.testing.assert_allclose(float(c_shard.sim_time),
                                   float(c_plain.sim_time), rtol=1e-6)

    def test_pattern_state_invariant_on_host_mesh(self):
        """Pattern-state outputs (matches, spawns, global PM count) are
        exact for ANY shard count when no shedding triggers; latency is
        the slowest shard's clock, so it is bounded by the serial one."""
        c_plain, o_plain = _planted_run(4, eng.run_engine)
        c_shard, o_shard = _planted_run(4, SH.run_engine_sharded)
        np.testing.assert_array_equal(np.asarray(c_shard.complex_count),
                                      np.asarray(c_plain.complex_count))
        np.testing.assert_array_equal(np.asarray(c_shard.pms_created),
                                      np.asarray(c_plain.pms_created))
        np.testing.assert_allclose(np.asarray(o_shard.n_pm),
                                   np.asarray(o_plain.n_pm))
        assert bool(jnp.all(o_shard.l_e <= o_plain.l_e + 1e-6))

    def test_indivisible_fallback_still_runs(self):
        ndev = len(jax.devices())
        # A pattern count that can't divide any multi-device mesh axis is
        # prime and < ndev only when ndev > 1; with 1 device the sharded
        # path itself runs.  Either way the call must succeed.
        c, o = _planted_run(3, SH.run_engine_sharded)
        np.testing.assert_array_equal(np.asarray(c.complex_count),
                                      np.ones(3))
        assert o.l_e.shape == (60,)

    def test_experiment_pattern_parallel_matches_serial(self):
        """runner.run_experiment(pattern_parallel=True) reproduces the
        serial pSPICE false-negative numbers on the same stream."""
        spec = pat.make_q1(window_size=1000, num_symbols=5)
        raw = streams.gen_stock(6000, num_symbols=100, pattern_symbols=5,
                                hot_fraction=0.9, p_class=0.05, seed=3)
        kw = dict(shedders=("pspice",), rate_multiplier=1.3, max_pms=64,
                  bin_size=64, latency_bound=1.0,
                  c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4,
                  c_shed_pm=1.5e-6, c_ebl=6e-5)
        serial = runner.run_experiment([spec], raw, **kw)
        par = runner.run_experiment([spec], raw, pattern_parallel=True,
                                    **kw)
        np.testing.assert_allclose(par["pspice"].fn, serial["pspice"].fn,
                                   rtol=1e-5, atol=1e-7)
