"""Integration tests for the vectorized CEP engine (paper §III + §IV)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)


def _stock_experiment(ws=3000, n=30000, shedders=("pspice", "pmbl", "ebl"),
                      **kw):
    spec = pat.make_q1(window_size=ws, num_symbols=10)
    raw = streams.gen_stock(n, num_symbols=500, pattern_symbols=10,
                            hot_fraction=0.9, p_class=0.03, seed=7)
    args = dict(COST, max_pms=128, bin_size=64, latency_bound=1.0)
    args.update(kw)
    return runner.run_experiment([spec], raw, shedders=shedders,
                                 rate_multiplier=1.2, **args)


class TestGroundTruthCounting:
    def test_seq_pattern_detects_known_plant(self):
        """Hand-planted Q1-style sequence must be detected exactly once."""
        spec = pat.make_q1(window_size=50, num_symbols=3)
        cp = pat.compile_patterns([spec])
        cfg = runner.default_config(cp, max_pms=16)
        model = eng.make_model(cp, cfg)
        n = 60
        cls = np.zeros((n, 1), np.int32)
        # classes 1,2,3 in order at positions 5, 10, 15
        cls[5, 0], cls[10, 0], cls[15, 0] = 1, 2, 3
        ev = eng.EventBatch(
            ev_class=jnp.asarray(cls),
            ev_bind=jnp.full((n, 1), -1, jnp.int32),
            ev_open=jnp.asarray(cls == 1),
            ev_id=jnp.zeros((n,), jnp.int32),
            ev_rand=jnp.zeros((n,), jnp.float32),
            ebl_raw=jnp.zeros((n,), jnp.float32),
            arrival=jnp.arange(n, dtype=jnp.float32))
        carry, outs = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(carry.complex_count[0]) == 1.0
        assert float(carry.pms_created[0]) == 1.0

    def test_out_of_order_not_detected(self):
        spec = pat.make_q1(window_size=50, num_symbols=3)
        cp = pat.compile_patterns([spec])
        cfg = runner.default_config(cp, max_pms=16)
        model = eng.make_model(cp, cfg)
        n = 60
        cls = np.zeros((n, 1), np.int32)
        cls[5, 0], cls[10, 0], cls[15, 0] = 1, 3, 2  # wrong order
        ev = eng.EventBatch(
            ev_class=jnp.asarray(cls),
            ev_bind=jnp.full((n, 1), -1, jnp.int32),
            ev_open=jnp.asarray(cls == 1),
            ev_id=jnp.zeros((n,), jnp.int32),
            ev_rand=jnp.zeros((n,), jnp.float32),
            ebl_raw=jnp.zeros((n,), jnp.float32),
            arrival=jnp.arange(n, dtype=jnp.float32))
        carry, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(carry.complex_count[0]) == 0.0

    def test_window_expiry_kills_pm(self):
        spec = pat.make_q1(window_size=8, num_symbols=3)
        cp = pat.compile_patterns([spec])
        cfg = runner.default_config(cp, max_pms=16)
        model = eng.make_model(cp, cfg)
        n = 60
        cls = np.zeros((n, 1), np.int32)
        cls[5, 0], cls[10, 0], cls[20, 0] = 1, 2, 3  # 2,3 after window end
        ev = eng.EventBatch(
            ev_class=jnp.asarray(cls),
            ev_bind=jnp.full((n, 1), -1, jnp.int32),
            ev_open=jnp.asarray(cls == 1),
            ev_id=jnp.zeros((n,), jnp.int32),
            ev_rand=jnp.zeros((n,), jnp.float32),
            ebl_raw=jnp.zeros((n,), jnp.float32),
            arrival=jnp.arange(n, dtype=jnp.float32))
        carry, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(carry.complex_count[0]) == 0.0

    def test_any_pattern_distinctness(self):
        """Q4-style: same bus delayed twice at a stop counts once."""
        spec = pat.make_q4(any_n=3, window_size=40, slide=40)
        cp = pat.compile_patterns([spec])
        cfg = runner.default_config(cp, max_pms=16)
        model = eng.make_model(cp, cfg)
        n = 40
        cls = np.zeros((n, 1), np.int32)
        ids = np.zeros(n, np.int32)
        binds = np.zeros((n, 1), np.int32)
        # bus 1 delayed twice, bus 2 once at stop 0: only 2 distinct → no CE
        for i, bus in [(3, 1), (6, 1), (9, 2)]:
            cls[i, 0] = 1
            ids[i] = bus
        opens = np.zeros((n, 1), bool)
        opens[0, 0] = True
        ev = eng.EventBatch(
            ev_class=jnp.asarray(cls), ev_bind=jnp.asarray(binds),
            ev_open=jnp.asarray(opens), ev_id=jnp.asarray(ids),
            ev_rand=jnp.zeros((n,), jnp.float32),
            ebl_raw=jnp.zeros((n,), jnp.float32),
            arrival=jnp.arange(n, dtype=jnp.float32))
        carry, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(carry.complex_count[0]) == 0.0
        # third distinct bus completes it
        cls[12, 0] = 1
        ids[12] = 3
        ev = ev._replace(ev_class=jnp.asarray(cls), ev_id=jnp.asarray(ids))
        carry, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(carry.complex_count[0]) == 1.0


@pytest.mark.slow
class TestEndToEnd:
    """The paper's headline behaviors on a reduced stream (§IV-B)."""

    @pytest.fixture(scope="class")
    def results(self):
        return _stock_experiment()

    def test_no_shed_run_is_lossless(self, results):
        r = next(iter(results.values()))
        assert r.ground_truth.pms_shed == 0

    def test_latency_bound_maintained(self, results):
        for name, r in results.items():
            viol = (r.result.l_e > 1.01).mean()
            assert viol < 0.02, (name, viol)

    def test_shedding_happens_under_overload(self, results):
        assert results["pspice"].result.pms_shed > 0
        assert results["pmbl"].result.pms_shed > 0
        assert results["ebl"].result.ebl_dropped > 0

    def test_pspice_not_worse_than_random(self, results):
        assert results["pspice"].fn <= results["pmbl"].fn + 0.05

    def test_fn_bounded(self, results):
        for name, r in results.items():
            assert 0.0 <= r.fn <= 1.0


class TestFig8Ablation:
    def test_pspice_minus_flag_plumbs_through(self):
        spec = pat.make_q1(window_size=400, num_symbols=4)
        raw = streams.gen_stock(4000, num_symbols=50, pattern_symbols=4,
                                hot_fraction=0.9, p_class=0.05, seed=1)
        res = runner.run_experiment(
            [spec], raw, shedders=("pspice",), rate_multiplier=1.2,
            use_remaining_time=False, max_pms=64, bin_size=32, **COST)
        assert "pspice" in res
