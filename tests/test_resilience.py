"""Resilience layer tests (DESIGN.md §12): admission-controlled ingest,
degradation ladder, carry guard/recovery, fault injection — plus the
config-validation and divide-by-zero regression satellites.

The load-bearing guarantee, tested first: with every resilience config
absent OR present-but-inert, runtime results are bitwise-identical to the
pre-resilience path — the layer provably costs nothing when idle.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.runtime as RT
from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro.eval import quality as Q
from repro.runtime import telemetry as TM

# Same constants as tests/test_runtime.py so the in-process jit cache is
# shared when both files run in one session.
COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)
N_EVENTS = 2000


def _assert_tree_equal(a, b, what=""):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


@pytest.fixture(scope="module")
def setup():
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=48, latency_bound=0.005,
                                gather_stats=True, shedder=eng.SHED_PSPICE,
                                **COST)
    model = eng.make_model(cp, cfg)
    rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)

    def make_events(seed, rate_mult=1.0, n=N_EVENTS):
        raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                                p_class=0.05, seed=100 + seed)
        return streams.classify(specs, raw, rate=rate * rate_mult, seed=seed)

    return specs, cfg, model, make_events


def _ev(n, arrival_rate=1000.0, seed=0, t0=0.0):
    """A minimal synthetic EventBatch for front-end-only tests."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / arrival_rate, n).astype(np.float32)
    return eng.EventBatch(
        ev_class=jnp.ones((n, 1), jnp.int32),
        ev_bind=jnp.zeros((n, 1), jnp.int32),
        ev_open=jnp.ones((n, 1), bool),
        ev_id=jnp.arange(n, dtype=jnp.int32),
        ev_rand=jnp.asarray(rng.random(n), jnp.float32),
        ebl_raw=jnp.zeros((n,), jnp.float32),
        arrival=jnp.asarray(t0 + np.cumsum(gaps), jnp.float32))


# ---------------------------------------------------------------------------
# Satellite: config validation with actionable messages
# ---------------------------------------------------------------------------

class TestConfigValidation:
    def _cfg(self, **kw):
        base = dict(num_patterns=1, max_states=4, max_classes=4, max_pms=32)
        base.update(kw)
        return eng.EngineConfig(**base)

    @pytest.mark.parametrize("field,value,needle", [
        ("latency_bound", 0.0, "latency_bound"),
        ("latency_bound", -1.0, "latency_bound"),
        ("max_pms", 0, "max_pms"),
        ("num_patterns", 0, "num_patterns"),
        ("ring_size", 0, "ring_size"),
        ("max_any_ids", -1, "max_any_ids"),
        ("safety_buffer", -0.1, "safety_buffer"),
        ("c_base", -1e-6, "c_base"),
        ("ebl_floor", 1.5, "ebl_floor"),
        ("ebl_decay", -0.1, "ebl_decay"),
        ("ebl_backlog_gain", -1.0, "ebl_backlog_gain"),
        ("shedder", "bogus", "shedder"),
        ("shed_plan", "quick", "shed_plan"),
        ("spawn_alloc", "marx", "spawn_alloc"),
        ("kinds", "all", "kinds"),
        ("spawn_modes", "never", "spawn_modes"),
    ])
    def test_engine_config_rejects_bad_field(self, field, value, needle):
        with pytest.raises(ValueError, match=needle):
            self._cfg(**{field: value})

    def test_engine_config_accepts_valid(self):
        self._cfg(latency_bound=0.005, ebl_floor=0.0, ebl_decay=1.0)

    @pytest.mark.parametrize("kw,needle", [
        (dict(chunk_size=0), "chunk_size"),
        (dict(scan_unroll=0), "scan_unroll"),
        (dict(group_chunks=0), "group_chunks"),
    ])
    def test_runtime_config_rejects_bad_field(self, kw, needle):
        with pytest.raises(ValueError, match=needle):
            RT.RuntimeConfig(**kw)

    def test_ladder_input_shed_requires_ingest(self):
        with pytest.raises(ValueError, match="ingest"):
            RT.RuntimeConfig(ladder=RT.LadderConfig())
        # capped below the admission rungs no front-end is needed
        RT.RuntimeConfig(ladder=RT.LadderConfig(max_rung=RT.RUNG_PM_TRIM))

    @pytest.mark.parametrize("kw,needle", [
        (dict(max_queue_events=0), "max_queue_events"),
        (dict(low_watermark=600, high_watermark=500), "watermark"),
        (dict(high_watermark=1 << 20), "watermark"),
        (dict(shed_max=1.5), "shed_max"),
        (dict(admit_rate=10.0, admit_burst=0.0), "admit_burst"),
    ])
    def test_ingest_config_rejects_bad_field(self, kw, needle):
        with pytest.raises(ValueError, match=needle):
            RT.IngestConfig(**kw)

    @pytest.mark.parametrize("kw,needle", [
        (dict(escalate_streak=0), "streak"),
        (dict(trim_frac=1.2), "trim_frac"),
        (dict(input_shed_frac=-0.1), "input_shed_frac"),
        (dict(max_rung=7), "max_rung"),
        (dict(latency_bound=0.0), "latency_bound"),
    ])
    def test_ladder_config_rejects_bad_field(self, kw, needle):
        with pytest.raises(ValueError, match=needle):
            RT.LadderConfig(**kw)

    @pytest.mark.parametrize("kw,needle", [
        (dict(check_every_chunks=0), "check_every_chunks"),
        (dict(checkpoint_every_chunks=0), "checkpoint_every_chunks"),
        (dict(quarantine_offers=-1), "quarantine_offers"),
    ])
    def test_guard_config_rejects_bad_field(self, kw, needle):
        with pytest.raises(ValueError, match=needle):
            RT.GuardConfig(**kw)

    def test_fault_config_rejects_bad_field(self):
        with pytest.raises(ValueError, match="fault kinds"):
            RT.FaultConfig(kinds=("burst", "meteor"))
        with pytest.raises(ValueError, match="p_fault"):
            RT.FaultConfig(p_fault=2.0)


# ---------------------------------------------------------------------------
# Ingest queue: admission control, watermarks, backpressure, determinism
# ---------------------------------------------------------------------------

class TestIngestQueue:
    CFG = RT.IngestConfig(max_queue_events=1000, high_watermark=500,
                          low_watermark=100, shed_max=0.9, seed=7)

    def test_passthrough_below_watermark(self):
        q = RT.IngestQueue(self.CFG)
        ev = _ev(200)
        rep = q.offer(ev)
        assert (rep.offered, rep.admitted, rep.shed, rep.rejected) \
            == (200, 200, 0, 0)
        assert not rep.backpressure
        out = q.take()
        _assert_tree_equal(ev, out, "passthrough must preserve events")
        assert q.depth == 0

    def test_watermark_shedding_with_hysteresis(self):
        q = RT.IngestQueue(self.CFG)
        r1 = q.offer(_ev(600))
        assert r1.drop_p == 0.0 and r1.backpressure   # above high AFTER
        r2 = q.offer(_ev(200, seed=1))
        assert r2.drop_p > 0.0 and r2.shed > 0        # now engaged
        q.take()                                       # drain below low
        r3 = q.offer(_ev(50, seed=2))
        assert r3.drop_p == 0.0 and r3.shed == 0      # disengaged

    def test_hard_bound_rejects_and_signals_backpressure(self):
        q = RT.IngestQueue(dataclasses.replace(
            self.CFG, high_watermark=1000, low_watermark=1000,
            shed_max=0.0))
        rep = q.offer(_ev(1500))
        assert rep.rejected == 500 and rep.admitted == 1000
        assert rep.backpressure and q.depth == 1000

    def test_token_bucket_clocked_by_arrival_time(self):
        # 2000 ev/s offered against a 500 ev/s bucket with a small burst:
        # roughly 3/4 of the steady-state stream must shed.
        cfg = RT.IngestConfig(max_queue_events=1 << 16,
                              high_watermark=1 << 16,
                              low_watermark=0, admit_rate=500.0,
                              admit_burst=64.0, seed=3)
        q = RT.IngestQueue(cfg)
        for i in range(10):
            q.offer(_ev(200, arrival_rate=2000.0, seed=i, t0=i * 0.1))
        assert q.total_shed > 0.5 * q.total_offered
        assert q.total_admitted < 0.5 * q.total_offered

    def test_seeded_determinism(self):
        reps = []
        for _ in range(2):
            q = RT.IngestQueue(self.CFG)
            q.forced_drop = 0.4
            ids = []
            for i in range(4):
                q.offer(_ev(300, seed=i))
                out = q.take()
                ids.append(np.asarray(out.ev_id) if out is not None
                           else np.zeros(0))
            reps.append(np.concatenate(ids))
        np.testing.assert_array_equal(reps[0], reps[1])

    def test_take_slices_across_batches_in_order(self):
        q = RT.IngestQueue(self.CFG)
        q.offer(_ev(60))
        q.offer(_ev(60, seed=1))
        out = q.take(100)
        assert RT.num_events(out) == 100 and q.depth == 20
        np.testing.assert_array_equal(np.asarray(out.ev_id)[:60],
                                      np.arange(60))
        rest = q.take()
        assert RT.num_events(rest) == 20
        np.testing.assert_array_equal(np.asarray(rest.ev_id),
                                      np.arange(40, 60))

    def test_neutral_events_are_inert(self, setup):
        """neutral_like events must advance the clock but never spawn,
        match, or E-BL-drop — the quarantine substitute is safe."""
        _, cfg, model, make_events = setup
        ev = RT.neutral_like(make_events(0))
        carry, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(np.asarray(carry.complex_count).sum()) == 0
        assert float(np.asarray(carry.pms_created).sum()) == 0
        assert float(np.asarray(carry.ebl_dropped)) == 0
        assert float(carry.sim_time) > 0


class TestIngestFrontEnd:
    CFG = RT.IngestConfig(max_queue_events=1000, high_watermark=900,
                          low_watermark=100, seed=11)

    def test_lockstep_take_aligns_to_min_depth(self):
        fe = RT.IngestFrontEnd(self.CFG, num_lanes=2)
        fe.queues[0].forced_drop = 0.5   # lane 0 sheds, lane 1 doesn't
        fe.offer(RT.stack([_ev(100), _ev(100, seed=1)]))
        d0, d1 = fe.queues[0].depth, fe.queues[1].depth
        assert d0 < d1 == 100
        out = fe.take()
        assert out.ev_id.shape == (2, d0)      # aligned to the min
        assert fe.queues[1].depth == d1 - d0   # remainder stays queued

    def test_drain_pads_short_lanes_with_neutral(self):
        fe = RT.IngestFrontEnd(self.CFG, num_lanes=2)
        fe.queues[0].forced_drop = 0.5
        fe.offer(RT.stack([_ev(100), _ev(100, seed=1)]))
        out = fe.take(drain=True)
        assert out.ev_id.shape == (2, 100)     # padded to the max
        lane0 = np.asarray(out.ev_class[0, :, 0])
        assert (lane0[-1] == 0) and fe.queues[0].depth == 0
        # lane 1 is the full untouched stream
        np.testing.assert_array_equal(np.asarray(out.ev_id[1]),
                                      np.arange(100))

    def test_quarantined_lane_purges_and_substitutes(self):
        fe = RT.IngestFrontEnd(self.CFG, num_lanes=2)
        fe.offer(RT.stack([_ev(50), _ev(50, seed=1)]))
        purged = fe.quarantine_lane(0, offers=2)
        assert purged == 50 and fe.quarantined_lanes() == [0]
        out = fe.take()
        assert out is not None and out.ev_id.shape == (2, 50)
        assert (np.asarray(out.ev_class[0]) == 0).all()   # neutral sub
        rep0, _ = fe.offer(RT.stack([_ev(30, seed=2), _ev(30, seed=3)]))
        assert rep0.quarantined and rep0.admitted == 0
        fe.offer(RT.stack([_ev(30, seed=4), _ev(30, seed=5)]))
        assert fe.quarantined_lanes() == []    # released after 2 offers


# ---------------------------------------------------------------------------
# Fault injector: determinism + contract of each stream fault
# ---------------------------------------------------------------------------

class TestFaultInjector:
    def test_seeded_replay_is_bit_identical(self):
        outs = []
        for _ in range(2):
            inj = RT.FaultInjector(RT.FaultConfig(seed=9, p_fault=0.7))
            evs = [inj.corrupt_events(_ev(400, seed=i)) for i in range(4)]
            outs.append((inj.log, evs))
        assert outs[0][0] == outs[1][0] and len(outs[0][0]) > 0
        for a, b in zip(outs[0][1], outs[1][1]):
            _assert_tree_equal(a, b, "replayed faulted stream")

    @pytest.mark.parametrize("kind", RT.STREAM_FAULTS)
    def test_stream_faults_keep_arrivals_monotone(self, kind):
        inj = RT.FaultInjector(RT.FaultConfig(seed=2, p_fault=1.0,
                                              kinds=(kind,)))
        ev = inj.corrupt_events(_ev(500))
        arr = np.asarray(ev.arrival)
        assert (np.diff(arr) >= -1e-6).all(), f"{kind} broke monotonicity"

    def test_duplicate_extends_reorder_permutes(self):
        cfg = RT.FaultConfig(seed=4, p_fault=1.0, kinds=("duplicate",),
                             dup_len=32)
        ev = RT.FaultInjector(cfg).corrupt_events(_ev(300))
        assert RT.num_events(ev) == 332
        ids = np.asarray(ev.ev_id)
        uniq, counts = np.unique(ids, return_counts=True)
        assert (counts == 2).sum() == 32     # exactly the dup window twice

        cfg = RT.FaultConfig(seed=4, p_fault=1.0, kinds=("reorder",))
        ev2 = RT.FaultInjector(cfg).corrupt_events(_ev(300))
        ids2 = np.asarray(ev2.ev_id)
        np.testing.assert_array_equal(np.sort(ids2), np.arange(300))
        assert (ids2 != np.arange(300)).any()

    def test_burst_compresses_gaps(self):
        cfg = RT.FaultConfig(seed=6, p_fault=1.0, kinds=("burst",),
                             burst_factor=10.0, burst_len=128)
        base = _ev(500)
        ev = RT.FaultInjector(cfg).corrupt_events(base)
        # total span shrinks by the compressed window's removed time
        assert float(ev.arrival[-1]) < float(base.arrival[-1])

    def test_state_faults_poison_what_guards_must_catch(self, setup):
        _, cfg, model, _ = setup
        inj = RT.FaultInjector(RT.FaultConfig(
            seed=1, p_fault=1.0, kinds=("lane_poison", "nan_refresh",
                                        "table_corrupt")))
        carry = inj.corrupt_carry(eng.init_carry(cfg))
        assert not np.isfinite(np.asarray(carry.sim_time))
        assert not np.isfinite(np.asarray(carry.obs_counts)).all()
        bad = inj.corrupt_model(model)
        assert not np.isfinite(np.asarray(bad.ut_tables)).all()
        cv = np.asarray(RT.carry_check_vec(carry))
        mv = np.asarray(RT.model_check_vec(bad))
        assert not cv.all() and not mv.all()


# ---------------------------------------------------------------------------
# Guard: checks, checkpoint/restore, trim
# ---------------------------------------------------------------------------

class TestGuard:
    def test_healthy_state_passes_all_checks(self, setup):
        _, cfg, model, make_events = setup
        carry, _ = eng.run_engine(cfg, model, make_events(0),
                                  eng.init_carry(cfg))
        assert np.asarray(RT.carry_check_vec(carry)).all()
        assert np.asarray(RT.model_check_vec(model)).all()

    @pytest.mark.parametrize("poison,check", [
        (lambda c: c._replace(sim_time=jnp.float32(jnp.nan)),
         "finite_time"),
        (lambda c: c._replace(
            lat_samples_l=c.lat_samples_l.at[0].set(jnp.inf)),
         "finite_latency_ring"),
        (lambda c: c._replace(ring_ptr=c.ring_ptr.at[0].set(-3)),
         "store_consistent"),
        (lambda c: c._replace(pms_shed=jnp.float32(-1.0)),
         "counters_sane"),
        (lambda c: c._replace(
            obs_counts=c.obs_counts.at[0, 0, 0].set(jnp.nan)),
         "finite_obs"),
    ])
    def test_each_carry_check_catches_its_poison(self, setup, poison,
                                                 check):
        _, cfg, _, _ = setup
        carry = poison(eng.init_carry(cfg))
        vec = np.asarray(RT.carry_check_vec(carry))
        assert not vec[RT.CARRY_CHECKS.index(check)]

    def test_checkpoint_restore_roundtrips_bitwise(self, setup):
        _, cfg, model, make_events = setup
        carry, _ = eng.run_engine(cfg, model, make_events(0),
                                  eng.init_carry(cfg))
        g = RT.CarryGuard(RT.GuardConfig())
        g.save(carry, model, chunk_i=5)
        poisoned = carry._replace(sim_time=jnp.float32(jnp.nan))
        rc, rm = g.restore(poisoned, model)
        _assert_tree_equal(carry, rc, "restored carry")
        _assert_tree_equal(model, rm, "restored model")
        assert g.checkpoint_chunk == 5 and g.restores == 1

    def test_checkpoint_survives_donation(self, setup):
        """The checkpoint must hold TRUE copies: running more chunks
        (which donate/delete the live carry buffers) must not corrupt
        what restore returns."""
        _, cfg, model, make_events = setup
        srt = RT.StreamRuntime(cfg, model,
                               rt=RT.RuntimeConfig(chunk_size=256))
        srt.push(make_events(0))
        g = RT.CarryGuard(RT.GuardConfig())
        g.save(srt.carry, srt.model, chunk_i=srt._chunk_i)
        want = jax.tree.map(lambda x: np.array(x), srt.carry)
        srt.push(make_events(1), flush=True)   # donates the old buffers
        rc, _ = g.restore(srt.carry, srt.model)
        _assert_tree_equal(want, rc, "checkpoint after donation")

    def test_trim_store_drops_requested_fraction(self, setup):
        _, cfg, model, _ = setup
        carry = eng.init_carry(cfg)
        n0 = 10   # plant n0 live PMs in open windows at known slots
        pms = carry.pms._replace(
            active=carry.pms.active.at[0, :n0].set(True),
            state=carry.pms.state.at[0, :n0].set(1),
            open_idx=carry.pms.open_idx.at[0, :n0].set(
                jnp.arange(200, 200 + n0 * 20, 20, dtype=jnp.int32)))
        carry = carry._replace(pms=pms)
        trimmed = RT.trim_store(cfg, model, carry, jnp.int32(500),
                                jnp.float32(0.5))
        n1 = int(np.asarray(trimmed.pms.active).sum())
        assert n1 == n0 - int(np.ceil(0.5 * n0))   # exactly rho dropped
        assert float(trimmed.pms_shed) == n0 - n1
        assert float(trimmed.shed_calls) == 1.0
        # the trim pays the engine's simulated shed cost
        assert float(trimmed.sim_time) > float(carry.sim_time)


# ---------------------------------------------------------------------------
# Degradation ladder
# ---------------------------------------------------------------------------

class TestLadder:
    CFG = RT.LadderConfig(escalate_streak=2, deescalate_streak=3,
                          max_rung=RT.RUNG_PM_TRIM)

    def test_escalates_after_streak_and_resets(self):
        lad = RT.DegradationLadder(RT.LadderConfig(
            escalate_streak=3, deescalate_streak=2))
        assert [lad.observe(True, i) for i in range(2)] == [None, None]
        assert lad.observe(False, 2) is None          # streak broken
        assert [lad.observe(True, i) for i in (3, 4)] == [None, None]
        tr = lad.observe(True, 5)
        assert tr["to"] == RT.RUNG_PM_TRIM and lad.rung == RT.RUNG_PM_TRIM

    def test_deescalates_symmetrically_and_clamps(self):
        lad = RT.DegradationLadder(RT.LadderConfig(
            escalate_streak=1, deescalate_streak=2,
            max_rung=RT.RUNG_INPUT_SHED))
        for i in range(5):
            lad.observe(True, i)
        assert lad.rung == RT.RUNG_INPUT_SHED          # clamped at max
        assert lad.observe(False, 5) is None
        assert lad.observe(False, 6)["to"] == RT.RUNG_PM_TRIM
        assert lad.observe(False, 7) is None           # fresh streak needed
        assert lad.observe(False, 8)["to"] == RT.RUNG_NORMAL
        assert lad.observe(False, 9) is None           # floor

    def test_quarantine_tick_deescalates_without_chunks(self):
        lad = RT.DegradationLadder(RT.LadderConfig(escalate_streak=1,
                                                   deescalate_streak=2))
        for i in range(4):
            lad.observe(True, i)
        assert lad.rung == RT.RUNG_QUARANTINE
        assert lad.quarantine_tick(4) is None
        tr = lad.quarantine_tick(5)
        assert tr["why"] == "quarantine_timeout" \
            and lad.rung == RT.RUNG_INPUT_SHED

    def test_runtime_escalation_mirrored_in_telemetry(self, setup):
        """A bound the stream can never meet escalates the ladder; every
        transition must appear in telemetry, trims must shed PMs, and the
        per-chunk rung must be recorded."""
        specs, cfg, model, make_events = setup
        rt = RT.RuntimeConfig(
            chunk_size=256,
            ingest=RT.IngestConfig(max_queue_events=1 << 16,
                                   high_watermark=1 << 16, low_watermark=0,
                                   seed=1),
            ladder=RT.LadderConfig(escalate_streak=1, deescalate_streak=2,
                                   latency_bound=1e-7))
        srt = RT.StreamRuntime(cfg, model, rt, specs=specs)
        srt.push(make_events(0), flush=True)
        assert srt.ladder.rung == RT.RUNG_QUARANTINE
        evs = srt.telemetry.events_of("ladder")
        assert len(evs) == len(srt.ladder.transitions) == 3
        assert [e.detail["to"] for e in evs] == [1, 2, 3]
        assert srt.telemetry.chunks[-1].rung == RT.RUNG_QUARANTINE
        assert max(c.rung for c in srt.telemetry.chunks) == 3
        # rung >= 2 forces admission-level shedding
        assert srt.ingest.forced_drop \
            == rt.ladder.input_shed_frac
        # quarantine refuses subsequent pushes outright
        n_before = srt.events_processed
        assert srt.push(make_events(1)) == []
        assert srt.quarantine_dropped == N_EVENTS
        assert srt.events_processed == n_before

    def test_quarantine_recovers_via_push_ticks(self, setup):
        specs, cfg, model, make_events = setup
        rt = RT.RuntimeConfig(
            chunk_size=256,
            ingest=RT.IngestConfig(max_queue_events=1 << 16,
                                   high_watermark=1 << 16, low_watermark=0,
                                   seed=1),
            ladder=RT.LadderConfig(escalate_streak=1, deescalate_streak=2,
                                   latency_bound=1e-7))
        srt = RT.StreamRuntime(cfg, model, rt, specs=specs)
        srt.push(make_events(0), flush=True)
        assert srt._quarantined
        srt.push(make_events(1, n=256))        # tick 1: refused
        assert srt._quarantined
        # tick 2 (an empty heartbeat push) de-escalates to rung 2 — the
        # refusal clock guarantees quarantine is never terminal.
        srt.push(RT.slice_events(make_events(1, n=256), 0, 0))
        assert not srt._quarantined
        assert srt.ladder.rung == RT.RUNG_INPUT_SHED
        drops = [e for e in srt.telemetry.events_of("ladder")
                 if e.detail["why"] == "quarantine_timeout"]
        assert len(drops) == 1

    def test_trim_rung_sheds_between_chunks(self, setup):
        """At rung >= 1 with a NONE in-scan shedder, any PM loss can only
        come from the ladder's between-chunk trim."""
        specs, cfg, model, make_events = setup
        cfg_ns = dataclasses.replace(cfg, shedder=eng.SHED_NONE,
                                     latency_bound=1.0)
        rt = RT.RuntimeConfig(
            chunk_size=256,
            ladder=RT.LadderConfig(escalate_streak=1, deescalate_streak=99,
                                   max_rung=RT.RUNG_PM_TRIM,
                                   latency_bound=1e-7, trim_frac=0.5))
        srt = RT.StreamRuntime(cfg_ns, model, rt)
        srt.push(make_events(0), flush=True)
        assert srt.ladder.rung == RT.RUNG_PM_TRIM
        assert float(np.asarray(srt.carry.pms_shed)) > 0
        agg = srt.telemetry.aggregate()
        assert agg["pms_shed"] > 0 and agg["max_rung"] == 1


# ---------------------------------------------------------------------------
# Runtime integration: poison → detect → restore → finish clean
# ---------------------------------------------------------------------------

class TestGuardedRuntime:
    def test_poisoned_carry_restores_and_finishes_finite(self, setup):
        specs, cfg, model, make_events = setup
        rt = RT.RuntimeConfig(chunk_size=256, guard=RT.GuardConfig(
            check_every_chunks=1, checkpoint_every_chunks=2))
        srt = RT.StreamRuntime(cfg, model, rt)
        ev = make_events(0)
        srt.push(RT.slice_events(ev, 0, 1024))
        srt.carry = srt.carry._replace(sim_time=jnp.float32(jnp.nan))
        srt.push(RT.slice_events(ev, 1024, N_EVENTS), flush=True)
        assert srt.guard_now() == []
        assert len(srt.telemetry.events_of("guard_violation")) >= 1
        assert len(srt.telemetry.events_of("guard_restore")) >= 1
        for leaf in jax.tree.leaves(srt.carry):
            a = np.asarray(leaf)
            if np.issubdtype(a.dtype, np.floating):
                assert np.isfinite(a).all()

    def test_nan_refresh_gate_keeps_deployed_model(self, setup):
        specs, cfg, model, make_events = setup
        rt = RT.RuntimeConfig(
            chunk_size=256,
            refresh=RT.RefreshConfig(every_chunks=2, min_observations=1.0))
        srt = RT.StreamRuntime(cfg, model, rt, specs=specs)
        srt.push(make_events(0, n=512))
        srt.carry = srt.carry._replace(
            obs_counts=srt.carry.obs_counts.at[0, 0, 0].set(jnp.nan))
        tables_before = np.array(srt.model.ut_tables)
        srt.push(make_events(1, n=512))
        assert srt.refresh_state.skipped_nonfinite >= 1
        np.testing.assert_array_equal(tables_before,
                                      np.asarray(srt.model.ut_tables))
        assert np.isfinite(np.asarray(srt.model.ut_tables)).all()

    def test_lane_restore_leaves_neighbors_bitwise_untouched(self, setup):
        specs, cfg, model, make_events = setup
        L = 2
        evL = RT.stack([make_events(i) for i in range(L)])
        mL = RT.broadcast_model(model, L)
        rt = RT.RuntimeConfig(chunk_size=256, guard=RT.GuardConfig(
            check_every_chunks=1, checkpoint_every_chunks=2))
        mt = RT.MultiTenantRuntime(cfg, mL, num_lanes=L, rt=rt)
        clean = RT.MultiTenantRuntime(cfg, RT.broadcast_model(model, L),
                                      num_lanes=L,
                                      rt=RT.RuntimeConfig(chunk_size=256))
        mt.push(RT.slice_events(evL, 0, 1024, axis=1))
        clean.push(RT.slice_events(evL, 0, 1024, axis=1))
        mt.carry = mt.carry._replace(
            sim_time=mt.carry.sim_time.at[1].set(jnp.nan))
        mt.push(RT.slice_events(evL, 1024, N_EVENTS, axis=1), flush=True)
        clean.push(RT.slice_events(evL, 1024, N_EVENTS, axis=1),
                   flush=True)
        viols = mt.telemetry.events_of("guard_violation")
        assert viols and viols[0].detail["lane"] == 1
        assert mt.telemetry.events_of("guard_restore")[0].detail["lanes"] \
            == [1]
        lane0 = jax.tree.map(lambda x: np.asarray(x)[0], mt.carry)
        lane0_clean = jax.tree.map(lambda x: np.asarray(x)[0], clean.carry)
        _assert_tree_equal(lane0_clean, lane0, "lane 0 must be untouched")
        for leaf in jax.tree.leaves(mt.carry):
            a = np.asarray(leaf)
            if np.issubdtype(a.dtype, np.floating):
                assert np.isfinite(a).all()


# ---------------------------------------------------------------------------
# The bitwise-off guarantee
# ---------------------------------------------------------------------------

class TestResilienceCostsNothing:
    def test_disabled_configs_bitwise_identical(self, setup):
        _, cfg, model, make_events = setup
        ev = make_events(0)
        c_mono, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        srt = RT.StreamRuntime(cfg, model,
                               rt=RT.RuntimeConfig(chunk_size=256))
        assert srt.ingest is None and srt.ladder is None \
            and srt.guard is None
        srt.push(ev, flush=True)
        _assert_tree_equal(c_mono, srt.carry, "resilience-off carry")

    def test_inert_resilience_bitwise_identical(self, setup):
        """Resilience ENABLED but never triggered (lavish watermarks, an
        unmeetable-ly generous bound, guards that always pass) must also
        be bitwise-identical — the layer only ever acts on its rungs."""
        specs, cfg, model, make_events = setup
        ev = make_events(0)
        c_mono, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        rt = RT.RuntimeConfig(
            chunk_size=256,
            ingest=RT.IngestConfig(max_queue_events=1 << 20,
                                   high_watermark=1 << 20, low_watermark=0,
                                   seed=0),
            ladder=RT.LadderConfig(latency_bound=1e9),
            guard=RT.GuardConfig(check_every_chunks=1,
                                 checkpoint_every_chunks=4))
        srt = RT.StreamRuntime(cfg, model, rt, specs=specs)
        for s in range(0, N_EVENTS, 700):
            srt.push(RT.slice_events(ev, s, min(s + 700, N_EVENTS)))
        srt.flush()
        _assert_tree_equal(c_mono, srt.carry, "inert resilience carry")
        assert srt.telemetry.events == []
        assert srt.guard.violations == 0 and srt.guard.checkpoints > 1


# ---------------------------------------------------------------------------
# Satellite: divide-by-zero / empty-input guards
# ---------------------------------------------------------------------------

class TestEmptyInputGuards:
    def test_device_chunk_stats_empty_chunk(self, setup):
        _, cfg, _, _ = setup
        carry = eng.init_carry(cfg)
        P = cfg.num_patterns
        outs = eng.StepOut(
            l_e=jnp.zeros((0,), jnp.float32),
            n_pm=jnp.zeros((0,), jnp.int32),
            shed=jnp.zeros((0,), bool),
            dropped=jnp.zeros((0,), bool),
            match_open=jnp.zeros((P, 0), jnp.int32),
            match_bind=jnp.zeros((P, 0), jnp.int32))
        vec = np.asarray(TM.device_chunk_stats(outs, carry))
        assert np.isfinite(vec).all()
        assert (vec[:6] == 0).all()
        stats = TM.summarize_chunk(0, 0, 0, 1, vec,
                                   TM.counter_snapshot(carry), 1e-3)
        assert stats.l_e_p99 == 0.0 and stats.completions == 0.0

    def test_compare_match_sets_empty_reference(self):
        rep = Q.compare_match_sets([set()], [set()])
        assert rep.recall == 1.0 and rep.fn_ratio == 0.0
        rep = Q.compare_match_sets([{(1, 2, 3)}], [set()])
        assert rep.recall == 1.0 and rep.n_spurious == 1
        rep = Q.compare_match_sets([set(), set()], [set(), {(0, 1, 5)}])
        assert rep.recall == 0.0 and rep.fn_ratio == 1.0

    def test_lb_violations_empty_run(self):
        empty = eng.RunResult(
            complex_count=np.zeros(1), pms_created=np.zeros(1),
            pms_shed=0.0, shed_calls=0.0, overflow=0.0, ebl_dropped=0.0,
            l_e=np.zeros(0), n_pm=np.zeros(0), carry=None)
        res = runner.ExperimentResult(
            shedder="none", fn=0.0, match_probability=0.0, max_rate=0.0,
            result=empty, ground_truth=empty, latency_bound=0.005)
        assert res.lb_violations == 0.0
        assert res.lb_compliance == 1.0

    def test_degradation_point_requires_matches(self):
        empty = eng.RunResult(
            complex_count=np.zeros(1), pms_created=np.zeros(1),
            pms_shed=0.0, shed_calls=0.0, overflow=0.0, ebl_dropped=0.0,
            l_e=np.zeros(0), n_pm=np.zeros(0), carry=None, matches=None)
        with pytest.raises(ValueError, match="emit_matches"):
            Q.degradation_point(empty, empty)
