"""Sharding-rule tests (divisibility fallbacks, spec shapes) — single device,
abstract mesh only."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import registry
from repro.dist import sharding as SH
from repro.models import transformer as T


@pytest.fixture(scope="module")
def mesh():
    # AbstractMesh with the production topology: no devices needed for
    # spec construction (SH.abstract_mesh bridges the 0.4.x/0.5+ ctor).
    from repro.launch import mesh as M
    return M.make_abstract_production_mesh()


def _specs_for(arch, mesh):
    cfg = registry.get_config(arch)
    params = jax.eval_shape(lambda: T.init_params(cfg,
                                                  jax.random.key(0)))
    return cfg, params, SH.param_specs(mesh, cfg, params)


class TestParamSpecs:
    def test_dense_attention_head_sharded(self, mesh):
        cfg, params, specs = _specs_for("starcoder2-15b", mesh)
        assert specs["layers"]["attn"]["wq"] == P(None, None, "model", None)
        # kv heads = 4 < 16 → replicated
        assert specs["layers"]["attn"]["wk"] == P(None, None, None, None)
        assert specs["layers"]["mlp"]["wi"] == P(None, None, "model")

    def test_minitron_falls_back_to_replicated_attention(self, mesh):
        cfg, params, specs = _specs_for("minitron-4b", mesh)
        assert specs["layers"]["attn"]["wq"] == P(None, None, None, None)
        assert specs["layers"]["mlp"]["wi"] == P(None, None, "model")

    def test_fsdp_shards_over_data_too(self, mesh):
        cfg, params, specs = _specs_for("qwen1.5-110b", mesh)
        assert specs["layers"]["mlp"]["wi"] == P(None, "data", "model")
        assert specs["embed"] == P("model", "data")

    def test_moe_experts_on_model_axis(self, mesh):
        cfg, params, specs = _specs_for("deepseek-moe-16b", mesh)
        assert specs["layers"]["moe"]["wi"] == P(None, "model", None, None)
        assert specs["layers"]["moe"]["router"] == P(None, None, "model")

    def test_mamba_channels_sharded(self, mesh):
        cfg, params, specs = _specs_for("mamba2-1.3b", mesh)
        assert specs["layers"]["mamba"]["wz"] == P(None, None, "model")
        assert specs["layers"]["mamba"]["wB"] == P(None, None, None)

    def test_every_leaf_divisible(self, mesh):
        """Property: every sharded dim divides evenly over its axes."""
        for arch in registry.ARCH_IDS:
            cfg, params, specs = _specs_for(arch, mesh)
            flat_p = jax.tree_util.tree_leaves(params)
            flat_s = jax.tree_util.tree_leaves(
                specs, is_leaf=lambda x: isinstance(x, P))
            for leaf, spec in zip(flat_p, flat_s):
                for dim, ax in zip(leaf.shape, tuple(spec)):
                    if ax is None:
                        continue
                    axes = (ax,) if isinstance(ax, str) else ax
                    size = int(np.prod([mesh.shape[a] for a in axes]))
                    assert dim % size == 0, (arch, leaf.shape, spec)


class TestBatchAndCacheSpecs:
    def test_batch_axes_fallback(self, mesh):
        assert SH.batch_axes(mesh, 256) == ("data",)
        assert SH.batch_axes(mesh, 1) is None

    def test_multipod_batch_axes(self):
        mp = SH.abstract_mesh((2, 16, 16), ("pod", "data", "model"))
        assert SH.batch_axes(mp, 256) == ("pod", "data")
        assert SH.batch_axes(mp, 16) == ("data",)

    def test_cache_sequence_sharded_over_model(self, mesh):
        from repro.models import decode as D
        cfg = registry.get_config("starcoder2-15b")
        cache = jax.eval_shape(lambda: D.init_cache(cfg, 128, 32768))
        specs = SH.cache_specs(mesh, cfg, cache)
        assert specs["k"] == P(None, "data", "model", None, None)
        assert specs["pos"] == P()
