"""Per-arch smoke tests (reduced configs) + model-layer correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import decode as D
from repro.models import layers as L
from repro.models import ssm as S
from repro.models import transformer as T
from repro.training import optimizer as O
from repro.training.train_step import make_train_step


def _batch(cfg, B=2, S=32, key=7):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    s_text = S - cfg.vlm_patches if cfg.vlm_patches else S
    b = {"tokens": jax.random.randint(ks[0], (B, s_text), 0,
                                      cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (B, s_text), 0,
                                      cfg.vocab_size)}
    if cfg.vlm_patches:
        b["patches"] = jax.random.normal(
            ks[2], (B, cfg.vlm_patches, cfg.d_model)) * 0.1
    if cfg.enc_dec:
        b["frames"] = jax.random.normal(
            ks[2], (B, cfg.enc_frames, cfg.d_model)) * 0.1
    return b


@pytest.mark.parametrize("arch", registry.ARCH_IDS)
class TestArchSmoke:
    """One reduced-config forward/train step per assigned architecture."""

    def test_train_step_runs_and_is_finite(self, arch):
        cfg = registry.get_smoke_config(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        opt = O.init_opt_state(params)
        step = make_train_step(cfg, O.AdamWConfig(lr=1e-3), remat=False)
        batch = _batch(cfg)
        params2, opt2, metrics = step(params, opt, batch)
        assert np.isfinite(float(metrics["loss"]))
        assert int(metrics["step"]) == 1
        # params actually changed
        delta = jax.tree.reduce(
            lambda a, b: a + b,
            jax.tree.map(lambda a, b: float(jnp.abs(
                a.astype(jnp.float32) - b.astype(jnp.float32)).sum()),
                params, params2))
        assert delta > 0

    def test_output_shapes(self, arch):
        cfg = registry.get_smoke_config(arch)
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        batch = _batch(cfg)
        pf = {k: v for k, v in batch.items() if k != "labels"}
        ml = 40 + (cfg.vlm_patches or 0)
        cache, logits = D.prefill(cfg, params, pf, max_len=ml, remat=False)
        B = batch["tokens"].shape[0]
        assert logits.shape == (B, cfg.vocab_size)
        assert not bool(jnp.isnan(logits).any())
        lg, cache = D.decode_step(cfg, params, cache,
                                  jnp.zeros((B,), jnp.int32))
        assert lg.shape == (B, cfg.vocab_size)
        assert not bool(jnp.isnan(lg).any())


@pytest.mark.parametrize("arch", ["starcoder2-15b", "deepseek-v3-671b",
                                  "mamba2-1.3b", "zamba2-7b",
                                  "whisper-small", "internvl2-76b"])
def test_decode_matches_full_forward(arch):
    """Prefill+decode == full forward at the next position (exactness of the
    cache path, incl. MLA absorption and SSD state carry)."""
    cfg = registry.get_smoke_config(arch)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    B, Sq = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, Sq + 1), 0,
                              cfg.vocab_size)
    batch_full = {"tokens": toks}
    batch_pre = {"tokens": toks[:, :Sq]}
    if cfg.vlm_patches:
        pt = jax.random.normal(jax.random.PRNGKey(2),
                               (B, cfg.vlm_patches, cfg.d_model)) * 0.1
        batch_full["patches"] = pt
        batch_pre["patches"] = pt
    if cfg.enc_dec:
        fr = jax.random.normal(jax.random.PRNGKey(3),
                               (B, cfg.enc_frames, cfg.d_model)) * 0.1
        batch_full["frames"] = fr
        batch_pre["frames"] = fr
    enc_out = (T.encoder(cfg, params, batch_full["frames"], remat=False)
               if cfg.enc_dec else None)
    x = T.embed_inputs(cfg, params, batch_full)
    h, _ = T.backbone(cfg, params, x, remat=False, enc_out=enc_out)
    h = L.rmsnorm(h, params["final_norm"], cfg.norm_eps)
    logits_full = T.lm_head_logits(cfg, params, h[:, -1:, :])[:, 0]
    ml = Sq + 4 + (cfg.vlm_patches or 0)
    cache, _ = D.prefill(cfg, params, batch_pre, max_len=ml, remat=False)
    lg, _ = D.decode_step(cfg, params, cache, toks[:, Sq])
    scale = float(jnp.abs(logits_full).max()) + 1e-9
    assert float(jnp.abs(lg - logits_full).max()) / scale < 2e-2


class TestLayers:
    def test_flash_attention_vs_reference(self):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 128, 4, 32))
        k = jax.random.normal(ks[1], (2, 128, 2, 32))
        v = jax.random.normal(ks[2], (2, 128, 2, 32))
        out = L.flash_attention(q, k, v, q_chunk=32, kv_chunk=64)
        oracle = L.attention_ref(q, k, v)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   atol=2e-5, rtol=2e-5)

    def test_ssd_chunked_vs_recurrent_oracle(self):
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="t", family="ssm", num_layers=1, d_model=64,
                          num_heads=0, num_kv_heads=0, head_dim=0, d_ff=0,
                          vocab_size=64, ssm=True, ssm_state=16,
                          ssm_head_dim=8, ssm_chunk=8, dtype="float32")
        p = S.init_mamba2(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 64)) * 0.5
        y, _ = S.ssd_forward(p, x, cfg)   # 40 not divisible by 8 → padding
        yref = S.ssd_reference(p, x, cfg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yref),
                                   atol=2e-4)

    def test_moe_exact_at_high_capacity(self):
        """With capacity ≥ demand, per-row dispatch equals dense top-k."""
        from repro.models.config import ModelConfig
        cfg = ModelConfig(name="t", family="moe", num_layers=1, d_model=32,
                          num_heads=4, num_kv_heads=4, head_dim=8, d_ff=16,
                          vocab_size=64, moe=True, num_experts=8,
                          num_shared_experts=0, moe_top_k=2,
                          capacity_factor=8.0, dtype="float32")
        p = L.init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
        out, aux = L.moe_block(p, x, cfg)
        # dense oracle
        xt = x.reshape(-1, 32)
        logits = xt @ p["router"]
        probs = jax.nn.softmax(logits, -1)
        tv, ti = jax.lax.top_k(probs, 2)
        o = jnp.zeros_like(xt)
        for e in range(8):
            he = jax.nn.silu(xt @ p["wg"][e]) * (xt @ p["wi"][e])
            ye = he @ p["wo"][e]
            w = jnp.where((ti == e), tv, 0.0).sum(-1, keepdims=True)
            o = o + ye * w
        np.testing.assert_allclose(np.asarray(out.reshape(-1, 32)),
                                   np.asarray(o), atol=1e-4)
        assert np.isfinite(float(aux))

    def test_rope_rotation_invariance(self):
        """RoPE: score depends only on relative position."""
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        q = jax.random.normal(ks[0], (1, 1, 1, 32))
        k = jax.random.normal(ks[1], (1, 1, 1, 32))
        def score(pq, pk):
            qr = L.apply_rope(q, jnp.array([pq]))
            kr = L.apply_rope(k, jnp.array([pk]))
            return float((qr * kr).sum())
        assert abs(score(5, 3) - score(105, 103)) < 1e-3

    def test_rmsnorm_scale_invariance(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 16))
        g = jnp.ones((16,))
        a = L.rmsnorm(x, g)
        b = L.rmsnorm(x * 1000.0, g)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)
