"""Training-substrate tests: optimizer, checkpointing, compression,
serving scheduler, and the multi-device dry-run plumbing (subprocess)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.scheduler import (SchedulerConfig, run_simulation,
                                     synth_workload)
from repro.training import checkpoint as CK
from repro.training import compression as COMP
from repro.training import optimizer as O


class TestOptimizer:
    def test_adamw_decreases_quadratic(self):
        cfg = O.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1)
        params = {"w": jnp.array([5.0, -3.0])}
        opt = O.init_opt_state(params)
        for _ in range(200):
            grads = {"w": 2 * params["w"]}
            params, opt = O.adamw_update(cfg, params, grads, opt)
        assert float(jnp.abs(params["w"]).max()) < 0.1

    def test_clip_by_global_norm(self):
        g = {"a": jnp.ones(100) * 10.0}
        clipped, norm = O.clip_by_global_norm(g, 1.0)
        assert abs(float(O.global_norm(clipped)) - 1.0) < 1e-4
        assert abs(float(norm) - 100.0) < 1e-3

    def test_nested_structure_preserved(self):
        cfg = O.AdamWConfig()
        params = {"l": {"w": jnp.ones((2, 2)), "b": jnp.zeros(2)}}
        opt = O.init_opt_state(params)
        grads = jax.tree.map(jnp.ones_like, params)
        p2, o2 = O.adamw_update(cfg, params, grads, opt)
        assert set(p2) == {"l"} and set(p2["l"]) == {"w", "b"}
        assert int(o2["step"]) == 1


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        tree = {"a": np.arange(12, dtype=np.float32).reshape(3, 4),
                "b": {"c": np.ones(5, np.int32)}}
        CK.save(str(tmp_path), 7, tree)
        out = CK.restore(str(tmp_path), tree)
        np.testing.assert_array_equal(out["a"], tree["a"])
        np.testing.assert_array_equal(out["b"]["c"], tree["b"]["c"])

    def test_latest_and_gc(self, tmp_path):
        tree = {"x": np.zeros(3)}
        for s in (1, 2, 3, 4, 5):
            CK.save(str(tmp_path), s, tree, keep_last=2)
        assert CK.latest_step(str(tmp_path)) == 5
        assert sorted(os.listdir(tmp_path)) == ["step_00000004",
                                                "step_00000005"]

    def test_atomicity_no_partial_dirs(self, tmp_path):
        tree = {"x": np.zeros(3)}
        CK.save(str(tmp_path), 1, tree)
        assert not any(d.startswith(".tmp") for d in os.listdir(tmp_path))

    def test_shape_mismatch_raises(self, tmp_path):
        CK.save(str(tmp_path), 1, {"x": np.zeros(3)})
        with pytest.raises(ValueError):
            CK.restore(str(tmp_path), {"x": np.zeros(4)})


class TestCompression:
    def test_quantize_roundtrip_bounded_error(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 3
        q, s = COMP.quantize_int8(x)
        err = jnp.abs(COMP.dequantize_int8(q, s) - x).max()
        assert float(err) <= float(s) * 0.5 + 1e-6

    def test_error_feedback_unbiased_over_time(self):
        g = jax.random.normal(jax.random.PRNGKey(1), (512,))
        err = jnp.zeros_like(g)
        acc = jnp.zeros_like(g)
        for _ in range(100):
            deq, err = COMP.compress_decompress(g, err)
            acc += deq
        rel = float(jnp.abs(acc - 100 * g).max() / jnp.abs(100 * g).max())
        assert rel < 1e-3

    def test_compressed_psum_in_shard_map(self):
        """int8 EF all-reduce across the host devices (≥1)."""
        mesh = jax.make_mesh((len(jax.devices()),), ("d",))
        from jax.sharding import PartitionSpec as P

        def f(g, e):
            m, ne = COMP.compressed_psum(g[0], e[0], "d")
            return m[None], ne[None]

        n = len(jax.devices())
        g = jnp.stack([jnp.full((64,), float(i + 1)) for i in range(n)])
        e = jnp.zeros_like(g)
        # COMP.shard_map: version-compatible shim (jax.shard_map is not
        # public on 0.4.x).
        mfn = COMP.shard_map(f, mesh=mesh, in_specs=(P("d"), P("d")),
                             out_specs=(P("d"), P("d")))
        mean, _ = mfn(g, e)
        expect = np.mean([i + 1 for i in range(n)])
        np.testing.assert_allclose(np.asarray(mean[0]), expect, rtol=1e-2)


class TestServingScheduler:
    def test_pspice_beats_baselines(self):
        res = {}
        for pol in ("pspice", "random", "admission"):
            cfg = SchedulerConfig(policy=pol, max_slots=32, slo=1.5, seed=1)
            reqs = synth_workload(400, rate=90.0, cfg=cfg, seed=5)
            res[pol] = run_simulation(cfg, reqs)["goodput"]
        assert res["pspice"] >= res["random"] - 0.02
        assert res["pspice"] > res["admission"]

    def test_all_requests_accounted(self):
        cfg = SchedulerConfig(policy="pspice", max_slots=16, slo=1.0)
        reqs = synth_workload(100, rate=50.0, cfg=cfg, seed=2)
        m = run_simulation(cfg, reqs)
        assert m["completed"] + m["evicted"] == 100


@pytest.mark.slow
class TestDryRunSubprocess:
    """The 512-device dry-run runs in a subprocess (device count is locked
    at first jax init, so the main test process must stay at 1 device)."""

    def test_single_cell_lowers_and_compiles(self):
        env = dict(os.environ, PYTHONPATH="src")
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun", "--arch",
             "internlm2-1.8b", "--shape", "decode_32k"],
            capture_output=True, text=True, env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            timeout=1800)
        assert out.returncode == 0, out.stderr[-2000:]
        assert '"status": "ok"' in out.stdout
