"""Event-block megakernel equivalence (DESIGN.md §10).

``backend="pallas_block"`` fuses ``block_events`` events into one kernel
launch with the PM store resident, splitting blocks at Algorithm-1 fire
points.  Everything here is BITWISE against ``backend="xla"``:

  1. the q1/q4 fixtures at non-tile-multiple store sizes, overloaded so
     the block-split shed path actually executes, for every shedder and
     every W in {1, 8, 32, 128} — whole carry (incl. gathered stats) and
     whole StepOut (incl. emitted match identities);
  2. ragged chunked streaming (run_engine_chunk) replaying the
     monolithic xla scan for every W, including W > chunk;
  3. the oracle scenario generator's padded random scenarios
     (tests/test_oracle.py) across the W grid;
  4. the runtime surfaces: grouped StreamRuntime, vmapped tenant lanes,
     and the pattern-sharded engine.

Plus the satellite edge cases: ``merge_carries`` (zero-lane merge,
multi-pattern lane-major layout) and ``wrap_event_index`` at the int32
boundary.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro import runtime as RT

from test_oracle import _scenario

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)
SHEDDERS = (eng.SHED_NONE, eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)
W_GRID = (1, 8, 32, 128)


def _assert_tree_equal(a, b, what=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=what)


def _setup(name, max_pms=37, n=300, seed=0, rate_mult=2.0,
           shedder=eng.SHED_PSPICE, **kw):
    """Overloaded fixture at a non-tile-multiple store size."""
    specs = [pat.make_q1(window_size=400, num_symbols=4) if name == "q1"
             else pat.make_q4(any_n=3, window_size=120, slide=40)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=0.005,
                                gather_stats=True, emit_matches=True,
                                shedder=shedder, **COST, **kw)
    model = eng.make_model(cp, cfg)
    rate = rate_mult * 3.0 / (cfg.c_base + cfg.c_match * 0.3 * max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=100 + seed)
    ev = streams.classify(specs, raw, rate=rate, seed=seed)
    return cfg, model, ev


def _block(cfg, w):
    return dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS_BLOCK,
                               block_events=w)


class TestBlockBackendEquivalence:
    """pallas_block == xla, whole carry and whole StepOut, bit for bit."""

    @pytest.mark.parametrize("w", W_GRID)
    @pytest.mark.parametrize("shedder", SHEDDERS)
    def test_w_sweep_q1(self, w, shedder):
        cfg, model, ev = _setup("q1", shedder=shedder)
        cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        if shedder in (eng.SHED_PSPICE, eng.SHED_PMBL):
            assert float(cx.pms_shed) > 0, "fixture must exercise the split"
        if shedder == eng.SHED_EBL:
            assert float(cx.ebl_dropped) > 0, "fixture must drop"
        cfg_b = _block(cfg, w)
        cb, ob = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
        _assert_tree_equal(cx, cb, f"q1/{shedder}/W={w} carry")
        _assert_tree_equal(ox, ob, f"q1/{shedder}/W={w} outs")

    @pytest.mark.parametrize("w", (8, 32))
    @pytest.mark.parametrize("shedder", SHEDDERS)
    def test_q4_any_in_windows(self, w, shedder):
        """ANY advance + slide-window ring spawns through the kernel."""
        cfg, model, ev = _setup("q4", max_pms=53, shedder=shedder)
        cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        cfg_b = _block(cfg, w)
        cb, ob = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
        _assert_tree_equal(cx, cb, f"q4/{shedder}/W={w} carry")
        _assert_tree_equal(ox, ob, f"q4/{shedder}/W={w} outs")

    @pytest.mark.parametrize("w", W_GRID)
    def test_ragged_chunked(self, w):
        """Ragged chunks (100 ∤ 320, W > chunk included) replay the
        monolithic xla scan."""
        cfg, model, ev = _setup("q1", n=320)
        cx, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(cx.pms_shed) > 0
        cfg_b = _block(cfg, w)
        carry = eng.init_carry(cfg_b)
        for start, piece in RT.iter_chunks(ev, 100):
            carry, _ = eng.run_engine_chunk(cfg_b, model, piece, carry,
                                            jnp.int32(start))
        _assert_tree_equal(cx, carry, f"chunked W={w}")

    def test_spawn_overflow(self):
        """Tiny store: the kernel's rank/overflow bookkeeping matches the
        engine's free-list compaction when candidates exceed slots."""
        cfg, model, ev = _setup("q4", max_pms=4, n=600, rate_mult=1.0,
                                shedder=eng.SHED_NONE)
        cx, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(cx.overflow) > 0, "fixture must overflow"
        cfg_b = _block(cfg, 32)
        cb, _ = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
        _assert_tree_equal(cx, cb, "overflow carry")


class TestOracleScenarioWSweep:
    """The oracle suite's padded random scenarios (one shared static
    config per W — scenario randomness lives in the arrays) through the
    block backend, monolithic and ragged-chunked, vs xla."""

    @pytest.mark.parametrize("w", W_GRID)
    def test_scenarios_block_equals_xla(self, w):
        for seed in range(6):
            cfg, model, ev = _scenario(seed)
            cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
            cfg_b = _block(cfg, w)
            cb, ob = eng.run_engine(cfg_b, model, ev,
                                    eng.init_carry(cfg_b))
            _assert_tree_equal(cx, cb, f"scenario {seed} W={w} carry")
            _assert_tree_equal(ox, ob, f"scenario {seed} W={w} outs")
            assert eng.match_sets(ob) == eng.match_sets(ox)
            carry_c = eng.init_carry(cfg_b)
            for start, piece in RT.iter_chunks(ev, 100):
                carry_c, _ = eng.run_engine_chunk(
                    cfg_b, model, piece, carry_c, jnp.int32(start))
            _assert_tree_equal(cx, carry_c,
                               f"scenario {seed} W={w} chunked")


class TestBlockRuntimeSurfaces:
    """The runtime entry points get the fused path through the backend
    dispatchers — results stay bitwise those of the xla engine."""

    def test_stream_runtime_grouped(self):
        cfg, model, ev = _setup("q1", n=1024)
        cx, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        srt = RT.StreamRuntime(_block(cfg, 32), model,
                               rt=RT.RuntimeConfig(chunk_size=128))
        srt.push(ev, flush=True)
        _assert_tree_equal(cx, srt.carry, "grouped runtime")

    def test_lanes_equal_sequential(self):
        """Vmapped block kernel: each lane bitwise == its own
        single-lane xla run (incl. per-lane shed splits)."""
        L = 2
        models, evs = [], []
        for lane in range(L):
            cfg, m, e = _setup("q1", n=256, seed=lane,
                               rate_mult=1.5 + lane)
            models.append(m)
            evs.append(e)
        cfg_b = _block(cfg, 32)
        cL, outsL = RT.run_chunk_lanes(
            cfg_b, RT.stack(models), RT.stack(evs),
            RT.init_lane_carries(cfg_b, L), jnp.int32(0))
        for lane in range(L):
            cx, ox = eng.run_engine(cfg, models[lane], evs[lane],
                                    eng.init_carry(cfg, seed=lane))
            _assert_tree_equal(cx, jax.tree.map(lambda x: x[lane], cL),
                               f"lane {lane} carry")
            _assert_tree_equal(ox, jax.tree.map(lambda x: x[lane], outsL),
                               f"lane {lane} outs")

    def test_pattern_sharded_engine(self):
        """run_engine_sharded drives the block backend through
        shard_map with pm_specs (single-axis mesh)."""
        from repro.dist import sharding as SH
        cfg, model, ev = _scenario(3)
        cfg_b = _block(cfg, 32)
        cx, ox = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
        cs, os_ = SH.run_engine_sharded(cfg_b, model, ev,
                                        eng.init_carry(cfg_b))
        _assert_tree_equal(cx, cs, "sharded block carry")
        _assert_tree_equal(ox, os_, "sharded block outs")


class TestOverloadAxis:
    """Sustained overload (the paper's regime of interest): a spawn-heavy
    stream at 1.2/1.4/1.6× service rate with a tight bound, so Algorithm 2
    fires MANY times per block and the fused in-kernel shed — threshold
    select, PRNG key chain, shed-cost accounting — is exercised end to
    end.  Bitwise vs xla for every shedder × W × overload ratio."""

    OVERLOAD = (1.2, 1.4, 1.6)

    @staticmethod
    def _overload_setup(shedder, mult, n=240):
        specs = [pat.make_q1(window_size=400, num_symbols=4)]
        cp = pat.compile_patterns(specs)
        cfg = runner.default_config(cp, max_pms=37, latency_bound=0.001,
                                    gather_stats=True, emit_matches=True,
                                    shedder=shedder, **COST)
        model = eng.make_model(cp, cfg)
        rate = mult * 3.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)
        raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                                p_class=0.5, seed=100)
        ev = streams.classify(specs, raw, rate=rate, seed=0)
        return cfg, model, ev

    @pytest.mark.parametrize("mult", OVERLOAD)
    @pytest.mark.parametrize("shedder", SHEDDERS)
    def test_overload_sweep_bitwise(self, shedder, mult):
        cfg, model, ev = self._overload_setup(shedder, mult)
        cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        if shedder in (eng.SHED_PSPICE, eng.SHED_PMBL):
            # >= 8 fires over 240 events: at W=128 (two blocks) some
            # block necessarily absorbs several fires in one launch, so
            # the in-kernel key-chain advance past row 0 is exercised.
            assert float(cx.shed_calls) >= 8, \
                f"fixture must fire repeatedly, got {float(cx.shed_calls)}"
        for w in (8, 32, 128):
            cfg_b = _block(cfg, w)
            cb, ob = eng.run_engine(cfg_b, model, ev, eng.init_carry(cfg_b))
            _assert_tree_equal(cx, cb, f"{shedder}/x{mult}/W={w} carry")
            _assert_tree_equal(ox, ob, f"{shedder}/x{mult}/W={w} outs")


class TestReplayLegacyPath:
    """``block_shed="replay"`` keeps the PR-5 fire/replay driver as the
    legacy oracle: the kernel bails at the first in-block fire, the host
    while_loop replays the fired event through ``_step`` and re-enters at
    ``fire_idx + 1``.  Must stay bitwise with xla (and therefore with the
    fused path, which is separately pinned to xla above)."""

    @pytest.mark.parametrize("w", (1, 8, 32))
    @pytest.mark.parametrize("shedder", (eng.SHED_PSPICE, eng.SHED_PMBL))
    def test_replay_equals_xla(self, shedder, w):
        """W=1 makes EVERY fire the last valid event of its block — the
        tail re-entry case (stop = fire_idx, re-entry at
        fire_idx + 1 == n_valid) — so no zero-width relaunch may occur."""
        cfg, model, ev = _setup("q1", shedder=shedder)
        cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(cx.shed_calls) > 0
        cfg_r = dataclasses.replace(_block(cfg, w), block_shed="replay")
        cr, outs_r = eng.run_engine(cfg_r, model, ev, eng.init_carry(cfg_r))
        _assert_tree_equal(cx, cr, f"replay/{shedder}/W={w} carry")
        _assert_tree_equal(ox, outs_r, f"replay/{shedder}/W={w} outs")

    def test_replay_chunked_tail_fire(self):
        """Ragged chunks × W=1: every block tail is also a chunk tail, so
        fires landing on the chunk's last valid event exercise the
        re-entry guard at each chunk-group boundary."""
        cfg, model, ev = _setup("q1", n=320)
        cx, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(cx.shed_calls) > 0
        cfg_r = dataclasses.replace(_block(cfg, 1), block_shed="replay")
        carry = eng.init_carry(cfg_r)
        for start, piece in RT.iter_chunks(ev, 100):
            carry, _ = eng.run_engine_chunk(cfg_r, model, piece, carry,
                                            jnp.int32(start))
        _assert_tree_equal(cx, carry, "replay chunked W=1")

    def test_replay_lanes_per_lane_fire_indices(self):
        """Vmapped lanes on the replay path: the lanes' streams differ, so
        their fire indices diverge within the same batched while
        iteration (non-fired lanes carry the fire_idx = W sentinel).
        Each lane must still equal its own single-lane xla run."""
        L = 2
        models, evs = [], []
        for lane in range(L):
            cfg, m, e = _setup("q1", n=256, seed=lane, rate_mult=1.5 + lane)
            models.append(m)
            evs.append(e)
        cfg_r = dataclasses.replace(_block(cfg, 32), block_shed="replay")
        cL, outsL = RT.run_chunk_lanes(
            cfg_r, RT.stack(models), RT.stack(evs),
            RT.init_lane_carries(cfg_r, L), jnp.int32(0))
        for lane in range(L):
            cx, ox = eng.run_engine(cfg, models[lane], evs[lane],
                                    eng.init_carry(cfg, seed=lane))
            _assert_tree_equal(cx, jax.tree.map(lambda x: x[lane], cL),
                               f"replay lane {lane} carry")
            _assert_tree_equal(ox, jax.tree.map(lambda x: x[lane], outsL),
                               f"replay lane {lane} outs")


class TestLazyInversion:
    """The kernel's Algorithm-1 check uses the cond-based f-inverse —
    must be BIT-identical to ``invert_latency`` for both model kinds
    (a divergent bit flips a shed decision and splits a block)."""

    @pytest.mark.parametrize("kind", [0, 1])  # LINEAR, NLOGN
    def test_matches_eager_inverse(self, kind):
        from repro.core import overload as ovl
        m = ovl.LatencyModel(a=jnp.float32(3.7e-5), b=jnp.float32(1.1e-4),
                             kind=jnp.int32(kind))
        targets = jnp.asarray(
            np.linspace(0.0, 2.0, 257), jnp.float32)
        eager = jax.vmap(lambda t: ovl.invert_latency(m, t))(targets)
        lazy = jax.vmap(lambda t: ovl.invert_latency_lazy(m, t))(targets)
        np.testing.assert_array_equal(np.asarray(eager), np.asarray(lazy))

    @pytest.mark.parametrize("kind", [0, 1])
    def test_detect_overload_lazy_flag(self, kind):
        from repro.core import overload as ovl
        m = ovl.LatencyModel(a=jnp.float32(5e-5), b=jnp.float32(2e-4),
                             kind=jnp.int32(kind))
        g = ovl.LatencyModel(a=jnp.float32(1e-6), b=jnp.float32(5e-5),
                             kind=jnp.int32(0))
        for n_pm in (0, 17, 4096):
            a = ovl.detect_overload(m, g, jnp.float32(0.01),
                                    jnp.int32(n_pm), 0.05)
            b = ovl.detect_overload(m, g, jnp.float32(0.01),
                                    jnp.int32(n_pm), 0.05, lazy=True)
            assert bool(a.shed) == bool(b.shed)
            assert int(a.rho) == int(b.rho)
            np.testing.assert_array_equal(np.asarray(a.l_e),
                                          np.asarray(b.l_e))


class TestMergeCarriesEdges:
    """Satellite: merge_carries edge cases, exercised directly (the
    runtime tests only hit the L>=1 uniform path)."""

    def test_zero_lane_merge(self):
        cfg = _setup("q1")[0]
        stacked = jax.tree.map(
            lambda x: jnp.zeros((0,) + x.shape, x.dtype),
            eng.init_carry(cfg))
        merged = eng.merge_carries(stacked)
        assert merged.pms.active.shape == (0, cfg.max_pms)
        assert float(merged.sim_time) == 0.0
        assert float(merged.pms_shed) == 0.0
        assert merged.ring.shape == (0, cfg.ring_size)

    def test_multi_pattern_lane_major_layout(self):
        """P>1 patterns per lane: the merged pattern axis is lane-major
        (lane 0's P patterns, then lane 1's), and scalar folds follow
        their documented semantics (sum counters, max clocks)."""
        specs = [pat.make_q1(window_size=50, num_symbols=4),
                 pat.make_q1(window_size=80, num_symbols=4)]
        cp = pat.compile_patterns(specs)
        cfg = runner.default_config(cp, max_pms=8, **COST)
        L, P = 3, cfg.num_patterns
        carries = []
        for lane in range(L):
            c = eng.init_carry(cfg, seed=lane)
            c = c._replace(
                complex_count=jnp.arange(P, dtype=jnp.float32) + 10 * lane,
                pms_shed=jnp.float32(lane),
                sim_time=jnp.float32(lane * 0.5),
                lat_ptr=jnp.int32(lane))
            carries.append(c)
        merged = eng.merge_carries(RT.stack(carries))
        want = np.concatenate(
            [np.arange(P, dtype=np.float32) + 10 * lane
             for lane in range(L)])
        np.testing.assert_array_equal(np.asarray(merged.complex_count),
                                      want)
        assert merged.pms.active.shape == (L * P, cfg.max_pms)
        assert float(merged.pms_shed) == sum(range(L))       # counters sum
        assert float(merged.sim_time) == 0.5 * (L - 1)       # clocks max
        assert int(merged.lat_ptr) == L - 1


class TestWrapEventIndex:
    """Satellite: the unbounded-stream index mapping at the int32 edge."""

    def test_boundary_values(self):
        assert int(eng.wrap_event_index(0)) == 0
        assert int(eng.wrap_event_index(2**31 - 1)) == 2**31 - 1
        assert int(eng.wrap_event_index(2**31)) == -(2**31)
        assert int(eng.wrap_event_index(2**32 - 1)) == -1
        assert int(eng.wrap_event_index(2**32 + 7)) == 7

    def test_window_differences_survive_wrap(self):
        """i - open_idx stays correct across the wrap as long as windows
        are << 2^31 (the property the engine's expiry relies on)."""
        a = eng.wrap_event_index(2**31 + 5)
        b = eng.wrap_event_index(2**31 - 3)
        assert int(a - b) == 8

    def test_engine_invariant_to_index_origin(self):
        """A chunked run started at ``origin`` and at ``origin + 2^31``
        (both wrapped) produces identical results — only index
        DIFFERENCES enter the operator."""
        cfg, model, ev = _setup("q1", n=128)
        cfg = dataclasses.replace(cfg, emit_matches=False)

        def run(origin):
            carry = eng.init_carry(cfg)
            outs = []
            for start, piece in RT.iter_chunks(ev, 64):
                # Window-open indices live in the carry, so both runs
                # must spawn in the same modular space from event 0 on.
                carry, o = eng.run_engine_chunk(
                    cfg, model, piece, carry,
                    eng.wrap_event_index(origin + start))
                outs.append(o)
            return carry, outs

        c0, o0 = run(0)
        c1, o1 = run(2**31)
        for field in ("complex_count", "pms_created", "pms_shed",
                      "overflow", "ebl_dropped"):
            np.testing.assert_array_equal(
                np.asarray(getattr(c0, field)),
                np.asarray(getattr(c1, field)), field)
        for a, b in zip(o0, o1):
            np.testing.assert_array_equal(np.asarray(a.l_e),
                                          np.asarray(b.l_e))
            np.testing.assert_array_equal(np.asarray(a.n_pm),
                                          np.asarray(b.n_pm))
