"""Quality subsystem tests: metrics units + metamorphic shedding laws.

The metamorphic laws hold for ANY shedder configuration, so they guard
the engine and the shedders without needing an oracle run:

  * SUBSET: a shed run's window-projected match multiset is contained in
    the no-shed ground truth's (shedding can lose complex events, never
    invent them) — provided the ground-truth run never overflowed its PM
    store, which the fixtures assert.
  * IDENTITY AT ZERO: a shedder that never fires (latency bound far
    above any realizable latency) is BITWISE identical to shedding
    disabled — whole carry and outputs.
  * MONOTONICITY (smoke): on the seeded scenarios, a higher sustained
    overload level does not decrease the false-negative ratio.
"""
import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.configs import pspice_paper as pp
from repro.data import streams
from repro.eval import quality as Q

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)

SHEDDING = (eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)


# ---------------------------------------------------------------------------
# Metric units
# ---------------------------------------------------------------------------

class TestCompareMatchSets:
    def test_exact_equality_is_perfect_recall(self):
        m = [{(1, -1, 5), (9, -1, 20)}, {(3, 2, 8)}]
        rep = Q.compare_match_sets(m, m)
        assert rep.recall == 1.0 and rep.fn_ratio == 0.0
        assert rep.n_spurious == 0 and rep.n_gt == 3 == rep.n_found

    def test_lost_match_counts_as_fn(self):
        gt = [{(1, -1, 5), (9, -1, 20)}]
        found = [{(1, -1, 5)}]
        rep = Q.compare_match_sets(found, gt)
        assert rep.recall == 0.5 and rep.fn_ratio == 0.5
        assert rep.per_pattern_fn[0] == 0.5

    def test_window_key_forgives_shifted_end(self):
        """The same window completing via a later constituent event is a
        detection under the window key, a miss under the identity key."""
        gt = [{(1, -1, 5)}]
        found = [{(1, -1, 7)}]                 # same window, later end
        win = Q.compare_match_sets(found, gt, key="window")
        ident = Q.compare_match_sets(found, gt, key="identity")
        assert win.recall == 1.0 and win.n_spurious == 0
        assert ident.recall == 0.0 and ident.n_spurious == 1

    def test_window_key_is_a_multiset(self):
        """An IN_WINDOWS window can complete twice; finding only one of
        the two completions is recall 1/2, not 1."""
        gt = [{(1, 4, 5), (1, 4, 9)}]          # same window, two matches
        found = [{(1, 4, 5)}]
        rep = Q.compare_match_sets(found, gt, key="window")
        assert rep.recall == 0.5

    def test_weights(self):
        gt = [{(0, -1, 1)}, {(0, -1, 1)}]
        found = [{(0, -1, 1)}, set()]
        rep = Q.compare_match_sets(found, gt, weights=np.array([3.0, 1.0]))
        assert rep.recall == pytest.approx(0.75)

    def test_empty_ground_truth_is_recall_one(self):
        rep = Q.compare_match_sets([set()], [set()])
        assert rep.recall == 1.0 and rep.fn_ratio == 0.0

    def test_pattern_count_mismatch_raises(self):
        with pytest.raises(ValueError):
            Q.compare_match_sets([set()], [set(), set()])


class TestScalarMetrics:
    def test_latency_compliance(self):
        l_e = np.array([0.1, 0.5, 1.5, 2.0])
        assert Q.latency_compliance(l_e, 1.0) == 0.5
        assert Q.latency_compliance(np.array([]), 1.0) == 1.0

    def test_degradation_curve_sorts_levels(self):
        pts = [(1.6, {"fn_ratio": 0.4, "drop_fraction": 0.5,
                      "lb_compliance": 0.9}),
               (1.2, {"fn_ratio": 0.1, "drop_fraction": 0.2,
                      "lb_compliance": 1.0})]
        curve = Q.degradation_curve(pts)
        assert curve["levels"] == [1.2, 1.6]
        assert curve["fn_ratio"] == [0.1, 0.4]


# ---------------------------------------------------------------------------
# Metamorphic shedding laws
# ---------------------------------------------------------------------------

def _fixture(name, shedder, max_pms=96, n=900, rate_mult=3.0,
             latency_bound=0.005, **cfg_kw):
    specs = [pat.make_q1(window_size=400, num_symbols=4) if name == "q1"
             else pat.make_q4(any_n=3, window_size=120, slide=40)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=max_pms,
                                latency_bound=latency_bound,
                                shedder=shedder, emit_matches=True,
                                **COST, **cfg_kw)
    model = eng.make_model(cp, cfg)
    rate = rate_mult * 3.0 / (cfg.c_base + cfg.c_match * 0.3 * max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=101)
    ev = streams.classify(specs, raw, rate=rate, seed=1)
    return cfg, model, ev


class TestShedderSubsetLaw:
    @pytest.mark.parametrize("name", ["q1", "q4"])
    @pytest.mark.parametrize("shedder", SHEDDING)
    def test_shed_matches_subset_of_ground_truth(self, name, shedder):
        cfg, model, ev = _fixture(name, shedder)
        gt_cfg = dataclasses.replace(cfg, shedder=eng.SHED_NONE)
        gt_c, gt_o = eng.run_engine(gt_cfg, model, ev,
                                    eng.init_carry(gt_cfg))
        assert float(gt_c.overflow) == 0.0, \
            "fixture invalid: ground truth overflowed its PM store"
        c, o = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        if shedder == eng.SHED_EBL:
            assert float(c.ebl_dropped) > 0, "fixture must drop"
        else:
            assert float(c.pms_shed) > 0, "fixture must shed"
        found = Q.project_matches(eng.match_sets(o), key="window")
        gt = Q.project_matches(eng.match_sets(gt_o), key="window")
        for p, (f, g) in enumerate(zip(found, gt)):
            extra = f - g                      # multiset difference
            assert not extra, (
                f"{name}/{shedder} pattern {p}: shed run invented "
                f"window completions {dict(extra)}")

    @pytest.mark.parametrize("shedder", SHEDDING)
    def test_report_spurious_is_zero_vs_ground_truth(self, shedder):
        cfg, model, ev = _fixture("q1", shedder)
        gt_cfg = dataclasses.replace(cfg, shedder=eng.SHED_NONE)
        _, gt_o = eng.run_engine(gt_cfg, model, ev, eng.init_carry(gt_cfg))
        _, o = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        rep = Q.compare_match_sets(eng.match_sets(o), eng.match_sets(gt_o))
        assert rep.n_spurious == 0
        assert 0.0 <= rep.fn_ratio <= 1.0


class TestZeroShedIdentity:
    """shedder=X with a bound no latency can reach == shedder disabled,
    bitwise, for every shedder — the rho=0 / never-fires limit."""

    @pytest.mark.parametrize("shedder", SHEDDING)
    def test_never_firing_shedder_is_bitwise_noshed(self, shedder):
        cfg, model, ev = _fixture("q1", shedder, latency_bound=1e9)
        base = dataclasses.replace(cfg, shedder=eng.SHED_NONE)
        c1, o1 = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        c0, o0 = eng.run_engine(base, model, ev, eng.init_carry(base))
        assert float(c1.pms_shed) == 0.0 and float(c1.ebl_dropped) == 0.0
        for a, b in zip(jax.tree.leaves(c1), jax.tree.leaves(c0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree.leaves(o1), jax.tree.leaves(o0)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.quality
class TestMonotonicity:
    """Sustained-overload monotonicity smoke on the seeded scenarios:
    more overload => the FN ratio does not decrease (small slack for the
    discrete match counts)."""

    SLACK = 0.02

    @pytest.mark.parametrize("scenario", ["bus", "soccer"])
    def test_fn_nondecreasing_in_overload(self, scenario):
        sc = streams.get_scenario(scenario)
        raw = sc.raw(n=9000)
        fns = {sh: [] for sh in SHEDDING}
        for mult in (1.2, 1.6, 2.0):
            res = runner.run_experiment(
                sc.specs(), raw, shedders=SHEDDING, rate_multiplier=mult,
                max_pms=sc.max_pms, bin_size=sc.bin_size,
                latency_bound=sc.latency_bound, seed=sc.seed, **pp.COST)
            for sh in SHEDDING:
                fns[sh].append(res[sh].fn_match)
        for sh, curve in fns.items():
            assert curve[0] <= curve[-1] + self.SLACK, (sh, curve)
            for lo, hi in zip(curve, curve[1:]):
                assert hi >= lo - self.SLACK, (sh, curve)


@pytest.mark.quality
class TestExperimentSurfacesQuality:
    """run_experiment's summary carries the match-set metrics
    (the recall/FN surface, not only latency stats)."""

    @pytest.fixture(scope="class")
    def results(self):
        sc = streams.get_scenario("bus")
        return runner.run_experiment(
            sc.specs(), sc.raw(n=9000), shedders=SHEDDING,
            rate_multiplier=1.4, max_pms=sc.max_pms, bin_size=sc.bin_size,
            latency_bound=sc.latency_bound, seed=sc.seed, **pp.COST)

    def test_metrics_populated(self, results):
        for sh, er in results.items():
            assert er.recall is not None and er.fn_match is not None
            assert er.recall == pytest.approx(1.0 - er.fn_match)
            assert 0.0 <= er.fn_match <= 1.0
            assert er.n_gt_matches > 0
            assert er.per_pattern_fn is not None
            assert len(er.per_pattern_fn) == len(er.ground_truth
                                                 .complex_count)
            assert 0.0 <= er.lb_compliance <= 1.0

    def test_match_sets_attached_to_runs(self, results):
        for er in results.values():
            assert er.result.matches is not None
            assert er.ground_truth.matches is not None

    def test_ordering_headline_bus(self, results):
        fn = {sh: er.fn_match for sh, er in results.items()}
        assert fn[eng.SHED_PSPICE] <= fn[eng.SHED_PMBL] + 1e-9
        assert fn[eng.SHED_PSPICE] <= fn[eng.SHED_EBL] + 1e-9


class TestDropFractionAndEmitGating:
    def test_drop_fraction_pm_shedder(self):
        cfg, model, ev = _fixture("q1", eng.SHED_PSPICE)
        c, o = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        res = eng.summarize(c, o)
        assert 0.0 < Q.drop_fraction(res) <= 1.0

    def test_match_sets_requires_emission(self):
        cfg, model, ev = _fixture("q1", eng.SHED_NONE)
        cfg = dataclasses.replace(cfg, emit_matches=False)
        carry, o = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert o.match_open.shape[-1] == 0
        with pytest.raises(ValueError):
            eng.match_sets(o)
        assert eng.summarize(carry, o).matches is None
