"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import shedder
from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.nfa_transition import nfa_advance_pallas
from repro.kernels.shed_select import (utility_histogram_pallas,
                                       utility_lookup_pallas)
from repro.models.layers import attention_ref, flash_attention


class TestFlashAttentionKernel:
    @pytest.mark.parametrize("B,Sq,Sk,H,KVH,D", [
        (1, 128, 128, 2, 2, 32),
        (2, 256, 256, 4, 2, 64),
        (1, 256, 256, 8, 1, 64),     # MQA
        (2, 128, 256, 4, 4, 128),    # Sq != Sk
    ])
    @pytest.mark.parametrize("causal", [True, False])
    def test_allclose_vs_oracle(self, B, Sq, Sk, H, KVH, D, causal):
        if causal and Sq != Sk:
            pytest.skip("causal offset case covered separately")
        ks = jax.random.split(jax.random.PRNGKey(B * Sq + H), 3)
        q = jax.random.normal(ks[0], (B, Sq, H, D), jnp.float32)
        k = jax.random.normal(ks[1], (B, Sk, KVH, D), jnp.float32)
        v = jax.random.normal(ks[2], (B, Sk, KVH, D), jnp.float32)
        out = flash_attention_pallas(q, k, v, causal=causal, interpret=True)
        oracle = attention_ref(q, k, v, causal=causal)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   atol=2e-5, rtol=2e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (1, 128, 2, 32)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(dtype)
        out = flash_attention_pallas(q, k, v, interpret=True)
        oracle = attention_ref(q, k, v)
        tol = 1e-5 if dtype == jnp.float32 else 3e-2
        np.testing.assert_allclose(
            np.asarray(out, np.float32), np.asarray(oracle, np.float32),
            atol=tol, rtol=tol)
        assert out.dtype == dtype

    def test_jnp_flash_matches_oracle_with_offset(self):
        """The model-side jnp flash (decode/chunked prefill path)."""
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (2, 64, 4, 32))
        k = jax.random.normal(ks[1], (2, 192, 2, 32))
        v = jax.random.normal(ks[2], (2, 192, 2, 32))
        out = flash_attention(q, k, v, causal=True, q_offset=128,
                              q_chunk=32, kv_chunk=64)
        oracle = attention_ref(q, k, v, causal=True, q_offset=128)
        np.testing.assert_allclose(np.asarray(out), np.asarray(oracle),
                                   atol=2e-5, rtol=2e-5)

    def test_causal_skip_equals_full_iteration(self):
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks[0], (1, 256, 2, 32))
        k = jax.random.normal(ks[1], (1, 256, 2, 32))
        v = jax.random.normal(ks[2], (1, 256, 2, 32))
        a = flash_attention(q, k, v, causal=True, causal_skip=True,
                            q_chunk=64, kv_chunk=64)
        b = flash_attention(q, k, v, causal=True, causal_skip=False,
                            q_chunk=64, kv_chunk=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


class TestNFAKernel:
    @pytest.mark.parametrize("N,M", [(256, 4), (512, 8), (1024, 16),
                                     (256, 32)])
    @pytest.mark.parametrize("use_binding", [0, 1])
    def test_allclose_vs_oracle(self, N, M, use_binding):
        rng = np.random.default_rng(N + M)
        state = jnp.asarray(rng.integers(0, M, N), jnp.int32)
        bind = jnp.asarray(rng.integers(0, 5, N), jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.7)
        tcol = jnp.asarray(
            np.minimum(np.arange(M) + rng.integers(0, 2, M), M - 1),
            jnp.int32)
        ns, comp = nfa_advance_pallas(state, bind, active, tcol, 2, M - 1,
                                      use_binding, interpret=True)
        nsr, compr = ref.nfa_advance_ref(state, bind, active, tcol, 2,
                                         M - 1, use_binding)
        np.testing.assert_array_equal(np.asarray(ns), np.asarray(nsr))
        np.testing.assert_array_equal(np.asarray(comp), np.asarray(compr))

    @pytest.mark.parametrize("N", [1, 77, 255, 300, 513])
    @pytest.mark.parametrize("use_binding", [0, 1])
    def test_non_tile_multiple_n(self, N, use_binding):
        """Odd N pads with inactive slots and slices back — the former
        `assert N % tile == 0` path (PM stores are any size)."""
        rng = np.random.default_rng(N * 13 + use_binding)
        M = 8
        state = jnp.asarray(rng.integers(0, M, N), jnp.int32)
        bind = jnp.asarray(rng.integers(0, 5, N), jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.7)
        tcol = jnp.asarray(
            np.minimum(np.arange(M) + rng.integers(0, 2, M), M - 1),
            jnp.int32)
        ns, comp = nfa_advance_pallas(state, bind, active, tcol, 2, M - 1,
                                      use_binding, interpret=True)
        assert ns.shape == (N,) and comp.shape == (N,)
        nsr, compr = ref.nfa_advance_ref(state, bind, active, tcol, 2,
                                         M - 1, use_binding)
        np.testing.assert_array_equal(np.asarray(ns), np.asarray(nsr))
        np.testing.assert_array_equal(np.asarray(comp), np.asarray(compr))


class TestShedKernels:
    @pytest.mark.parametrize("N,bins,m", [(256, 8, 4), (512, 16, 8),
                                          (1024, 32, 12)])
    def test_lookup_allclose(self, N, bins, m):
        rng = np.random.default_rng(N)
        state = jnp.asarray(rng.integers(0, m, N), jnp.int32)
        rw = jnp.asarray(rng.integers(1, bins * 32, N), jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.8)
        table = jnp.asarray(rng.random((bins, m)), jnp.float32)
        u = utility_lookup_pallas(state, rw, active, table, bin_size=32,
                                  interpret=True)
        ur = ref.utility_lookup_ref(state, rw, active, table, 32)
        np.testing.assert_allclose(
            np.where(np.asarray(active), np.asarray(u), 0),
            np.where(np.asarray(active), np.asarray(ur), 0), atol=1e-5)

    def test_histogram_allclose(self):
        rng = np.random.default_rng(0)
        u = jnp.asarray(rng.random(512) * 10, jnp.float32)
        h = utility_histogram_pallas(u, jnp.float32(0.0), jnp.float32(10.0),
                                     nbins=32, interpret=True)
        hr = ref.histogram_ref(u, jnp.float32(0.0), jnp.float32(10.0), 32)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        assert int(h.sum()) == 512

    @pytest.mark.parametrize("rho", [0, 1, 17, 100, 400])
    def test_shed_lowest_count_and_threshold(self, rho):
        rng = np.random.default_rng(rho)
        N, bins, m = 512, 16, 8
        state = jnp.asarray(rng.integers(0, m, N), jnp.int32)
        rw = jnp.asarray(rng.integers(1, bins * 32, N), jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.8)
        table = jnp.asarray(rng.random((bins, m)), jnp.float32)
        new = ops.shed_lowest_pallas(active, state, rw, table,
                                     jnp.int32(rho), bin_size=32,
                                     interpret=True)
        refm = ref.shed_lowest_ref(active, state, rw, table,
                                   jnp.int32(rho), 32)
        # exact same number dropped...
        assert int(new.sum()) == int(refm.sum())
        # ...and the kept-utility floor matches (same threshold semantics)
        u = ref.utility_lookup_ref(state, rw, active, table, 32)
        kept_min = np.where(np.asarray(new), np.asarray(u), np.inf).min()
        kept_min_ref = np.where(np.asarray(refm), np.asarray(u),
                                np.inf).min()
        np.testing.assert_allclose(kept_min, kept_min_ref, atol=1e-5)


class TestShedKernelVsShedderOracle:
    """utility_histogram_pallas exact-ρ threshold plan vs the
    core.shedder.drop_lowest_utility oracle — tie-heavy utility
    distributions and non-tile-multiple N (the former `assert N % tile`
    path)."""

    def _assert_matches_oracle(self, active, state, rw, table, rho,
                               bin_size=32):
        new = ops.shed_lowest_pallas(active, state, rw, table,
                                     jnp.int32(rho), bin_size=bin_size,
                                     interpret=True)
        u = ref.utility_lookup_ref(state, rw, active, table, bin_size)
        u_act = jnp.where(active, u, jnp.inf)
        oracle = shedder.drop_lowest_utility(active, u_act, jnp.int32(rho))
        n_active = int(jnp.sum(active))
        # Exactly the oracle's drop count (min(rho, n_active))...
        assert int(new.sum()) == int(oracle.sum())
        assert n_active - int(new.sum()) == min(rho, n_active)
        # ...never revives inactive slots...
        assert not bool(jnp.any(new & ~active))
        # ...and every dropped utility ≤ every kept utility up to the
        # threshold plan's guarantee: the final refinement bucket is
        # span/nbins^levels wide (nbins=64, levels=3 here), and ties
        # inside it may break differently from the oracle's argsort.
        dropped = np.asarray(active & ~new)
        kept = np.asarray(new)
        if dropped.any() and kept.any():
            un = np.asarray(u)
            act = np.asarray(active)
            span = un[act].max() - un[act].min()
            tol = max(span / 64.0 ** 3, 1e-6) * 1.01
            assert un[dropped].max() <= un[kept].min() + tol

    @pytest.mark.parametrize("N", [77, 300, 500, 513])
    @pytest.mark.parametrize("rho", [0, 5, 64, 1000])
    def test_non_tile_multiple_n(self, N, rho):
        rng = np.random.default_rng(N * 7 + rho)
        bins, m = 16, 8
        state = jnp.asarray(rng.integers(0, m, N), jnp.int32)
        rw = jnp.asarray(rng.integers(1, bins * 32, N), jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.8)
        table = jnp.asarray(rng.random((bins, m)), jnp.float32)
        self._assert_matches_oracle(active, state, rw, table, rho)

    @pytest.mark.parametrize("n_distinct", [1, 2, 3])
    @pytest.mark.parametrize("rho", [1, 17, 100])
    def test_tie_heavy_distributions(self, n_distinct, rho):
        """Utility tables with only a few distinct values put (nearly) all
        the mass in one histogram bucket — the exact-ρ tie-break on the
        boundary-bucket remainder must still hit the budget exactly."""
        rng = np.random.default_rng(n_distinct * 31 + rho)
        N, bins, m = 384, 16, 8
        levels = np.linspace(0.25, 0.75, n_distinct)
        table = jnp.asarray(rng.choice(levels, size=(bins, m)), jnp.float32)
        state = jnp.asarray(rng.integers(0, m, N), jnp.int32)
        # rw pinned to exact bin edges → no interpolation → pure ties.
        rw = jnp.asarray(rng.integers(1, bins, N) * 32, jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.9)
        self._assert_matches_oracle(active, state, rw, table, rho)

    def test_all_equal_utilities_exact_budget(self):
        """Degenerate lo == hi histogram plan: every PM ties."""
        N, bins, m = 200, 8, 4
        state = jnp.zeros((N,), jnp.int32)
        rw = jnp.full((N,), 64, jnp.int32)
        table = jnp.full((bins, m), 0.5, jnp.float32)
        active = jnp.ones((N,), bool)
        for rho in (0, 1, 50, 199, 200, 999):
            self._assert_matches_oracle(active, state, rw, table, rho)

    @pytest.mark.parametrize("N", [100, 260])
    def test_histogram_padding_not_counted(self, N):
        """Padded tail (NaN) must not leak into any bucket."""
        rng = np.random.default_rng(N)
        u = jnp.asarray(rng.random(N) * 10, jnp.float32)
        h = utility_histogram_pallas(u, jnp.float32(0.0), jnp.float32(10.0),
                                     nbins=16, interpret=True)
        hr = ref.histogram_ref(u, jnp.float32(0.0), jnp.float32(10.0), 16)
        np.testing.assert_array_equal(np.asarray(h), np.asarray(hr))
        assert int(h.sum()) == N

    @pytest.mark.parametrize("N", [100, 321])
    def test_lookup_padding_sliced_off(self, N):
        rng = np.random.default_rng(N)
        bins, m = 8, 4
        state = jnp.asarray(rng.integers(0, m, N), jnp.int32)
        rw = jnp.asarray(rng.integers(1, bins * 32, N), jnp.int32)
        active = jnp.asarray(rng.random(N) < 0.8)
        table = jnp.asarray(rng.random((bins, m)), jnp.float32)
        u = utility_lookup_pallas(state, rw, active, table, bin_size=32,
                                  interpret=True)
        assert u.shape == (N,)
        ur = ref.utility_lookup_ref(state, rw, active, table, 32)
        np.testing.assert_allclose(
            np.where(np.asarray(active), np.asarray(u), 0),
            np.where(np.asarray(active), np.asarray(ur), 0), atol=1e-5)
