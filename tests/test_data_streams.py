"""Property tests for the stream generators and classifier."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements-dev.txt; deterministic
    from _hyp_fallback import given, settings, st  # fallback sweeps

from repro.cep import patterns as pat
from repro.data import streams


class TestGenerators:
    @given(st.integers(1000, 5000), st.integers(0, 10))
    @settings(max_examples=10, deadline=None)
    def test_stock_stream_well_formed(self, n, seed):
        raw = streams.gen_stock(n, seed=seed)
        assert raw.n == n
        assert raw.type_id.min() >= 0
        assert raw.type_id.max() < raw.num_types
        assert set(np.unique(raw.attr)) <= {0, 1}

    def test_stock_hot_fraction(self):
        raw = streams.gen_stock(50_000, pattern_symbols=10,
                                hot_fraction=0.9, seed=0)
        hot = (raw.type_id < 10).mean()
        assert abs(hot - 0.9) < 0.02

    def test_soccer_striker_binding_points_backwards(self):
        raw = streams.gen_soccer(20_000, seed=0)
        defend = np.where(raw.attr == 1)[0]
        strikers = np.where(raw.attr == 2)[0]
        if len(defend) and len(strikers):
            first_def_after = defend[defend > strikers[0]][0]
            assert raw.group[first_def_after] >= 0

    def test_bus_delays_cluster_on_hot_stops(self):
        raw = streams.gen_bus(100_000, p_delay=0.05, burst_boost=5.0,
                              seed=0)
        delayed_rate = raw.attr.mean()
        assert delayed_rate > 0.05  # boosted stops raise the average


class TestClassifier:
    def test_q1_classes_match_symbols(self):
        spec = pat.make_q1(window_size=100, num_symbols=10)
        raw = streams.gen_stock(10_000, seed=1)
        ev = streams.classify([spec], raw, rate=100.0)
        cls = np.asarray(ev.ev_class[:, 0])
        rising_pattern = (raw.type_id < 10) & (raw.attr == 1)
        assert (cls[rising_pattern] == raw.type_id[rising_pattern] + 1).all()
        assert (cls[~rising_pattern] == 0).all()

    def test_arrival_times_monotone(self):
        spec = pat.make_q1(window_size=100)
        raw = streams.gen_stock(1000, seed=2)
        ev = streams.classify([spec], raw, rate=123.0)
        arr = np.asarray(ev.arrival)
        assert (np.diff(arr) > 0).all()
        np.testing.assert_allclose(arr[1] - arr[0], 1 / 123.0, rtol=1e-4)

    def test_ebl_priorities_in_unit_interval(self):
        spec = pat.make_q4(any_n=3, window_size=1000, slide=100)
        raw = streams.gen_bus(5000, seed=3)
        ev = streams.classify([spec], raw, rate=10.0)
        raw_prio = np.asarray(ev.ebl_raw)
        assert raw_prio.min() >= 0.0 and raw_prio.max() <= 1.0

    def test_q4_windows_open_on_slide(self):
        spec = pat.make_q4(any_n=3, window_size=1000, slide=250)
        raw = streams.gen_bus(2000, seed=4)
        ev = streams.classify([spec], raw, rate=10.0)
        opens = np.where(np.asarray(ev.ev_open[:, 0]))[0]
        assert (opens % 250 == 0).all()
        assert len(opens) == 8
