"""Hot-path equivalence properties (DESIGN.md §8).

Three families, all BITWISE:
  1. the O(N) cumsum spawn allocator vs the legacy stable-argsort
     allocator, over random overloaded streams (hypothesis / fallback);
  2. backend="pallas" (repro.kernels dispatch, interpret on CPU) vs
     backend="xla" for run_engine AND run_engine_chunk, across all four
     shedders and both spawn modes;
  3. the static pattern census (kinds / spawn_modes specialization) vs
     the always-compute-both "mixed" configuration.
"""
import dataclasses

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # declared in requirements-dev.txt; deterministic
    from _hyp_fallback import given, settings, st  # fallback sweeps

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro import runtime as RT

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)
SHEDDERS = (eng.SHED_NONE, eng.SHED_PSPICE, eng.SHED_PMBL, eng.SHED_EBL)


def _assert_tree_equal(a, b, what=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=what)


def _spec(name):
    if name == "q1":  # SEQ / SPAWN_AT_OPEN
        return pat.make_q1(window_size=400, num_symbols=4)
    return pat.make_q4(any_n=3, window_size=120, slide=40)  # ANY / IN_WINDOWS


def _setup(name, max_pms=48, n=600, seed=0, rate_mult=1.0):
    specs = [_spec(name)]
    cp = pat.compile_patterns(specs)
    # Tight bound + overload rate so the shed path actually executes.
    cfg = runner.default_config(cp, max_pms=max_pms, latency_bound=0.005,
                                gather_stats=True,
                                shedder=eng.SHED_PSPICE, **COST)
    model = eng.make_model(cp, cfg)
    rate = rate_mult * 3.0 / (cfg.c_base + cfg.c_match * 0.3 * max_pms)
    raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                            p_class=0.05, seed=100 + seed)
    ev = streams.classify(specs, raw, rate=rate, seed=seed)
    return cfg, model, ev


class TestSpawnAllocatorEquivalence:
    """The O(N) free-list compaction must pick EXACTLY the slots the
    legacy stable argsort picked — whole-carry bitwise equality over
    random streams, including streams that overflow the store and
    streams that shed."""

    @given(st.integers(0, 7), st.integers(1, 3))
    @settings(max_examples=12, deadline=None)
    def test_random_streams_bitwise_identical(self, seed, rate_x):
        cfg, model, ev = _setup("q1", seed=seed, rate_mult=float(rate_x))
        c_new, o_new = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        leg = dataclasses.replace(cfg, spawn_alloc="argsort")
        c_old, o_old = eng.run_engine(leg, model, ev, eng.init_carry(leg))
        _assert_tree_equal(c_new, c_old, f"carry seed={seed}")
        _assert_tree_equal(o_new, o_old, f"outs seed={seed}")

    @pytest.mark.parametrize("name", ["q1", "q4"])
    @pytest.mark.parametrize("shedder", SHEDDERS)
    def test_all_shedders_and_spawn_modes(self, name, shedder):
        cfg, model, ev = _setup(name)
        cfg = dataclasses.replace(cfg, shedder=shedder)
        c_new, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        leg = dataclasses.replace(cfg, spawn_alloc="argsort")
        c_old, _ = eng.run_engine(leg, model, ev, eng.init_carry(leg))
        _assert_tree_equal(c_new, c_old, f"{name}/{shedder}")

    def test_overflowing_store_bitwise_identical(self):
        """Tiny store: candidates exceed free slots, exercising the
        rank >= n_free sentinel path of both allocators."""
        cfg, model, ev = _setup("q4", max_pms=4)
        c_new, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        leg = dataclasses.replace(cfg, spawn_alloc="argsort")
        c_old, _ = eng.run_engine(leg, model, ev, eng.init_carry(leg))
        assert float(c_new.overflow) > 0, "fixture must actually overflow"
        _assert_tree_equal(c_new, c_old, "overflow carry")


class TestBackendEquivalence:
    """EngineConfig(backend="pallas") routes advance / utility lookup /
    shed through repro.kernels.ops; results must be bitwise-equal to the
    jnp reference backend (one-hot matmuls touch exactly one nonzero,
    and the histogram plans share bucket_edges)."""

    @pytest.mark.parametrize("name", ["q1", "q4"])
    @pytest.mark.parametrize("shedder", SHEDDERS)
    def test_run_engine(self, name, shedder):
        cfg, model, ev = _setup(name, max_pms=32, n=150)
        cfg = dataclasses.replace(cfg, shedder=shedder)
        cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        cfg_p = dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS)
        cp_, op_ = eng.run_engine(cfg_p, model, ev, eng.init_carry(cfg_p))
        _assert_tree_equal(cx, cp_, f"{name}/{shedder} carry")
        _assert_tree_equal(ox, op_, f"{name}/{shedder} outs")

    def test_run_engine_chunk(self):
        """Chunked pallas execution replays the monolithic xla scan."""
        cfg, model, ev = _setup("q1", max_pms=32, n=320, rate_mult=2.0)
        cx, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(cx.pms_shed) > 0, "fixture must actually shed"
        cfg_p = dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS)
        carry = eng.init_carry(cfg_p)
        for start, piece in RT.iter_chunks(ev, 64):
            carry, _ = eng.run_engine_chunk(cfg_p, model, piece, carry,
                                            jnp.int32(start))
        _assert_tree_equal(cx, carry, "chunked pallas vs monolithic xla")


class TestCensusEquivalence:
    """kinds / spawn_modes specialization skips dead per-event ops; the
    skipped ops must be provably dead — bitwise equality vs "mixed"."""

    @pytest.mark.parametrize("name", ["q1", "q4"])
    @pytest.mark.parametrize("shedder", SHEDDERS)
    def test_specialized_matches_mixed(self, name, shedder):
        cfg, model, ev = _setup(name)
        cfg = dataclasses.replace(cfg, shedder=shedder)
        assert cfg.kinds != "mixed" and cfg.spawn_modes != "mixed"
        mixed = dataclasses.replace(cfg, kinds="mixed", spawn_modes="mixed")
        c1, o1 = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        c2, o2 = eng.run_engine(mixed, model, ev, eng.init_carry(mixed))
        _assert_tree_equal(c1, c2, f"{name}/{shedder} census carry")
        _assert_tree_equal(o1, o2, f"{name}/{shedder} census outs")

    def test_default_config_census(self):
        cp = pat.compile_patterns([_spec("q1"), _spec("q4")])
        cfg = runner.default_config(cp)
        assert cfg.kinds == "mixed" and cfg.spawn_modes == "mixed"
        cp1 = pat.compile_patterns([_spec("q1")])
        cfg1 = runner.default_config(cp1)
        assert cfg1.kinds == "seq" and cfg1.spawn_modes == "at_open"


class TestScenarioBackendParity:
    """Backend parity on the QUALITY SWEEP's scenario configurations
    (repro.data.streams.SCENARIOS) — the realistic multi-pattern shapes
    the paper evaluation runs, not only the synthetic q1/q4 fixtures:
    the stock Q1 window grid (3 SEQ patterns), the soccer Q3 any_n grid
    (8 bound ANY patterns) and the bus Q4 slide windows, each at an odd,
    non-tile-multiple PM-store size, with match emission on and the
    pSPICE shed path hot."""

    @pytest.mark.parametrize("scenario,max_pms",
                             [("stock", 37), ("soccer", 53), ("bus", 61)])
    def test_scenario_xla_pallas_bitwise(self, scenario, max_pms):
        sc = streams.get_scenario(scenario)
        specs = sc.specs()
        cp = pat.compile_patterns(specs)
        cfg = runner.default_config(cp, max_pms=max_pms,
                                    latency_bound=0.005,
                                    shedder=eng.SHED_PSPICE,
                                    emit_matches=True, **COST)
        model = eng.make_model(cp, cfg)
        rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * max_pms)
        ev = streams.classify(specs, sc.raw(n=500), rate=rate, seed=0)
        cx, ox = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(cx.pms_shed) > 0, "fixture must exercise the shed path"
        cfg_p = dataclasses.replace(cfg, backend=eng.BACKEND_PALLAS)
        cp_, op_ = eng.run_engine(cfg_p, model, ev, eng.init_carry(cfg_p))
        _assert_tree_equal(cx, cp_, f"{scenario} carry")
        _assert_tree_equal(ox, op_, f"{scenario} outs")
        # The scenario's match identities decode identically per backend.
        assert eng.match_sets(ox) == eng.match_sets(op_), scenario


class TestNoSortInHotPath:
    """The compiled per-event step must contain no sort for the default
    config — spawn allocation and both shed plans are sort-free.
    Asserted through the repro.analysis rule API (DESIGN.md §11), the
    same rule CI's check_all sweep evaluates."""

    @pytest.mark.parametrize("shedder",
                             [eng.SHED_PSPICE, eng.SHED_PMBL])
    def test_compiled_artifact_has_no_sort(self, shedder):
        from repro import analysis as A
        cfg, model, ev = _setup("q1", n=64)
        cfg = dataclasses.replace(cfg, shedder=shedder)
        art = A.trace_artifact(eng.run_engine, cfg, model, ev,
                               eng.init_carry(cfg),
                               name=f"no-sort[{shedder}]", n_events=64)
        fs = [f for f in A.run_rules(
            art, A.get_contract("cep.run_engine")) if f.rule == "no-sort"]
        assert fs and all(f.ok for f in fs), [f.evidence for f in fs]

    def test_legacy_plan_does_sort(self):
        """Positive control: the rule actually detects — the legacy
        config's artifact must TRIP no-sort (both at the jaxpr and the
        HLO level), proving the analyzer is live."""
        from repro import analysis as A
        cfg, model, ev = _setup("q1", n=64)
        cfg = dataclasses.replace(cfg, spawn_alloc="argsort",
                                  shed_plan="sort")
        art = A.trace_artifact(eng.run_engine, cfg, model, ev,
                               eng.init_carry(cfg), name="legacy",
                               n_events=64)
        fs = [f for f in A.run_rules(
            art, A.get_contract("cep.run_engine")) if f.rule == "no-sort"]
        assert fs and any(not f.ok for f in fs)
        assert art.census.get("sort", 0) > 0
