"""repro.runtime: chunked-execution equivalence, tenant lanes, online
refresh, drifting streams, and the PR's satellite fixes (DESIGN.md §7)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro import runtime as RT
from repro.dist import sharding as SH

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)
N_EVENTS = 2000


def _assert_tree_equal(a, b, what=""):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=what)


@pytest.fixture(scope="module")
def setup():
    """One shedding config shared by every test (compile-cache friendly):
    tight latency bound + overload rate, so the shed cond actually fires."""
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=48, latency_bound=0.005,
                                gather_stats=True, shedder=eng.SHED_PSPICE,
                                **COST)
    model = eng.make_model(cp, cfg)
    rate = 3.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)

    def make_events(seed, rate_mult=1.0, n=N_EVENTS):
        raw = streams.gen_stock(n, num_symbols=50, pattern_symbols=4,
                                p_class=0.05, seed=100 + seed)
        return streams.classify(specs, raw, rate=rate * rate_mult, seed=seed)

    return specs, cfg, model, make_events


class TestChunkedEquivalence:
    @pytest.mark.parametrize("chunk", [64, 100, 256, N_EVENTS])
    def test_chunked_bitwise_equals_monolithic(self, setup, chunk):
        """Several chunk sizes — including non-divisors of the stream
        length — replay the monolithic scan bit for bit."""
        _, cfg, model, make_events = setup
        ev = make_events(0)
        c_mono, o_mono = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        assert float(c_mono.pms_shed) > 0, "fixture must actually shed"

        carry = eng.init_carry(cfg)
        outs = []
        for start, piece in RT.iter_chunks(ev, chunk):
            carry, o = eng.run_engine_chunk(cfg, model, piece, carry,
                                            jnp.int32(start))
            outs.append(o)
        o_cat = jax.tree.map(lambda *xs: jnp.concatenate(xs), *outs)
        _assert_tree_equal(c_mono, carry, f"carry, chunk={chunk}")
        _assert_tree_equal(o_mono, o_cat, f"outs, chunk={chunk}")

    def test_stream_runtime_ragged_pushes(self, setup):
        """Pushes of arbitrary sizes re-chunk through the buffer; flush
        drains the tail; the result is still bitwise-identical."""
        _, cfg, model, make_events = setup
        ev = make_events(0)
        c_mono, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        srt = RT.StreamRuntime(cfg, model,
                               rt=RT.RuntimeConfig(chunk_size=256))
        for s in range(0, N_EVENTS, 700):
            srt.push(RT.slice_events(ev, s, min(s + 700, N_EVENTS)))
        srt.flush()
        _assert_tree_equal(c_mono, srt.carry, "ragged pushes")
        assert srt.events_processed == N_EVENTS


class TestTenantLanes:
    L = 3

    @pytest.fixture(scope="class")
    def lane_run(self, setup):
        _, cfg, model, make_events = setup
        evs = [make_events(i, rate_mult=1.0 + 0.2 * i)
               for i in range(self.L)]
        evL = RT.stack(evs)
        mL = RT.broadcast_model(model, self.L)
        cL, oL = RT.run_chunk_lanes(cfg, mL, evL,
                                    RT.init_lane_carries(cfg, self.L),
                                    jnp.int32(0))
        return evs, evL, mL, cL, oL

    def test_lanes_bitwise_equal_sequential(self, setup, lane_run):
        _, cfg, model, _ = setup
        evs, _, _, cL, oL = lane_run
        assert float(np.asarray(cL.pms_shed).sum()) > 0
        for lane in range(self.L):
            c_i, o_i = eng.run_engine(cfg, model, evs[lane],
                                      eng.init_carry(cfg, seed=lane))
            _assert_tree_equal(c_i, RT.unstack_lane(cL, lane),
                               f"lane {lane} carry")
            _assert_tree_equal(o_i, RT.unstack_lane(oL, lane),
                               f"lane {lane} outs")

    def test_merge_carries(self, setup, lane_run):
        _, cfg, _, _ = setup
        _, _, _, cL, _ = lane_run
        merged = eng.merge_carries(cL)
        P = cfg.num_patterns
        assert merged.complex_count.shape == (self.L * P,)
        assert merged.pms.active.shape == (self.L * P, cfg.max_pms)
        np.testing.assert_allclose(
            np.asarray(merged.complex_count),
            np.asarray(cL.complex_count).reshape(-1))
        assert float(merged.pms_shed) == pytest.approx(
            float(np.asarray(cL.pms_shed).sum()))
        assert float(merged.sim_time) == pytest.approx(
            float(np.asarray(cL.sim_time).max()))

    def test_sharded_lanes_match_vmapped(self, setup, lane_run):
        """On the host's (1-device) mesh the shard_map path must agree
        exactly with the plain lane-batched path."""
        _, cfg, _, _ = setup
        _, evL, mL, cL, oL = lane_run
        c_sh, o_sh = SH.run_chunk_lanes_sharded(
            cfg, mL, evL, RT.init_lane_carries(cfg, self.L), jnp.int32(0))
        _assert_tree_equal(cL, c_sh, "sharded carry")
        _assert_tree_equal(oL, o_sh, "sharded outs")

    def test_multitenant_runtime_matches_lane_scan(self, setup, lane_run):
        _, cfg, _, _ = setup
        _, evL, mL, cL, _ = lane_run
        mt = RT.MultiTenantRuntime(cfg, mL, num_lanes=self.L,
                                   rt=RT.RuntimeConfig(chunk_size=512))
        mt.push(evL, flush=True)
        _assert_tree_equal(cL, mt.carry, "runtime carry")
        assert mt.events_processed == self.L * N_EVENTS


class TestLaneSpecs:
    def _cfg(self, p):
        return eng.EngineConfig(num_patterns=p, max_states=4, max_classes=4,
                                max_pms=32)

    def test_two_axis_mesh_composes_lanes_and_patterns(self):
        from jax.sharding import PartitionSpec as P
        mesh = SH.abstract_mesh((2, 2), ("data", "model"))
        sp = SH.lane_specs(mesh, self._cfg(4), num_lanes=4)
        assert sp["lane_axis"] == "data" and sp["pattern_axis"] == "model"
        assert sp["carry"].pms.active == P("data", "model", None)
        assert sp["events"].ev_class == P("data", None, "model")
        assert sp["carry"].sim_time == P("data")
        assert sp["out"].l_e == P("data", None)

    def test_indivisible_lane_count_falls_back(self):
        mesh = SH.abstract_mesh((2, 2), ("data", "model"))
        sp = SH.lane_specs(mesh, self._cfg(4), num_lanes=3)
        assert sp["lane_axis"] is None and sp["pattern_axis"] == "model"

    def test_same_axis_shards_lanes_only(self):
        mesh = SH.abstract_mesh((4,), ("data",))
        sp = SH.lane_specs(mesh, self._cfg(4), num_lanes=4,
                           pattern_axis="data")
        assert sp["lane_axis"] == "data" and sp["pattern_axis"] is None


class TestChunkBuffer:
    def _ev(self, n, tag=0):
        return eng.EventBatch(
            ev_class=jnp.full((n, 1), tag, jnp.int32),
            ev_bind=jnp.zeros((n, 1), jnp.int32),
            ev_open=jnp.zeros((n, 1), bool),
            ev_id=jnp.arange(n, dtype=jnp.int32),
            ev_rand=jnp.zeros((n,), jnp.float32),
            ebl_raw=jnp.zeros((n,), jnp.float32),
            arrival=jnp.arange(n, dtype=jnp.float32))

    def test_ragged_pushes_rechunk(self):
        buf = RT.ChunkBuffer(64)
        got = buf.push(self._ev(100))
        assert [(s, RT.num_events(e)) for s, e in got] == [(0, 64)]
        assert buf.pending == 36
        got = buf.push(self._ev(100))
        assert [(s, RT.num_events(e)) for s, e in got] == [(64, 64), (128, 64)]
        got = buf.drain()
        assert [(s, RT.num_events(e)) for s, e in got] == [(192, 8)]
        assert buf.pending == 0 and buf.drain() == []

    def test_lane_stacked_axis(self):
        buf = RT.ChunkBuffer(32, axis=1)
        evL = RT.stack([self._ev(50), self._ev(50, tag=1)])
        got = buf.push(evL)
        assert len(got) == 1
        start, piece = got[0]
        assert start == 0 and piece.ev_class.shape == (2, 32, 1)
        (start, piece), = buf.drain()
        assert start == 32 and piece.ev_class.shape == (2, 18, 1)

    def test_outputs_never_alias_pushed_batch(self):
        """Full-range jax slices alias their input; everything the buffer
        hands out feeds DONATING jits, so it must own its buffers — a
        chunk-multiple push or a drained sub-chunk tail returning the
        caller's own arrays would let donation delete them."""
        ev = self._ev(64)
        _, region, n = RT.ChunkBuffer(64).push_region(ev)
        assert n == 1
        for a, b in zip(jax.tree.leaves(region), jax.tree.leaves(ev)):
            assert a is not b
        buf = RT.ChunkBuffer(64)
        tail_in = self._ev(10)
        assert buf.push_region(tail_in)[2] == 0
        (_, tail), = buf.drain()
        for a, b in zip(jax.tree.leaves(tail), jax.tree.leaves(tail_in)):
            assert a is not b
        (_, piece), = RT.ChunkBuffer(64).push(self._ev(64))
        for a, b in zip(jax.tree.leaves(piece), jax.tree.leaves(ev)):
            assert a is not b

    def test_zero_length_push(self):
        """An empty push is a no-op at every buffer state: empty buffer,
        buffered tail, and interleaved with real pushes."""
        buf = RT.ChunkBuffer(64)
        start, region, n = buf.push_region(self._ev(0))
        assert (start, region, n) == (0, None, 0) and buf.pending == 0
        assert buf.push(self._ev(0)) == [] and buf.drain() == []
        # with a buffered tail the empty push must not disturb it
        buf.push(self._ev(50))
        assert buf.push(self._ev(0)) == [] and buf.pending == 50
        got = buf.push(self._ev(30))
        assert [(s, RT.num_events(e)) for s, e in got] == [(0, 64)]
        assert buf.pending == 16

    def test_zero_length_push_through_runtime(self, setup):
        """StreamRuntime.push of an empty batch returns no stats and does
        not perturb the stream (bitwise)."""
        _, cfg, model, make_events = setup
        ev = make_events(0)
        c_mono, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        srt = RT.StreamRuntime(cfg, model,
                               rt=RT.RuntimeConfig(chunk_size=256))
        assert srt.push(RT.slice_events(ev, 0, 0)) == []
        srt.push(ev)
        assert srt.push(RT.slice_events(ev, 0, 0)) == []
        srt.flush()
        _assert_tree_equal(c_mono, srt.carry, "empty pushes interleaved")

    def test_push_larger_than_one_group(self, setup):
        """A single push spanning MANY chunk groups (here 2000 events =
        8 chunks at group_chunks=3: groups of 3/3/2 + a short tail) splits
        correctly and stays bitwise-identical to the monolithic scan."""
        _, cfg, model, make_events = setup
        ev = make_events(0)
        c_mono, _ = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        srt = RT.StreamRuntime(cfg, model, rt=RT.RuntimeConfig(
            chunk_size=250, group_chunks=3))
        stats = srt.push(ev, flush=True)
        assert len(stats) == 8   # 2000 events = 8 chunks, groups of 3/3/2
        _assert_tree_equal(c_mono, srt.carry, "one push, many groups")

    def test_ragged_pushes_interleaved_with_refresh_boundaries(self, setup):
        """Ragged pushes + grouped dispatch + refresh cadence: groups must
        truncate at refresh boundaries regardless of push phase, so the
        grouped runtime refreshes on exactly the same chunks — and ends in
        exactly the same state — as chunk-at-a-time execution."""
        specs, cfg, model, make_events = setup
        ev = make_events(0)
        rcfg = RT.RefreshConfig(every_chunks=3, min_observations=64.0)

        def run(group_chunks, sizes):
            srt = RT.StreamRuntime(
                cfg, model, specs=specs,
                rt=RT.RuntimeConfig(chunk_size=200, refresh=rcfg,
                                    group_chunks=group_chunks))
            s = 0
            for sz in sizes:
                srt.push(RT.slice_events(ev, s, min(s + sz, N_EVENTS)))
                s += sz
            srt.flush()
            return srt

        sizes = [130, 470, 900, 57, 443]   # ragged, refresh-unaligned
        grouped = run(4, sizes)
        serial = run(1, [N_EVENTS])
        _assert_tree_equal(serial.carry, grouped.carry,
                           "grouped+ragged vs serial with refresh")
        assert [c.refreshed for c in grouped.telemetry.chunks] \
            == [c.refreshed for c in serial.telemetry.chunks]
        assert grouped.refresh_state.refresh_count \
            == serial.refresh_state.refresh_count > 0


class TestRefresh:
    def test_refresh_updates_tables_and_latency_model(self, setup):
        specs, cfg, model, make_events = setup
        rcfg = RT.RefreshConfig(every_chunks=2, min_observations=64.0)
        model_w = RT.prepare_model(specs, model, rcfg)
        assert model_w.ut_tables.shape[1] == RT.table_width(specs, 64)
        carry, _ = eng.run_engine(cfg, model_w, make_events(0),
                                  eng.init_carry(cfg))
        state = RT.RefreshState()
        m2, carry2, did = RT.refresh_model(specs, cfg, model_w, carry,
                                           rcfg, state)
        assert did and state.refresh_count == 1
        # shapes stable (no chunk retrace), contents updated
        assert m2.ut_tables.shape == model_w.ut_tables.shape
        assert not np.array_equal(np.asarray(m2.ut_tables),
                                  np.asarray(model_w.ut_tables))
        assert not np.array_equal(np.asarray(m2.f_model.a),
                                  np.asarray(model_w.f_model.a))

    def test_min_observation_gate(self, setup):
        specs, cfg, model, _ = setup
        rcfg = RT.RefreshConfig(min_observations=1e12)
        state = RT.RefreshState()
        _, _, did = RT.refresh_model(specs, cfg, model,
                                     eng.init_carry(cfg), rcfg, state)
        assert not did and state.skipped_obs == 1

    def test_drift_gate_skips_stable_stream(self, setup):
        specs, cfg, model, make_events = setup
        rcfg = RT.RefreshConfig(min_observations=64.0, drift_threshold=1e9)
        carry, _ = eng.run_engine(cfg, model, make_events(0),
                                  eng.init_carry(cfg))
        state = RT.RefreshState()
        _, _, did1 = RT.refresh_model(specs, cfg, model, carry, rcfg, state)
        _, _, did2 = RT.refresh_model(specs, cfg, model, carry, rcfg, state)
        assert did1 and not did2 and state.skipped_drift == 1

    def test_runtime_refreshes_on_cadence(self, setup):
        specs, cfg, model, make_events = setup
        srt = RT.StreamRuntime(
            cfg, model, specs=specs,
            rt=RT.RuntimeConfig(
                chunk_size=500,
                refresh=RT.RefreshConfig(every_chunks=2,
                                         min_observations=64.0)))
        srt.push(make_events(0), flush=True)
        assert srt.refresh_state.refresh_count >= 1
        assert srt.telemetry.aggregate()["refreshes"] >= 1

    def test_refresh_requires_gather_stats(self, setup):
        specs, cfg, model, _ = setup
        no_stats = dataclasses.replace(cfg, gather_stats=False)
        with pytest.raises(ValueError, match="gather_stats"):
            RT.StreamRuntime(
                no_stats, model, specs=specs,
                rt=RT.RuntimeConfig(refresh=RT.RefreshConfig()))


class TestLongStreamGuards:
    """Unbounded streams cross int32 boundaries (~2.1B events)."""

    def test_wrap_event_index_past_int32(self):
        assert int(eng.wrap_event_index(7)) == 7
        assert int(eng.wrap_event_index(2**31 + 5)) == -(2**31) + 5
        # int32 difference arithmetic survives the wrap
        i = eng.wrap_event_index(2**31 + 5)
        open_idx = eng.wrap_event_index(2**31 - 5)
        assert int(i - open_idx) == 10

    def test_refit_handles_wrapped_lat_ptr(self, setup):
        _, cfg, _, _ = setup
        carry = eng.init_carry(cfg, lat_capacity=64)
        carry = carry._replace(
            lat_ptr=jnp.int32(-100),  # ring long since full, ptr wrapped
            lat_samples_n=jnp.arange(64, dtype=jnp.float32),
            lat_samples_l=jnp.arange(64, dtype=jnp.float32) * 1e-4)
        f = RT.refit_latency_model(carry)
        assert np.isfinite(float(f.a)) and float(f.a) > 0


class TestChunkerBudget:
    """suggested_group_chunks: the 8192-event budget is a CAP (regression:
    chunk sizes 513–1023 used to hit the max(16, ...) floor and dispatch
    up to ~16k events, double the documented budget)."""

    @pytest.mark.parametrize("chunk,expect", [
        (256, 32), (512, 16),    # exact divisors of the budget
        (513, 15), (767, 10), (1023, 8),  # the formerly-broken band
        (1024, 16), (4096, 16),  # legacy fixed group, budget-exempt
    ])
    def test_boundary_sizes(self, chunk, expect):
        assert RT.chunker.suggested_group_chunks(chunk) == expect

    def test_budget_is_a_cap_below_1024(self):
        budget = RT.chunker.GROUP_EVENT_BUDGET
        for chunk in range(1, 1024):
            g = RT.chunker.suggested_group_chunks(chunk)
            assert g >= 1
            assert chunk * g <= budget, \
                f"chunk={chunk}: dispatch {chunk * g} exceeds budget"

    def test_rejects_nonpositive(self):
        with pytest.raises(ValueError):
            RT.chunker.suggested_group_chunks(0)


class TestTelemetry:
    def test_chunk_stats_consistent(self, setup):
        _, cfg, model, make_events = setup
        srt = RT.StreamRuntime(cfg, model,
                               rt=RT.RuntimeConfig(chunk_size=512))
        stats = srt.push(make_events(0), flush=True)
        assert [s.n_events for s in stats] == [512, 512, 512, 464]
        assert [s.start for s in stats] == [0, 512, 1024, 1536]
        for s in stats:
            assert s.events_per_s > 0 and s.l_e_p99 >= s.l_e_p50
        agg = srt.telemetry.aggregate()
        assert agg["n_events"] == N_EVENTS
        assert agg["pms_shed"] == pytest.approx(float(srt.carry.pms_shed))
        assert agg["completions"] == pytest.approx(
            float(np.asarray(srt.carry.complex_count).sum()))

    def test_quantiles_on_very_short_chunks_match_numpy(self, setup):
        """device_chunk_stats p50/p99 on 1–3 valid events, pinned against
        NumPy percentiles — the quantile must reduce over exactly the
        chunk's valid rows, never padding (regression: satellite audit of
        short-tail chunks)."""
        from repro.runtime import telemetry as TM
        _, cfg, model, make_events = setup
        ev = make_events(0)
        carry, outs = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        l_e = np.asarray(outs.l_e)
        for k in (1, 2, 3):
            piece = jax.tree.map(lambda x: x[:k], outs)
            vec = np.asarray(TM.device_chunk_stats(piece, carry))
            np.testing.assert_allclose(
                vec[TM._VEC["l_e_p50"]], np.percentile(l_e[:k], 50),
                rtol=1e-6, err_msg=f"p50, k={k}")
            np.testing.assert_allclose(
                vec[TM._VEC["l_e_p99"]], np.percentile(l_e[:k], 99),
                rtol=1e-6, err_msg=f"p99, k={k}")
            assert vec[TM._VEC["l_e_max"]] == l_e[:k].max()

    def test_grouped_dispatch_and_ragged_tail_quantiles(self, setup):
        """Grouped dispatches only ever carry FULL chunks (push_region) and
        the short tail runs as its own piece, so per-chunk p50/p99 must
        equal NumPy percentiles over each chunk's exact event span — here
        the tail is 2 events."""
        from repro.runtime import telemetry as TM  # noqa: F401
        _, cfg, model, make_events = setup
        n = 4 * 256 + 2
        ev = make_events(0, n=n)
        _, o_mono = eng.run_engine(cfg, model, ev, eng.init_carry(cfg))
        l_e = np.asarray(o_mono.l_e)
        srt = RT.StreamRuntime(cfg, model, rt=RT.RuntimeConfig(
            chunk_size=256, group_chunks=4))
        stats = srt.push(ev, flush=True)
        assert [s.n_events for s in stats] == [256] * 4 + [2]
        for s in stats:
            span = l_e[s.start:s.start + s.n_events]
            np.testing.assert_allclose(s.l_e_p50, np.percentile(span, 50),
                                       rtol=1e-6, err_msg=f"p50@{s.start}")
            np.testing.assert_allclose(s.l_e_p99, np.percentile(span, 99),
                                       rtol=1e-6, err_msg=f"p99@{s.start}")


class TestDriftingStreams:
    def test_gen_stock_drift_ramps_match_probability(self):
        raw = streams.gen_stock_drift(20_000, p_class=0.01, p_class_end=0.2,
                                      seed=3)
        head = raw.attr[:5000].mean()
        tail = raw.attr[-5000:].mean()
        assert tail > 3 * head

    def test_drifting_arrivals_ramp(self):
        arr = streams.drifting_arrivals(1000, rate=100.0, rate_end=400.0)
        assert np.all(np.diff(arr) > 0)
        assert np.diff(arr)[:10].mean() > 3 * np.diff(arr)[-10:].mean()
        assert arr[0] == 0.0

    def test_classify_rate_end_plumbs(self, setup):
        specs, _, _, _ = setup
        raw = streams.gen_stock(500, seed=0)
        flat = streams.classify(specs, raw, rate=100.0)
        ramp = streams.classify(specs, raw, rate=100.0, rate_end=400.0)
        assert float(ramp.arrival[-1]) < float(flat.arrival[-1])


class TestSatelliteFixes:
    def test_lb_violations_counts_only_bound_exceedances(self):
        r = eng.RunResult(
            complex_count=np.ones(1), pms_created=np.ones(1), pms_shed=0.0,
            shed_calls=0.0, overflow=0.0, ebl_dropped=0.0,
            l_e=np.array([0.1, 0.5, 1.5, 2.0]), n_pm=np.zeros(4),
            carry=None)
        res = runner.ExperimentResult(
            shedder="pspice", fn=0.0, match_probability=1.0, max_rate=1.0,
            result=r, ground_truth=r, latency_bound=1.0)
        assert res.lb_violations == pytest.approx(0.5)
        res2 = dataclasses.replace(res, latency_bound=0.3)
        assert res2.lb_violations == pytest.approx(0.75)

    def test_scheduler_metrics_linear_pass_matches_bruteforce(self):
        from repro.serving import scheduler as SC
        cfg = SC.SchedulerConfig(max_slots=8, slo=0.5, policy="pspice",
                                 seed=0)
        reqs = SC.synth_workload(200, rate=60.0, cfg=cfg, seed=1)
        sched = SC.PSpiceScheduler(cfg)
        for r in sorted(reqs, key=lambda r: r.arrival):
            sched.submit(r)
        while len(sched.finished) < len(reqs):
            sched.run_step()
        m = sched.metrics()
        hit = [r for r in sched.finished
               if r.done and r.finish_time <= r.deadline]
        miss_w = sum(r.weight for r in sched.finished
                     if not (r.done and r.finish_time <= r.deadline))
        assert m["in_slo"] == len(hit)
        assert m["goodput"] == pytest.approx(
            len(hit) / max(len(sched.finished), 1))
        assert m["weighted_miss"] == pytest.approx(
            miss_w / sum(r.weight for r in sched.finished))
        assert m["completed"] == sum(r.done for r in sched.finished)
