"""Shared pytest config.  NOTE: no XLA_FLAGS here — smoke tests and benches
must see 1 device; only launch/dryrun.py (subprocess) forces 512.

Markers (slow, quality) are registered in pyproject.toml; the default
run deselects `quality` (addopts) — the CI quality job selects it back
with `-m quality`."""
