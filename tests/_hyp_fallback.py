"""Deterministic drop-in for the `hypothesis` API used by this suite.

`hypothesis` is declared in requirements-dev.txt / pyproject.toml, but the
tier-1 suite must still collect and pass where it isn't installed.  Test
modules import it as:

    try:
        from hypothesis import given, settings, strategies as st
    except ImportError:
        from _hyp_fallback import given, settings, st

When the fallback is active, every ``@given`` test becomes a pytest
parametrization over a fixed, seeded sample of the declared strategies
(plus the strategy corners) — the same properties, deterministic inputs.
Only the strategy surface this suite uses is implemented (integers,
floats with bounds, sampled_from, booleans).
"""
from __future__ import annotations

import dataclasses
import inspect

import numpy as np
import pytest

_N_SAMPLES = 12
_SEED = 0xC0FFEE


@dataclasses.dataclass(frozen=True)
class _Integers:
    lo: int
    hi: int

    def sample(self, rng):
        return int(rng.integers(self.lo, self.hi + 1))

    @property
    def corners(self):
        return (self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class _Floats:
    lo: float
    hi: float

    def sample(self, rng):
        return float(rng.uniform(self.lo, self.hi))

    @property
    def corners(self):
        return (self.lo, self.hi)


@dataclasses.dataclass(frozen=True)
class _SampledFrom:
    choices: tuple

    def sample(self, rng):
        return self.choices[int(rng.integers(0, len(self.choices)))]

    @property
    def corners(self):
        return (self.choices[0], self.choices[-1])


class _Strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Integers(min_value, max_value)

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return _Floats(min_value, max_value)

    @staticmethod
    def sampled_from(elements):
        return _SampledFrom(tuple(elements))

    @staticmethod
    def booleans():
        return _SampledFrom((False, True))


st = strategies = _Strategies()


def settings(*_a, **_kw):
    """No-op stand-in for hypothesis.settings(...)."""
    def deco(fn):
        return fn
    return deco


def given(*strats):
    """Parametrize over a deterministic sample of the strategies."""
    def deco(fn):
        rng = np.random.default_rng(_SEED)
        cases = [tuple(s.corners[0] for s in strats),
                 tuple(s.corners[1] for s in strats)]
        cases += [tuple(s.sample(rng) for s in strats)
                  for _ in range(_N_SAMPLES)]
        cases = list(dict.fromkeys(cases))   # dedupe, keep order
        names = [p for p in inspect.signature(fn).parameters
                 if p != "self"]
        if len(names) == 1:                  # pytest wants bare values here
            cases = [c[0] for c in cases]
        return pytest.mark.parametrize(",".join(names), cases)(fn)
    return deco
