"""Multi-tenant streaming runtime demo: L tenants, drifting streams,
online model refresh (~1 min).

Each tenant is an independent Q1 stock query over its own stream — its
own arrival rate (all drifting upward) and its own drifting match
statistics.  The runtime ingests lane-stacked micro-batches, runs all
lanes through one lane-batched chunk scan with a donated carry, and
between chunks re-estimates every lane's Markov/utility model from its
accumulated observations, so each tenant's shedder tracks its own drift.

  PYTHONPATH=src python examples/runtime_multitenant.py
"""
import sys

from repro.cep import engine as eng
from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams
from repro import runtime as RT

COST = dict(c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
            c_ebl=6e-5)


def main() -> int:
    L, n, chunk = 4, 16_384, 1024
    print(f"=== repro.runtime: {L} tenants x {n} events, "
          f"chunk={chunk}, refresh every 4 chunks ===")
    specs = [pat.make_q1(window_size=400, num_symbols=4)]
    cp = pat.compile_patterns(specs)
    cfg = runner.default_config(cp, max_pms=128, latency_bound=0.02,
                                gather_stats=True, shedder="pspice", **COST)
    model = eng.make_model(cp, cfg)

    # Start near capacity and drift well past it: the back half of every
    # stream overloads the operator, so the shedder has to work.
    rate = 1.0 / (cfg.c_base + cfg.c_match * 0.3 * cfg.max_pms)
    evs = []
    for lane in range(L):
        raw = streams.gen_stock_drift(n, num_symbols=50, pattern_symbols=4,
                                      p_class=0.03, p_class_end=0.10,
                                      seed=100 + lane)
        evs.append(streams.classify(specs, raw, rate=rate * (1 + 0.2 * lane),
                                    rate_end=4.0 * rate, seed=lane))

    mt = RT.MultiTenantRuntime(
        cfg, RT.broadcast_model(model, L), num_lanes=L, specs=specs,
        rt=RT.RuntimeConfig(
            chunk_size=chunk,
            refresh=RT.RefreshConfig(every_chunks=4, min_observations=256,
                                     decay=0.5)))

    print(f"\n{'chunk':>5s} {'events/s':>10s} {'p99 l_e':>9s} "
          f"{'PMs shed':>9s} {'completions':>12s} {'refresh':>8s}")
    # Stream in pushes of an odd size — the buffer re-chunks; flush drains
    # the tail.
    push = 3000
    evL = RT.stack(evs)
    for s in range(0, n, push):
        batch = RT.slice_events(evL, s, min(s + push, n), axis=1)
        for st in mt.push(batch, flush=(s + push >= n)):
            print(f"{st.chunk_index:5d} {st.events_per_s:10.0f} "
                  f"{st.l_e_p99:9.4f} {st.pms_shed:9.0f} "
                  f"{st.completions:12.0f} "
                  f"{'yes' if st.refreshed else '':>8s}")

    agg = mt.telemetry.aggregate()
    merged = mt.merged_carry()
    print(f"\naggregate: {agg['events_per_s']:.0f} events/s over "
          f"{agg['n_events']} events in {agg['n_chunks']} chunks; "
          f"{agg['refreshes']} refresh rounds")
    print("per-tenant completions:",
          [int(c) for c in merged.complex_count])
    print("per-tenant refreshes:  ",
          [s.refresh_count for s in mt.refresh_state])
    return 0


if __name__ == "__main__":
    sys.exit(main())
