"""Quickstart: pSPICE end-to-end on a stock stream (paper Q1, ~1 min).

Builds the Markov utility model from a warm-up phase, then runs the same
overloaded stream through pSPICE / random PM drop (PM-BL) / event shedding
(E-BL) and prints the false-negative comparison — the paper's core result.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys

from repro.cep import patterns as pat
from repro.cep import runner
from repro.data import streams


def main() -> int:
    print("=== pSPICE quickstart: Q1 (seq of 10 stock symbols) ===")
    spec = pat.make_q1(window_size=4000, num_symbols=10)
    raw = streams.gen_stock(50_000, num_symbols=500, pattern_symbols=10,
                            hot_fraction=0.9, p_class=0.03, seed=1)
    res = runner.run_experiment(
        [spec], raw, shedders=("pspice", "pmbl", "ebl"),
        rate_multiplier=1.2, latency_bound=1.0, max_pms=128, bin_size=64,
        c_base=3e-4, c_match=6e-5, c_shed_base=1.5e-4, c_shed_pm=1.5e-6,
        c_ebl=6e-5)

    any_r = next(iter(res.values()))
    print(f"\nmatch probability: {any_r.match_probability:.2%}   "
          f"max operator throughput: {any_r.max_rate:.0f} ev/s   "
          f"overload: 120%\n")
    print(f"{'shedder':10s} {'FN%':>7s} {'PMs shed':>9s} "
          f"{'events dropped':>15s} {'max latency':>12s}")
    for name, r in res.items():
        print(f"{name:10s} {100 * r.fn:6.1f}% {r.result.pms_shed:9.0f} "
              f"{r.result.ebl_dropped:15.0f} "
              f"{float(r.result.l_e.max()):11.3f}s")
    print("\nLatency bound (1.0s) is maintained by pSPICE while shedding "
          "the least useful partial matches.")
    return 0


if __name__ == "__main__":
    sys.exit(main())
