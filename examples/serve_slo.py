"""Example 2: pSPICE as an LLM-serving eviction policy (beyond-paper).

Runs the SLO-bounded continuous-batching scheduler with the three policies
and shows pSPICE's goodput advantage; then drives a REAL (smoke-size) model
decode through the same scheduler via the launch/serve.py driver path.

  PYTHONPATH=src python examples/serve_slo.py
"""
import sys

from repro.serving.scheduler import (SchedulerConfig, run_simulation,
                                     synth_workload)


def main() -> int:
    print("=== pSPICE-on-serving: SLO-bounded decode scheduling ===\n")
    print(f"{'policy':12s} {'goodput':>8s} {'completed':>10s} "
          f"{'evictions':>10s}")
    for pol in ("pspice", "random", "admission"):
        cfg = SchedulerConfig(policy=pol, max_slots=48, slo=1.5)
        reqs = synth_workload(800, rate=120.0, cfg=cfg, seed=3)
        m = run_simulation(cfg, reqs)
        print(f"{pol:12s} {m['goodput']:8.3f} {m['completed']:10d} "
              f"{m['evictions']:10d}")
    print("\npSPICE evicts the in-flight sequences least likely to finish "
          "inside the SLO\nper unit of remaining decode cost — the paper's "
          "utility (Eq. 1) on KV slots.")
    print("\nFor real model compute through the same scheduler:")
    print("  PYTHONPATH=src python -m repro.launch.serve "
          "--arch internlm2-1.8b --policy pspice")
    return 0


if __name__ == "__main__":
    sys.exit(main())
