"""Example 3: fault-tolerant LM training end-to-end (~2 min on CPU).

Trains a reduced internlm2 config for a few hundred steps on a learnable
synthetic stream, with checkpointing and a mid-run simulated failure
(NaN injection) that the loop recovers from — the node-failure story of
DESIGN.md §6 at laptop scale.

  PYTHONPATH=src python examples/train_lm.py
"""
import shutil
import sys
import tempfile

from repro.launch import train


def main() -> int:
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    try:
        print("=== phase 1: train w/ checkpoints + injected fault ===")
        train.main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "60",
                    "--batch", "4", "--seq", "128", "--ckpt-dir", ckpt,
                    "--ckpt-every", "20", "--inject-nan-at", "35"])
        print("\n=== phase 2: crash-resume from the latest checkpoint ===")
        train.main(["--arch", "internlm2-1.8b", "--smoke", "--steps", "80",
                    "--batch", "4", "--seq", "128", "--ckpt-dir", ckpt,
                    "--ckpt-every", "20"])
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
